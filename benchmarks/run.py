"""Benchmark harness — one benchmark per paper table/figure + system
benches.  Prints ``name,us_per_call,derived`` CSV rows.

  table1  — Table 1: rounds-to-target, IID split (FedHeN/NoSide/Decouple)
  table2  — Table 2: rounds-to-target, non-IID (Dirichlet) split
  comm    — communication-savings accounting (bytes to target)
  sidecost— 'side objective adds minimal cost' (paper §2): step-time +
            FLOPs ratio of ClientTrainingSideObj vs ClientTraining
  aggsrv  — server masked-aggregation throughput (kernel contract, XLA path)
  streamscale — streaming cohort engine: cohort x chunk sweep of round
            latency + compiled peak temp memory (O(chunk) memory claim)
  serve   — early-exit serving throughput (reduced arch, CPU)
  roofline— aggregates results/dryrun/*.json (see EXPERIMENTS.md §Roofline)

Env: BENCH_FAST=1 shrinks rounds; BENCH_ONLY=name,name selects a subset.
"""

from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def _row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)


# ---------------------------------------------------------------------------

def bench_tables(which: str):
    from benchmarks.fed_common import table_rows
    iid = which == "table1"
    rounds = 16 if os.environ.get("BENCH_FAST") else 40
    t0 = time.time()
    rows = table_rows(iid=iid, rounds=rounds)
    wall = (time.time() - t0) * 1e6
    meta = rows.pop()["_meta"]
    for r in rows:
        name = f"{which}_{r['model']}_tgt{r['target']}"
        derived = (f"fedhen={r['fedhen']};noside={r['noside']};"
                   f"decouple={r['decouple']};gain={r['gain']:.2f}x")
        _row(name, meta["fedhen"]["us_per_round"], derived)
    _row(which + "_total", wall, f"rounds={rounds}")
    return rows, meta


def bench_comm():
    from benchmarks.fed_common import run_protocol, TARGETS
    from repro.core.federated import rounds_to_target
    rounds = 16 if os.environ.get("BENCH_FAST") else 40
    out = {}
    for a in ("fedhen", "noside", "decouple"):
        res = run_protocol(a, iid=True, rounds=rounds)
        r = rounds_to_target(res["history"], "acc_simple", TARGETS[0])
        bytes_to_tgt = res["bytes_per_round"] * r if r > 0 else float("nan")
        out[a] = bytes_to_tgt
        _row(f"comm_bytes_to_target_{a}", res["wall_per_round_us"],
             f"rounds={r};MB={bytes_to_tgt / 1e6:.1f}")
    if out["fedhen"] == out["fedhen"]:  # not nan
        rest = [v for k, v in out.items() if k != "fedhen" and v == v]
        if rest:
            _row("comm_savings", 0.0,
                 f"fedhen_vs_best_baseline="
                 f"{min(rest) / out['fedhen']:.2f}x")


def bench_sidecost():
    """Paper §2 claim: the side objective is cheap (one extra head)."""
    from repro.configs.base import LayerSpec, ModelConfig
    from repro.core.adapters import LMAdapter
    from repro.optim.sgd import sgd_update
    cfg = ModelConfig(n_layers=6, d_model=128, n_heads=4, n_kv_heads=4,
                      d_ff=256, vocab_size=512,
                      pattern=(LayerSpec("attn"),), exit_layer=3,
                      compute_dtype="float32")
    ad = LMAdapter(cfg)
    params = ad.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 65),
                                          0, cfg.vocab_size)}
    times, flops = {}, {}
    from repro.roofline import hlo_walk
    for name, loss in (("plain", ad.loss_complex), ("side", ad.loss_side)):
        step = jax.jit(lambda p, b, f=loss: sgd_update(
            p, jax.grad(f)(p, b), 0.1, 10.0))
        out = step(params, batch)  # compile
        jax.block_until_ready(out)
        t0 = time.time()
        n = 10
        for _ in range(n):
            out = step(params, batch)
        jax.block_until_ready(out)
        times[name] = (time.time() - t0) / n * 1e6
        txt = jax.jit(lambda p, b, f=loss: jax.grad(f)(p, b)).lower(
            params, batch).compile().as_text()
        flops[name] = hlo_walk.analyze(txt)["flops"]

    _row("side_objective_cost", times["side"],
         f"time_ratio={times['side'] / times['plain']:.3f};"
         f"flops_ratio={flops['side'] / flops['plain']:.3f}"
         f";paper_claim=minimal")


def bench_aggsrv():
    """Server aggregation throughput (the masked_agg kernel contract)."""
    from repro.kernels.masked_agg.ref import masked_agg_ref
    z, n = 10, 4_000_000
    x = jax.random.normal(jax.random.PRNGKey(0), (z, n), jnp.float32)
    mask = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (n,))
    w = jnp.full((z,), 1.0 / z)
    fn = jax.jit(lambda x: masked_agg_ref(x, mask, w, w))
    jax.block_until_ready(fn(x))
    t0 = time.time()
    reps = 5
    for _ in range(reps):
        out = fn(x)
    jax.block_until_ready(out)
    us = (time.time() - t0) / reps * 1e6
    gbps = (z * n * 4) / (us / 1e6) / 1e9
    _row("server_masked_agg", us, f"GBps={gbps:.2f};leaf=10x4M")


def bench_streamscale():
    """Cohort x chunk x engine sweep: the streaming engine's memory/latency
    story plus the flat-vs-tree fold comparison."""
    from benchmarks.streaming_cohort import sweep
    rounds = 1 if os.environ.get("BENCH_FAST") else 3
    for r in sweep(timed_rounds=rounds):
        derived = (f"k={r['k']};chunk={r['chunk']};engine={r['engine']};"
                   f"temp_mib={r['temp_bytes'] / 2**20:.2f};"
                   f"fold_kib={r['fold_temp_bytes'] / 2**10:.0f};"
                   f"reduces={r['hlo_reduce_ops']}")
        for key in ("fits_under_seed_peak", "flat_fits_under_tree",
                    "flat_fewer_reduces"):
            if key in r:
                derived += f";{key}={r[key]}"
        _row(f"streamscale_{r['label']}", r["us_per_round"], derived)


def bench_serve():
    from repro import configs
    from repro.launch.serve import generate
    from repro.models import transformer as tfm
    cfg = configs.get_reduced("gemma2-2b")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    _, stats = generate(params, cfg, prompts, 16, adaptive_threshold=0.5)
    us = (time.time() - t0) * 1e6
    _row("serve_early_exit", us / (8 * 16),
         f"exit_confident={stats['exit_confident_frac']:.2f};"
         f"agreement={stats['exit_agreement']:.2f}")


def bench_roofline():
    path = "results/dryrun"
    if not os.path.isdir(path):
        _row("roofline", 0.0, "no results/dryrun; run repro.launch.dryrun")
        return
    n, worst = 0, None
    for f in sorted(os.listdir(path)):
        if not f.endswith(".json"):
            continue
        with open(os.path.join(path, f)) as fh:
            d = json.load(fh)
        n += 1
        frac = d.get("useful_flops_ratio", 0)
        if d["mesh"] == "16x16" and (worst is None or frac < worst[1]):
            worst = (f"{d['arch']}x{d['shape']}", frac)
    _row("roofline_records", 0.0,
         f"n={n};worst_useful_flops={worst[0]}:{worst[1]:.3f}"
         if worst else f"n={n}")


BENCHES = {
    "table1": lambda: bench_tables("table1"),
    "table2": lambda: bench_tables("table2"),
    "comm": bench_comm,
    "sidecost": bench_sidecost,
    "aggsrv": bench_aggsrv,
    "streamscale": bench_streamscale,
    "serve": bench_serve,
    "roofline": bench_roofline,
}


def main() -> None:
    only = os.environ.get("BENCH_ONLY")
    names = only.split(",") if only else list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        try:
            BENCHES[name]()
        except Exception as e:  # noqa: BLE001
            _row(name + "_ERROR", 0.0, repr(e)[:150])


if __name__ == "__main__":
    main()
