"""Communication savings: wire dtype x architecture sweep (the paper's
headline claim made measurable).

For each (architecture, comm_dtype) point the sweep runs the full FedHeN
protocol on the synthetic task and records the trainer's MEASURED wire
sizes — the real encoder's payload + scale-sidecar bytes per round,
download and upload separately (``FederatedTrainer._measured_comm_bytes``)
— together with the end-of-run evaluation, so every bytes/round number is
paired with the accuracy it buys.  Quantization is not free-floating
simulation: clients train on the decoded broadcast and the server folds
the encoded uploads through the dequantizing ``masked_agg`` accumulate, so
the accuracy delta vs the f32 wire is the round's actual quantization
error compounded over training.

Headline gates (CI-enforced by this script's exit code):

* int8 (ISSUE 4 acceptance): >= 3x fewer bytes/round than f32 on every
  architecture (measured incl. the f32 scale sidecar — the analytic
  ratio at quant_block=128 is 128 / (32 + 4) ~= 3.9x).
* ``int8+ef+topk`` (wire v2 acceptance): the compressed upload path —
  int8 payload + top-k (1/14) sparsification + stochastic rounding +
  error feedback — must move >= 10x fewer UPLOAD bytes/round than f32
  (``ratio_up_vs_f32``; the dense download is untouched by the upload
  knobs, so the total ratio saturates near the int8 wire's) AND end the
  run with held-out accuracy at least the plain int8 wire's at matched
  rounds (error feedback pays for the sparsification).  The accuracy
  floor carries a two-standard-error noise allowance for the ~2k-token
  held-out set (``ACC_NOISE_MARGIN``) so a one-token eval difference
  cannot flake CI; the recorded accuracies in the committed json are
  the unslacked evidence.

Run as a script to emit ``BENCH_comm.json`` and exit nonzero on a gate
failure (the CI smoke): ``python benchmarks/comm_savings.py --fast``.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer
from repro.data.federated import iid_split
from repro.data.synthetic import synthetic_lm

# the compressed-upload point is labelled like a dtype so the trend gate
# keys it as its own (arch, comm_dtype) row
COMPRESSED = "int8+ef+topk"
# topk_frac=1/14 is the knee: ~11x upload savings (>= the 10x gate) at
# an accuracy cost inside eval noise; 1/16 buys 12.6x but error feedback
# no longer fully pays for the sparsification at bench horizons
COMPRESSED_KW = dict(comm_dtype="int8", topk_frac=1 / 14,
                     stochastic_rounding=True, error_feedback=True)
WIRE_DTYPES = ("float32", "bfloat16", "int8", COMPRESSED)

# Two heterogeneous-architecture points: a pure-attention stack and a
# local-attention stack with a deeper exit — different treedefs, leaf
# shapes and M sizes, so the wire layer is exercised on two layouts.
ARCHS: Tuple[ModelConfig, ...] = (
    ModelConfig(name="attn4", n_layers=4, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=256,
                pattern=(LayerSpec("attn"),), exit_layer=2,
                compute_dtype="float32"),
    ModelConfig(name="local6", n_layers=6, d_model=48, n_heads=4,
                n_kv_heads=4, d_ff=96, vocab_size=256, window=16,
                pattern=(LayerSpec("local_attn"),), exit_layer=4,
                compute_dtype="float32"),
)

GATE_MIN_INT8_RATIO = 3.0
GATE_MIN_COMPRESSED_UP_RATIO = 10.0
# two binomial standard errors of the 64x32-token held-out eval
# (sqrt(p(1-p)/n) ~ 0.002 at the accuracies these short runs reach):
# the compressed point must match plain int8 up to eval-set noise
ACC_NOISE_MARGIN = 0.004


def run_point(cfg: ModelConfig, comm_dtype: str, *, rounds: int,
              seed: int = 0) -> Dict:
    wire_kw = (dict(COMPRESSED_KW) if comm_dtype == COMPRESSED
               else {"comm_dtype": comm_dtype})
    fed = FedConfig(n_devices=8, n_simple=4, participation=0.5,
                    rounds=rounds, local_epochs=1, lr=0.1, batch_size=8,
                    algorithm="fedhen", seed=seed, cohort_chunk=2,
                    **wire_kw)
    data = synthetic_lm(fed.n_devices * 16, 32, cfg.vocab_size, seed=1)
    shards = [{"tokens": jnp.asarray(s["tokens"])}
              for s in iid_split(data, fed.n_devices, seed=2)]
    trainer = FederatedTrainer(LMAdapter(cfg), fed, shards)
    test = synthetic_lm(64, 32, cfg.vocab_size, seed=999)
    test_batch = {"tokens": jnp.asarray(test["tokens"])}

    t0 = time.time()
    loss = float("nan")
    for _ in range(rounds):
        loss = trainer.run_round()["loss_complex"]
    dt = time.time() - t0
    ev = trainer.evaluate(test_batch)
    return {
        "arch": cfg.name,
        "comm_dtype": comm_dtype,
        "rounds": rounds,
        "bytes_down_per_round": trainer.bytes_down_per_round,
        "bytes_up_per_round": trainer.bytes_up_per_round,
        "bytes_per_round": trainer.bytes_per_round,
        "total_mbytes": trainer.total_bytes / 1e6,
        "analytic_f32_bytes_per_round": trainer.analytic_bytes_per_round(),
        "loss_complex": loss,
        "acc_complex": ev["acc_complex"],
        "acc_simple": ev["acc_simple"],
        "us_per_round": dt / rounds * 1e6,
    }


def sweep(rounds: int) -> List[Dict]:
    rows = []
    for cfg in ARCHS:
        base = None
        for dtype in WIRE_DTYPES:
            row = run_point(cfg, dtype, rounds=rounds)
            if dtype == "float32":
                base = row
                row["ratio_vs_f32"] = 1.0
                row["ratio_up_vs_f32"] = 1.0
                row["acc_simple_delta_vs_f32"] = 0.0
                row["acc_complex_delta_vs_f32"] = 0.0
            else:
                row["ratio_vs_f32"] = (base["bytes_per_round"]
                                       / row["bytes_per_round"])
                row["ratio_up_vs_f32"] = (base["bytes_up_per_round"]
                                          / row["bytes_up_per_round"])
                row["acc_simple_delta_vs_f32"] = (row["acc_simple"]
                                                  - base["acc_simple"])
                row["acc_complex_delta_vs_f32"] = (row["acc_complex"]
                                                   - base["acc_complex"])
            rows.append(row)
    return rows


def check_gates(rows: List[Dict]) -> List[str]:
    failures = []
    by_key = {(r["arch"], r["comm_dtype"]): r for r in rows}
    for r in rows:
        if not np.isfinite(r["loss_complex"]):
            failures.append(f"{r['arch']}/{r['comm_dtype']}: non-finite "
                            f"end loss")
        if r["comm_dtype"] == "int8" and \
                r["ratio_vs_f32"] < GATE_MIN_INT8_RATIO:
            failures.append(
                f"{r['arch']}/int8: bytes/round ratio vs f32 "
                f"{r['ratio_vs_f32']:.2f} < {GATE_MIN_INT8_RATIO}")
        if r["comm_dtype"] == COMPRESSED:
            if r["ratio_up_vs_f32"] < GATE_MIN_COMPRESSED_UP_RATIO:
                failures.append(
                    f"{r['arch']}/{COMPRESSED}: upload bytes/round ratio "
                    f"vs f32 {r['ratio_up_vs_f32']:.2f} < "
                    f"{GATE_MIN_COMPRESSED_UP_RATIO}")
            int8 = by_key.get((r["arch"], "int8"))
            if int8 is not None and \
                    r["acc_simple"] < int8["acc_simple"] - ACC_NOISE_MARGIN:
                failures.append(
                    f"{r['arch']}/{COMPRESSED}: acc_simple "
                    f"{r['acc_simple']:.4f} below plain int8 "
                    f"{int8['acc_simple']:.4f} - {ACC_NOISE_MARGIN} "
                    f"at matched rounds (error feedback should pay "
                    f"for the top-k)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="4 rounds per point (CI smoke)")
    ap.add_argument("--out", default="BENCH_comm.json")
    args = ap.parse_args(argv)

    rounds = 4 if args.fast else 12
    rows = sweep(rounds)
    payload = {
        "bench": "comm_savings",
        "backend": jax.default_backend(),
        "gate_min_int8_ratio": GATE_MIN_INT8_RATIO,
        "gate_min_compressed_up_ratio": GATE_MIN_COMPRESSED_UP_RATIO,
        "acc_noise_margin": ACC_NOISE_MARGIN,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    for r in rows:
        print(f"{r['arch']:>8}/{r['comm_dtype']:<12}: "
              f"{r['bytes_per_round'] / 1e6:.3f} MB/round "
              f"({r['ratio_vs_f32']:.2f}x vs f32, up "
              f"{r['ratio_up_vs_f32']:.2f}x), "
              f"acc_simple {r['acc_simple']:.4f} "
              f"(d={r['acc_simple_delta_vs_f32']:+.4f}), "
              f"loss {r['loss_complex']:.4f}")

    failures = check_gates(rows)
    if failures:
        print(f"REGRESSION: {failures} (see {args.out})")
        return 1
    print(f"ok — wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
