"""Telemetry overhead: the tentpole's cost contract, measured and gated.

The repro/obs layer promises that instrumentation is ~free when disabled
and cheap when enabled.  This benchmark prices that promise on the real
round loop (tiny LM, the async/comm bench cohort geometry) by timing
four variants of the SAME training run:

* ``raw``      — a hand-inlined round loop that replicates the seed's
  ``run_round`` body (sample -> gather -> jitted round -> state swap ->
  byte totals) with NO telemetry calls at all: the pre-telemetry
  baseline the overhead percentages are measured against.
* ``off``      — ``FederatedTrainer`` with no telemetry (the NOOP
  singleton's early-return path): what every un-instrumented caller
  pays.  **Gate: < 2% over raw.**
* ``on_null``  — telemetry enabled with a ``NullSink``: full event
  assembly (spans, phases, counters, ledgers) without I/O.
  **Gate: < 5% over raw.**
* ``on_jsonl`` — telemetry enabled with a ``JsonlSink`` to a temp file:
  the run-log configuration CI uploads.  **Gate: < 5% over raw.**  The
  produced JSONL is rendered through ``repro.obs.report`` (the
  ``tools/obs_report.py`` path), so the reporter is exercised here too.

Methodology: all four variants are warmed up (the compile round — the
telemetry-on first round deliberately pays an explicit AOT
trace_lower/compile split; steady-state cost is what the gates price),
then timed **interleaved round-by-round** so slow drift in CPU load hits
every variant equally, and the per-variant statistic is the **min**
round wall (the classic noise-robust benchmark estimator — any positive
deviation from the min is interference, and real telemetry overhead is
a constant per-round cost the min cannot hide).  Negative measured
overhead clamps to 0.

Run as a script to emit ``BENCH_obs.json`` and exit nonzero on a gate
failure (the CI smoke): ``python benchmarks/obs_overhead.py --fast``.
``benchmarks/bench_trend.py`` diffs the committed baseline for creep
below the absolute ceilings.
"""

from __future__ import annotations

import argparse
import json
import statistics
import tempfile
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer, ServerState
from repro.data.federated import iid_split
from repro.data.synthetic import synthetic_lm
from repro.obs import report as obs_report
from repro.obs import telemetry as obslib

CFG = ModelConfig(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=256, pattern=(LayerSpec("attn"),),
                  exit_layer=2, compute_dtype="float32")

GATE_OFF_PCT = 2.0      # telemetry-off round-clock overhead ceiling
GATE_ON_PCT = 5.0       # telemetry-on ceiling (any enabled sink)


def make_trainer(telemetry=None) -> FederatedTrainer:
    fed = FedConfig(n_devices=8, n_simple=4, participation=0.5,
                    rounds=8, local_epochs=1, lr=0.1, batch_size=8,
                    algorithm="fedhen", seed=0, cohort_chunk=2)
    data = synthetic_lm(fed.n_devices * 16, 32, CFG.vocab_size, seed=1)
    shards = [{"tokens": jnp.asarray(s["tokens"])}
              for s in iid_split(data, fed.n_devices, seed=2)]
    return FederatedTrainer(LMAdapter(CFG), fed, shards,
                            telemetry=telemetry)


def raw_round(tr: FederatedTrainer) -> Dict[str, float]:
    """The seed's ``run_round`` body, verbatim and telemetry-free — the
    baseline every overhead percentage is measured against."""
    simple_ids, complex_ids = tr._sample_cohort()
    data_s = tr._gather(simple_ids)
    data_c = tr._gather(complex_ids)
    key = jax.random.PRNGKey(tr.fed.seed * 100003 + tr.server.round)
    new_complex, new_simple_host, metrics = tr._round_fn(
        tr.server.complex, tr.server.simple_host, data_s, data_c, key,
        tr._flat_mask_arg())
    tr.server = ServerState(complex=new_complex,
                            simple_host=new_simple_host,
                            round=tr.server.round + 1)
    tr.total_bytes += tr.bytes_per_round
    tr.total_bytes_down += tr.bytes_down_per_round
    tr.total_bytes_up += tr.bytes_up_per_round
    return {k: float(v) for k, v in metrics.items()}


def timed(step: Callable[[], Dict]) -> float:
    t0 = time.perf_counter()
    m = step()
    jax.block_until_ready(m.get("loss_complex", 0.0))
    return time.perf_counter() - t0


def measure(rounds: int) -> List[Dict]:
    tmp_jsonl = tempfile.NamedTemporaryFile(suffix=".jsonl", delete=False)
    tmp_jsonl.close()
    mem = obslib.MemorySink()
    raw_tr = make_trainer(None)
    variants = [
        ("raw", lambda: raw_round(raw_tr), raw_tr, None),
        ("off", None, make_trainer(None), None),
        ("on_null", None,
         make_trainer(obslib.Telemetry([obslib.NullSink(), mem])), mem),
        ("on_jsonl", None,
         make_trainer(obslib.Telemetry([obslib.JsonlSink(tmp_jsonl.name)])),
         None),
    ]
    steps = [(v, step if step is not None else tr.run_round, tr, sink)
             for v, step, tr, sink in variants]
    # warmup: every variant pays its compile round before any timing
    for _, step, _, _ in steps:
        timed(step)
    # interleave: one round of each variant per sweep, so load drift is
    # shared; min is the noise-robust per-variant statistic
    times: Dict[str, List[float]] = {v: [] for v, _, _, _ in steps}
    for _ in range(rounds):
        for v, step, _, _ in steps:
            times[v].append(timed(step))

    rows = []
    base = min(times["raw"])
    for variant, _, tr, sink in steps:
        best = min(times[variant])
        overhead = max((best - base) / base * 100.0, 0.0)
        events_per_round = 0
        if sink is not None:
            # deterministic count: events stamped with the last round
            last = max(e["round"] for e in sink.events
                       if e.get("round") is not None)
            events_per_round = len(
                [e for e in sink.events if e.get("round") == last])
        row = {"variant": variant, "rounds": rounds,
               "min_round_s": best,
               "median_round_s": statistics.median(times[variant]),
               "overhead_pct": overhead,
               "events_per_round": events_per_round}
        if variant == "on_jsonl":
            tr.obs.close()
            rendered = obs_report.report_path(tmp_jsonl.name)
            assert "telemetry run report" in rendered  # reporter exercised
            row["report_lines"] = len(rendered.splitlines())
        rows.append(row)
    return rows


def check_gates(rows: List[Dict]) -> List[str]:
    failures = []
    for r in rows:
        limit = {"off": GATE_OFF_PCT, "on_null": GATE_ON_PCT,
                 "on_jsonl": GATE_ON_PCT}.get(r["variant"])
        if limit is not None and r["overhead_pct"] >= limit:
            failures.append(f"{r['variant']}: telemetry overhead "
                            f"{r['overhead_pct']:.2f}% >= {limit}% of "
                            f"round clock")
        if r["variant"] == "on_null" and r["events_per_round"] <= 0:
            failures.append("on_null: no events observed — the enabled "
                            "path is not emitting")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="6 rounds per variant (CI smoke)")
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args(argv)

    rounds = 6 if args.fast else 12
    rows = measure(rounds)
    payload = {
        "bench": "obs_overhead",
        "backend": jax.default_backend(),
        "gate_off_pct": GATE_OFF_PCT,
        "gate_on_pct": GATE_ON_PCT,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    for r in rows:
        print(f"{r['variant']:>8}: {r['min_round_s'] * 1e3:8.1f} ms/round"
              f" (min; median {r['median_round_s'] * 1e3:.1f})"
              f"  overhead {r['overhead_pct']:5.2f}%"
              f"  events/round {r['events_per_round']}")

    failures = check_gates(rows)
    if failures:
        print(f"REGRESSION: {failures} (see {args.out})")
        return 1
    print(f"ok — wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
