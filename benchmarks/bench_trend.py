"""Bench-trend gate: diff a freshly produced BENCH json against the
committed baseline and fail on a regression in the gated metrics.

The bench files this repo commits are trend-gated in CI:

* ``BENCH_streaming.json`` (benchmarks/streaming_cohort.py) — rows keyed
  by ``label``; gated metrics are the quantities the engine owns: compiled
  round / fold temp bytes and HLO reduce-op counts.  Wall-clock is
  recorded but NOT gated (CI runners are noisy).
* ``BENCH_comm.json`` (benchmarks/comm_savings.py) — rows keyed by
  ``(arch, comm_dtype)`` (the compressed wire-v2 point rides a pseudo
  dtype label, ``int8+ef+topk``); gated metrics are the wire sizes
  (bytes/round, down + up) and the savings ratios vs f32 (total and
  upload-direction).  Accuracy is recorded but NOT gated in the trend
  diff (4 synthetic rounds are seed noise) — the compressed point's
  accuracy-vs-int8 floor is that script's own exit code.
* ``BENCH_async.json`` (benchmarks/async_rounds.py) — rows keyed by
  ``label`` (``lag0``/``lag1``/``lag2``); gated metrics are the simulated
  straggler round-clock speedups (must not drop).  The bit-for-bit lag=0
  parity is gated by that script's own exit code, not the trend diff.
* ``BENCH_obs.json`` (benchmarks/obs_overhead.py) — rows keyed by
  ``variant`` (``off``/``on_null``/``on_jsonl``); gated metrics are the
  telemetry overhead percentages vs the uninstrumented round loop and
  the deterministic events-per-round count.  The <2%/<5% absolute
  ceilings are gated by that script's own exit code; the trend diff
  catches creep below them.
* ``BENCH_clients.json`` (benchmarks/client_scale.py) — rows keyed by
  ``label`` (``n1e3``..``n1e6``); the gated metric is the deterministic
  per-client state-matrix footprint.  The O(cohort) flatness gate
  (sampling+state wall time within 2x from 10^3 to 10^6 clients) is that
  script's own exit code — wall-clock is never trend-gated.
* ``BENCH_vr.json`` (benchmarks/variance_reduction.py) — rows keyed by
  ``label`` (``none``/``scaffold``); the gated metric is the
  deterministic control-variate store footprint.  The convergence gates
  (rounds-to-target and final-accuracy ordering, SCAFFOLD vs plain
  folding) are that script's own exit code.

A metric regresses when the fresh value is worse than baseline by more
than ``--tolerance`` (default 10%): "worse" is *larger* for cost metrics
(bytes, op counts) and *smaller* for the savings ratio.  Zero-valued
byte baselines get a small absolute slack so allocator jitter across
jax/XLA releases cannot flake a 0-vs-208-bytes comparison, and
percentage metrics (``*_pct``) get a small absolute-points slack so
timer noise around a near-zero overhead baseline cannot flake the diff.

Usage: ``python benchmarks/bench_trend.py BASELINE FRESH [--tolerance .1]``
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

# metric -> direction ("up" = larger is worse, "down" = smaller is worse)
GATES = {
    "streaming_cohort": {
        "key": ("label",),
        "metrics": {"temp_bytes": "up", "fold_temp_bytes": "up",
                    "hlo_reduce_ops": "up", "fold_reduce_ops": "up"},
    },
    "comm_savings": {
        "key": ("arch", "comm_dtype"),
        "metrics": {"bytes_per_round": "up", "bytes_down_per_round": "up",
                    "bytes_up_per_round": "up", "ratio_vs_f32": "down",
                    "ratio_up_vs_f32": "down"},
    },
    "async_rounds": {
        "key": ("label",),
        "metrics": {"speedup_straggler_first": "down",
                    "speedup_straggler_last": "down"},
    },
    "obs_overhead": {
        "key": ("variant",),
        "metrics": {"overhead_pct": "up", "events_per_round": "up"},
    },
    "client_scale": {
        "key": ("label",),
        # state_bytes is deterministic (matrix geometry); the wall-clock
        # flatness ratio is gated by the script's own exit code, not the
        # trend diff (CI runners are noisy)
        "metrics": {"state_bytes": "up"},
    },
    "variance_reduction": {
        "key": ("label",),
        # state_bytes is deterministic (store geometry); rounds-to-target
        # and the final-accuracy ordering are gated by that script's own
        # exit code — trajectories are never trend-gated (seed-sensitive
        # across jax releases), wall-clock never either
        "metrics": {"state_bytes": "up"},
    },
}

# absolute slack for byte metrics whose baseline is ~0 (allocator jitter)
ZERO_SLACK_BYTES = 4096
# absolute slack (percentage points) for *_pct metrics: overhead
# baselines sit near 0, where relative tolerance means nothing
PCT_SLACK_POINTS = 2.0


def index_rows(payload: Dict, key_fields: Tuple[str, ...]) -> Dict:
    return {tuple(r[k] for k in key_fields): r for r in payload["rows"]}


def compare(baseline: Dict, fresh: Dict, tolerance: float) -> List[str]:
    bench = baseline.get("bench")
    if bench != fresh.get("bench"):
        return [f"bench kind mismatch: {bench!r} vs {fresh.get('bench')!r}"]
    if bench not in GATES:
        return [f"unknown bench kind {bench!r}"]
    gate = GATES[bench]
    base_rows = index_rows(baseline, gate["key"])
    fresh_rows = index_rows(fresh, gate["key"])
    failures = []
    for key, base in base_rows.items():
        row = fresh_rows.get(key)
        if row is None:
            failures.append(f"{key}: row missing from fresh results")
            continue
        for metric, direction in gate["metrics"].items():
            if metric not in base:
                continue        # baseline predates the metric: not gated
            b, f = float(base[metric]), float(row[metric])
            if direction == "up":
                limit = b * (1.0 + tolerance)
                # token match, not endswith: "bytes_per_round" and
                # "bytes_down_per_round" deserve the zero-baseline slack
                # exactly as much as "temp_bytes" does
                if b == 0 and "bytes" in metric.split("_"):
                    limit += ZERO_SLACK_BYTES
                if metric.endswith("_pct"):
                    limit += PCT_SLACK_POINTS
                bad = f > limit
            else:
                bad = f < b * (1.0 - tolerance)
            if bad:
                failures.append(f"{key}.{metric}: {f:g} vs baseline {b:g} "
                                f"(>{tolerance:.0%} {'' if direction == 'up' else 'drop '}regression)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH json")
    ap.add_argument("fresh", help="freshly produced BENCH json")
    ap.add_argument("--tolerance", type=float, default=0.10)
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    failures = compare(baseline, fresh, args.tolerance)
    if failures:
        print(f"TREND REGRESSION vs {args.baseline}:")
        for line in failures:
            print(f"  {line}")
        return 1
    n = len(baseline.get("rows", []))
    print(f"trend ok: {args.fresh} within {args.tolerance:.0%} of "
          f"{args.baseline} ({n} rows)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
