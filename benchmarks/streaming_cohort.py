"""Streaming cohort engine: cohort size x chunk size x engine sweep.

Measures, for each (cohort k, cohort_chunk, agg_engine) point, the
compiled round's peak temp memory (``memory_analysis().temp_size_in_bytes``
of the AOT round — XLA's scheduled scratch high-water mark, the quantity
the streaming engine bounds), the HLO op / reduce counts (the flat engine
collapses one masked-agg reduction per leaf into ONE per fold), and the
wall-clock round latency.

Headline rows:

* ``k40_chunk5`` — a cohort 4x the seed default (k=40 vs k=10) streamed
  with ``cohort_chunk=5`` must fit under the one-shot k=10 round's peak
  temp memory (ISSUE 2 acceptance).
* ``k40_chunk5`` (flat) vs ``k40_chunk5_tree`` — the flat-buffer fold must
  use no more temp memory than the per-leaf tree fold
  (``fold_temp_bytes``, the aggregation program lowered alone — flat
  compiles to ZERO scratch on CPU, in-place accumulation, vs the tree
  fold's per-leaf temps) and the compiled round must carry fewer reduce
  ops (ISSUE 3 acceptance).

Round-level ``temp_bytes`` is reported for both engines too.  Note its
flat-vs-tree delta on CPU is allocator noise, not engine cost: the round
arena is dominated by identical client-training scratch, and XLA's buffer
assignment tucks the tree engine's 28 small accumulators into arena holes
where the flat engine's one contiguous accumulator cannot go (measured
+0.46% here; the fold-scoped numbers above isolate what the engine owns,
and on TPU the ``input_output_aliases`` accumulator removes the second
copy entirely).

Run as a script to emit ``BENCH_streaming.json`` and exit nonzero on a
regression (the CI smoke): ``python benchmarks/streaming_cohort.py --fast``.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer
from repro.data.federated import iid_split
from repro.data.synthetic import synthetic_lm

STREAM_CFG = ModelConfig(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=256,
                         pattern=(LayerSpec("attn"),), exit_layer=2,
                         compute_dtype="float32")

# (label, total clients, cohort_chunk, agg_engine); participation 0.5 ->
# k = clients/2.  k=10 matches the seed FedConfig default cohort
# (100 devices x 10%).
SWEEP: Tuple[Tuple[str, int, int, str], ...] = (
    ("k10_chunk0", 20, 0, "flat"),      # seed-default cohort, one-shot
    ("k10_chunk5", 20, 5, "flat"),
    ("k40_chunk0", 80, 0, "flat"),      # 4x cohort, one-shot: memory blow-up
    ("k40_chunk10", 80, 10, "flat"),
    ("k40_chunk5", 80, 5, "flat"),      # 4x cohort streamed: acceptance row
    ("k40_chunk5_tree", 80, 5, "tree"),  # per-leaf fold: the flat-vs-tree row
)


def build_trainer(n_devices: int, chunk: int, *, engine: str = "flat",
                  timed_rounds: int) -> FederatedTrainer:
    fed = FedConfig(n_devices=n_devices, n_simple=n_devices // 2,
                    participation=0.5, rounds=timed_rounds, local_epochs=1,
                    lr=0.1, batch_size=8, algorithm="fedhen", seed=0,
                    cohort_chunk=chunk, agg_engine=engine)
    data = synthetic_lm(n_devices * 16, 32, STREAM_CFG.vocab_size, seed=1)
    shards = iid_split(data, fed.n_devices, seed=2)
    shards = [{"tokens": jnp.asarray(s["tokens"])} for s in shards]
    return FederatedTrainer(LMAdapter(STREAM_CFG), fed, shards)


def measure_fold(trainer, z: int) -> Dict:
    """Lower ONE aggregation fold (z stacked clients) by itself: the temp
    bytes and op counts the engine owns, isolated from training scratch."""
    from repro.core import aggregate
    engine = trainer.fed.agg_engine
    template = trainer.server.complex
    mask = trainer.mask
    chunk = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (z,) + x.shape), template)
    is_simple = jnp.zeros(z, bool)
    valid = jnp.ones(z, bool)

    def bind(flat_mask):
        """flat_mask enters as a traced argument (mirroring the round jit)"""
        return aggregate.make_engine(
            engine, algorithm="fedhen", mask=mask,
            layout=trainer.layout if engine == "flat" else None,
            flat_mask=flat_mask, block_n=trainer.fed.agg_block_n)

    state = bind(None)[0](template)
    if engine == "flat":
        fold = lambda s, c, i, v, fm: bind(fm)[1](s, c, i, v)
        args = (state, chunk, is_simple, valid, trainer.flat_mask)
    else:
        fold = lambda s, c, i, v: bind(None)[1](s, c, i, v)
        args = (state, chunk, is_simple, valid)
    compiled = jax.jit(fold).lower(*args).compile()
    hlo = compiled.as_text()
    return {"fold_temp_bytes":
            int(compiled.memory_analysis().temp_size_in_bytes),
            "fold_reduce_ops": hlo.count(" reduce(")}


def measure(n_devices: int, chunk: int, *, engine: str = "flat",
            timed_rounds: int = 3) -> Dict:
    trainer = build_trainer(n_devices, chunk, engine=engine,
                            timed_rounds=timed_rounds)
    compiled = trainer.lower_round().compile()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    trainer.run_round()                      # compile + warm the jit cache
    t0 = time.time()
    for _ in range(timed_rounds):
        trainer.run_round()
    us = (time.time() - t0) / timed_rounds * 1e6
    row = {"k": trainer.k_simple + trainer.k_complex, "chunk": chunk,
           "engine": engine,
           "us_per_round": us,
           "temp_bytes": int(mem.temp_size_in_bytes),
           "arg_bytes": int(mem.argument_size_in_bytes),
           "hlo_ops": hlo.count(" = "),
           "hlo_reduce_ops": hlo.count(" reduce(")}
    row.update(measure_fold(
        trainer, chunk if chunk > 0 else max(trainer.k_simple,
                                             trainer.k_complex)))
    return row


def sweep(timed_rounds: int = 3) -> List[Dict]:
    rows = []
    for label, n_devices, chunk, engine in SWEEP:
        r = measure(n_devices, chunk, engine=engine,
                    timed_rounds=timed_rounds)
        r["label"] = label
        rows.append(r)
    by = {r["label"]: r for r in rows}
    # the PR 2 acceptance comparison: 4x cohort streamed vs seed one-shot
    flat = by["k40_chunk5"]
    flat["fits_under_seed_peak"] = (
        flat["temp_bytes"] <= by["k10_chunk0"]["temp_bytes"])
    # CI-gated variant with headroom: a broken chunking path blows round
    # temp up ~4x (see k40_chunk0), while allocator-level jitter across
    # jax/XLA releases moves it by fractions of a percent — 1.5x separates
    # the two without making CI track XLA's buffer assignment exactly
    flat["stream_memory_ok"] = (
        flat["temp_bytes"] <= 1.5 * by["k10_chunk0"]["temp_bytes"])
    # the PR 3 acceptance comparison: flat fold vs per-leaf tree fold
    tree = by["k40_chunk5_tree"]
    flat["flat_fits_under_tree"] = (flat["fold_temp_bytes"]
                                    <= tree["fold_temp_bytes"])
    flat["flat_fewer_reduces"] = (flat["hlo_reduce_ops"]
                                  < tree["hlo_reduce_ops"])
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="1 timed round per point (CI smoke)")
    ap.add_argument("--out", default="BENCH_streaming.json")
    args = ap.parse_args(argv)

    rows = sweep(timed_rounds=1 if args.fast else 3)
    from repro.core import flatten
    params_abs = jax.eval_shape(LMAdapter(STREAM_CFG).init,
                                jax.random.PRNGKey(0))
    payload = {
        "bench": "streaming_cohort",
        "backend": jax.default_backend(),
        "model": STREAM_CFG.name,
        "n_flat": flatten.build_layout(params_abs, total_multiple=2048).n_flat,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    for r in rows:
        print(f"{r['label']:>16}: {r['us_per_round']:.0f} us/round, "
              f"round temp {r['temp_bytes'] / 2**20:.2f} MiB, "
              f"fold temp {r['fold_temp_bytes'] / 2**10:.0f} KiB, "
              f"{r['hlo_reduce_ops']} reduce ops ({r['engine']})")

    flat = next(r for r in rows if r["label"] == "k40_chunk5")
    failures = [k for k in ("stream_memory_ok", "flat_fits_under_tree",
                            "flat_fewer_reduces") if not flat[k]]
    if failures:
        print(f"REGRESSION: {failures} (see {args.out})")
        return 1
    print(f"ok — wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
