"""Streaming cohort engine: cohort size x chunk size sweep.

Measures, for each (cohort k, cohort_chunk) point, the compiled round's
peak temp memory (``memory_analysis().temp_size_in_bytes`` of the AOT
round — XLA's scheduled scratch high-water mark, the quantity the
streaming engine bounds) and the wall-clock round latency.

The headline row: a cohort 4x the seed default (k=40 vs k=10) streamed
with ``cohort_chunk=5`` must fit under the one-shot k=10 round's peak temp
memory — that is the scale the engine buys (ISSUE 2 acceptance).
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer
from repro.data.federated import iid_split
from repro.data.synthetic import synthetic_lm

STREAM_CFG = ModelConfig(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=256,
                         pattern=(LayerSpec("attn"),), exit_layer=2,
                         compute_dtype="float32")

# (label, total clients, cohort_chunk); participation 0.5 -> k = clients/2.
# k=10 matches the seed FedConfig default cohort (100 devices x 10%).
SWEEP: Tuple[Tuple[str, int, int], ...] = (
    ("k10_chunk0", 20, 0),    # seed-default cohort, one-shot
    ("k10_chunk5", 20, 5),
    ("k40_chunk0", 80, 0),    # 4x cohort, one-shot: the memory blow-up
    ("k40_chunk10", 80, 10),
    ("k40_chunk5", 80, 5),    # 4x cohort streamed: the acceptance row
)


def build_trainer(n_devices: int, chunk: int, *,
                  timed_rounds: int) -> FederatedTrainer:
    fed = FedConfig(n_devices=n_devices, n_simple=n_devices // 2,
                    participation=0.5, rounds=timed_rounds, local_epochs=1,
                    lr=0.1, batch_size=8, algorithm="fedhen", seed=0,
                    cohort_chunk=chunk)
    data = synthetic_lm(n_devices * 16, 32, STREAM_CFG.vocab_size, seed=1)
    shards = iid_split(data, fed.n_devices, seed=2)
    shards = [{"tokens": jnp.asarray(s["tokens"])} for s in shards]
    return FederatedTrainer(LMAdapter(STREAM_CFG), fed, shards)


def measure(n_devices: int, chunk: int, *, timed_rounds: int = 3) -> Dict:
    trainer = build_trainer(n_devices, chunk, timed_rounds=timed_rounds)
    compiled = trainer.lower_round().compile()
    mem = compiled.memory_analysis()
    trainer.run_round()                      # compile + warm the jit cache
    t0 = time.time()
    for _ in range(timed_rounds):
        trainer.run_round()
    us = (time.time() - t0) / timed_rounds * 1e6
    return {"k": trainer.k_simple + trainer.k_complex, "chunk": chunk,
            "us_per_round": us,
            "temp_bytes": int(mem.temp_size_in_bytes),
            "arg_bytes": int(mem.argument_size_in_bytes)}


def sweep(timed_rounds: int = 3) -> List[Dict]:
    rows = []
    for label, n_devices, chunk in SWEEP:
        r = measure(n_devices, chunk, timed_rounds=timed_rounds)
        r["label"] = label
        rows.append(r)
    by = {r["label"]: r for r in rows}
    # the acceptance comparison: 4x cohort streamed vs seed one-shot peak
    by["k40_chunk5"]["fits_under_seed_peak"] = (
        by["k40_chunk5"]["temp_bytes"] <= by["k10_chunk0"]["temp_bytes"])
    return rows
