"""SCAFFOLD variance reduction vs plain federated averaging: held-out
convergence on a non-IID split, plus the flat state store's footprint
and overhead.

SCAFFOLD (Karimireddy et al. 2020) exists for exactly the setting FedHeN
creates: heterogeneous clients doing many local steps on non-IID shards
drift toward their local optima.  On this synthetic task the drift shows
up as a *decaying plateau*: plain masked averaging reaches peak held-out
accuracy in a few rounds and then slides backwards round over round as
client drift accumulates, while the control-variate correction
``c - c_i`` holds the server at the plateau.  Both effects are measured
and CI-gated:

1. **Rounds-to-target** (``acc_complex >= ACC_TARGET`` on a held-out
   batch, server model): SCAFFOLD must reach it in no more rounds than
   plain folding.
2. **End-of-run accuracy** (the drift-resistance headline): SCAFFOLD's
   final held-out accuracy must be at least plain folding's — on this
   task the baseline has measurably decayed by then, so the gate fails
   if the correction stops correcting.
3. **State-store cost.**  The ``(N_clients, n_flat)`` control-variate
   store's footprint (deterministic — trend-gated), cumulative
   gather/scatter traffic, and a microbenchmark of one cohort
   gather+scatter round trip — the per-round host cost SCAFFOLD adds.

Run as a script to emit ``BENCH_vr.json`` and exit nonzero on a gate
failure (the CI smoke): ``python benchmarks/variance_reduction.py --fast``.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer, rounds_to_target
from repro.data.federated import dirichlet_split
from repro.data.synthetic import synthetic_lm

CFG = ModelConfig(name="attn4", n_layers=4, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=256,
                  pattern=(LayerSpec("attn"),), exit_layer=2,
                  compute_dtype="float32")

# the drift-heavy setting SCAFFOLD targets: strongly non-IID shards,
# several local epochs, full participation (so every c_i refreshes each
# round).  Geometry is identical in --fast and full mode (only the round
# budget changes) so the deterministic state-store rows trend-compare
# across modes.
N_DEVICES = 8
DIRICHLET_ALPHA = 0.05
LOCAL_EPOCHS = 4

# held-out accuracy target both variants reach within the budget (tuned
# once on the synthetic task; the gate is the ORDERING)
ACC_TARGET = 0.74

GATHER_SCATTER_REPS = 50


def make_trainer(vr: str, *, rounds: int, seed: int = 0
                 ) -> FederatedTrainer:
    fed = FedConfig(n_devices=N_DEVICES, n_simple=N_DEVICES // 2,
                    participation=1.0, rounds=rounds,
                    local_epochs=LOCAL_EPOCHS, lr=0.2, batch_size=8,
                    iid=False, dirichlet_alpha=DIRICHLET_ALPHA,
                    algorithm="fedhen", seed=seed,
                    variance_reduction=vr)
    data = synthetic_lm(800, 32, CFG.vocab_size, seed=1)
    shards = [{"tokens": jnp.asarray(s["tokens"])}
              for s in dirichlet_split(data, fed.n_devices,
                                       fed.dirichlet_alpha, seed=2)]
    return FederatedTrainer(LMAdapter(CFG), fed, shards)


def gather_scatter_us(trainer: FederatedTrainer) -> float:
    """One cohort gather + scatter round trip through the state store
    (microbenchmark of the host cost SCAFFOLD adds per round)."""
    store = trainer.cv_store
    if store is None:
        return 0.0
    k = trainer.k_simple + trainer.k_complex
    ids = np.arange(k) % store.n_clients
    rows = np.asarray(store.gather(ids))
    t0 = time.perf_counter()
    for _ in range(GATHER_SCATTER_REPS):
        jax.block_until_ready(store.gather(ids))
        store.scatter(ids, rows)
    return (time.perf_counter() - t0) / GATHER_SCATTER_REPS * 1e6


def run_point(vr: str, *, rounds: int) -> Dict:
    trainer = make_trainer(vr, rounds=rounds)
    test = {"tokens": jnp.asarray(
        synthetic_lm(128, 32, CFG.vocab_size, seed=99)["tokens"])}
    history: List[Dict] = []
    t0 = time.time()
    for _ in range(rounds):
        m = trainer.run_round()
        m.update(trainer.evaluate(test))
        m["round"] = trainer.server.round
        history.append(m)
    wall = time.time() - t0
    store = trainer.cv_store
    cv_norm = (float(jnp.linalg.norm(trainer.cv_global))
               if trainer.cv_global is not None else 0.0)
    return {
        "label": vr,
        "variance_reduction": vr,
        "rounds": rounds,
        "rounds_to_target": rounds_to_target(history, "acc_complex",
                                             ACC_TARGET),
        "final_acc_complex": history[-1]["acc_complex"],
        "final_loss_complex": history[-1]["loss_complex"],
        "acc_trajectory": [round(h["acc_complex"], 4) for h in history],
        "state_bytes": store.nbytes if store else 0,
        "state_backend": store.backend if store else "-",
        "cum_gathered_bytes": store.gathered_bytes if store else 0,
        "cum_scattered_bytes": store.scattered_bytes if store else 0,
        "gather_scatter_us": gather_scatter_us(trainer),
        "cv_global_norm": cv_norm,
        "bytes_per_round": trainer.bytes_per_round,
        "us_per_round": wall / rounds * 1e6,
    }


def check_gates(payload: Dict) -> List[str]:
    rows = {r["label"]: r for r in payload["rows"]}
    none, scaf = rows["none"], rows["scaffold"]
    failures = []
    for r in (none, scaf):
        if not np.isfinite(r["final_loss_complex"]):
            failures.append(f"{r['label']}: non-finite end loss")
    if scaf["rounds_to_target"] < 0:
        failures.append(
            f"scaffold never reached acc {ACC_TARGET} in "
            f"{scaf['rounds']} rounds (final "
            f"{scaf['final_acc_complex']:.4f})")
    elif none["rounds_to_target"] > 0 and \
            scaf["rounds_to_target"] > none["rounds_to_target"]:
        failures.append(
            f"scaffold slower to acc {ACC_TARGET}: "
            f"{scaf['rounds_to_target']} vs {none['rounds_to_target']} "
            f"rounds")
    if scaf["final_acc_complex"] < none["final_acc_complex"]:
        failures.append(
            f"scaffold lost the drift-resistance edge: final acc "
            f"{scaf['final_acc_complex']:.4f} < plain folding's "
            f"{none['final_acc_complex']:.4f}")
    if scaf["state_bytes"] <= 0 or scaf["state_bytes"] % (4 * N_DEVICES):
        failures.append(f"state-store footprint {scaf['state_bytes']} is "
                        f"not {N_DEVICES} f32 rows")
    if scaf["cum_gathered_bytes"] <= 0 or scaf["cum_scattered_bytes"] <= 0:
        failures.append("scaffold run never touched the state store")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="8 rounds per variant (CI smoke)")
    ap.add_argument("--out", default="BENCH_vr.json")
    args = ap.parse_args(argv)

    rounds = 8 if args.fast else 16
    rows = [run_point(vr, rounds=rounds) for vr in ("none", "scaffold")]

    payload = {
        "bench": "variance_reduction",
        "backend": jax.default_backend(),
        "acc_target": ACC_TARGET,
        "n_devices": N_DEVICES,
        "dirichlet_alpha": DIRICHLET_ALPHA,
        "local_epochs": LOCAL_EPOCHS,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)

    for r in rows:
        hit = r["rounds_to_target"]
        print(f"{r['label']:>8}: final acc {r['final_acc_complex']:.4f} "
              f"after {r['rounds']} rounds, target {ACC_TARGET} "
              + (f"at round {hit}" if hit > 0 else "not reached")
              + f", store {r['state_bytes']} B ({r['state_backend']}), "
                f"gather+scatter {r['gather_scatter_us']:.0f} us")

    failures = check_gates(payload)
    if failures:
        print(f"REGRESSION: {failures} (see {args.out})")
        return 1
    print(f"ok — wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
