"""Population-scale client service: O(cohort) per-round host cost, gated.

The client registry tier (``core/sampling.py`` + ``core/client_state.py``)
promises that the per-round HOST work — drawing the cohort, recording
participation, version-tag download billing — costs O(cohort), not
O(population): "millions of users" must be a config value, not a rewrite.
This benchmark prices that promise by running the full host-side round
path (uniform super-cohort ``plan`` -> ``record_round`` ->
``bill_downloads``) at a FIXED cohort size while the client population
grows 10^3 -> 10^6, and gating the wall-time flatness.

Per row (one population size, label ``n1e3`` .. ``n1e6``):

* ``sample_state_ms`` — min wall time of one full host round
  (sample + record + bill) over ``--repeats`` timed loops of ``ROUNDS``
  rounds each (min = the noise-robust estimator every bench here uses);
* ``plan_ms`` — the sampling draw alone, same methodology;
* ``state_bytes`` — the client-state matrix footprint
  (``(N + 1) x width`` f64): deterministic, trend-gated by
  ``bench_trend.py`` so the schema cannot silently widen.

Own gate (script exit code): ``max(sample_state_ms) <=``
``FLATNESS_LIMIT x min(sample_state_ms)`` across the population sweep —
a 1000x population growth may cost at most 2x in per-round host time.
An O(N) regression (a dict rebuild, a full-matrix copy, a
``Generator.choice`` on the sparse path) blows this up by orders of
magnitude, so the 2x ceiling is loose for noise yet tight for bugs.

Run as a script to emit ``BENCH_clients.json`` and exit nonzero on a
gate failure (the CI smoke): ``python benchmarks/client_scale.py --fast``.
``--fast`` only trims repeats — the population sweep IS the gate, so all
rows are always present.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from repro.core.client_state import ClientStateMatrix
from repro.core.sampling import CohortSampler

POPULATIONS = (10**3, 10**4, 10**5, 10**6)
# fixed cohort: participation scales as COHORT / N.  128 keeps every
# population on the SAME sampler code path (4k < n -> batched rejection):
# at 256 the 10^3 row would take the dense partial-Fisher-Yates branch,
# which is legitimately faster and would turn the flatness gate into a
# code-path comparison instead of an O(N) growth detector.
COHORT = 128
ROUNDS = 50             # host rounds per timed loop
FLATNESS_LIMIT = 2.0    # max/min sample_state_ms across the sweep
NBYTES_DOWN = 1.0e6     # nominal per-client download (billing arithmetic
                        # only — the cost being timed is the tag compare)


def host_round(sampler: CohortSampler, state: ClientStateMatrix,
               round_index: int) -> None:
    """One round of the host-side client-service path: draw the uniform
    super-cohort, record participation, bill version-tagged downloads
    (every client fetches the fresh round tag — the worst billing case:
    all misses, full scatter)."""
    plan = sampler.plan(round_index)
    ids = plan.real_ids()
    state.record_round(ids, round_index)
    state.bill_downloads(ids, np.full(ids.shape, float(round_index)),
                         NBYTES_DOWN)


def time_loop(fn, rounds: int, repeats: int) -> float:
    """Min wall seconds of ``rounds`` calls of ``fn`` over ``repeats``
    trials (per-round time = min / rounds)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for r in range(rounds):
            fn(r)
        best = min(best, time.perf_counter() - t0)
    return best


def measure(repeats: int) -> List[Dict]:
    rows = []
    for n in POPULATIONS:
        sampler = CohortSampler(n_devices=n, n_simple=n // 2,
                                participation=COHORT / n, seed=7,
                                uniform=True)
        state = ClientStateMatrix(n)
        # warmup: first-touch page faults on the state matrix + any
        # numpy lazy init, outside the timed loops
        host_round(sampler, state, 0)

        plan_s = time_loop(lambda r: sampler.plan(r), ROUNDS, repeats)
        full_s = time_loop(lambda r: host_round(sampler, state, r),
                           ROUNDS, repeats)
        rows.append({
            "label": f"n1e{int(np.log10(n))}",
            "n_clients": n,
            "cohort": COHORT,
            "k_super": sampler.k_super,
            "plan_ms": plan_s / ROUNDS * 1e3,
            "sample_state_ms": full_s / ROUNDS * 1e3,
            "state_bytes": state.nbytes,
        })
    return rows


def check_gates(rows: List[Dict]) -> List[str]:
    times = [r["sample_state_ms"] for r in rows]
    lo, hi = min(times), max(times)
    failures = []
    if hi > FLATNESS_LIMIT * lo:
        failures.append(
            f"per-round host time is not O(cohort): "
            f"{hi:.4f} ms at worst vs {lo:.4f} ms at best "
            f"(> {FLATNESS_LIMIT}x) across populations "
            f"{[r['n_clients'] for r in rows]}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer timing repeats (CI smoke); the population "
                         "sweep and the flatness gate are identical")
    ap.add_argument("--out", default="BENCH_clients.json")
    args = ap.parse_args(argv)

    repeats = 3 if args.fast else 10
    rows = measure(repeats)
    payload = {
        "bench": "client_scale",
        "cohort": COHORT,
        "flatness_limit": FLATNESS_LIMIT,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    for r in rows:
        print(f"{r['label']:>6}: plan {r['plan_ms']:7.4f} ms  "
              f"sample+state {r['sample_state_ms']:7.4f} ms/round  "
              f"state {r['state_bytes'] / 2**20:8.2f} MiB")

    failures = check_gates(rows)
    if failures:
        print(f"REGRESSION: {failures} (see {args.out})")
        return 1
    print(f"ok — wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
