"""Shared harness for the paper-table benchmarks (Tables 1 & 2).

The paper's metric: communication rounds to reach a fixed target test
accuracy, for FedHeN vs Decouple vs NoSide, on IID and non-IID splits.
This container is CPU-only, so the benchmark runs the *protocol* faithfully
(heterogeneous cohort, side objective, masked aggregation, E local epochs,
clip 10) at reduced scale: a small decoder LM on synthetic Markov data.
The validated claims are the ORDERING and the gain ratio, not absolute
CIFAR accuracies (see EXPERIMENTS.md §Paper-validation).
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp

from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer, rounds_to_target
from repro.data.federated import dirichlet_split, iid_split
from repro.data.synthetic import synthetic_lm

BENCH_CFG = ModelConfig(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab_size=256,
                        pattern=(LayerSpec("attn"),), exit_layer=2,
                        compute_dtype="float32")

# targets chosen so all three algorithms cross them within the default
# 40-round budget (tuned once; see EXPERIMENTS.md §Paper-validation)
TARGETS = (0.10, 0.20)


def run_protocol(algorithm: str, *, iid: bool, rounds: int = 40,
                 seed: int = 0) -> Dict:
    fed = FedConfig(n_devices=20, n_simple=10, participation=0.2,
                    rounds=rounds, local_epochs=1, lr=0.1, batch_size=8,
                    iid=iid, dirichlet_alpha=0.5, algorithm=algorithm,
                    seed=seed)
    data = synthetic_lm(400, 32, BENCH_CFG.vocab_size, seed=1)
    split = iid_split(data, fed.n_devices, seed=2) if iid else \
        dirichlet_split(data, fed.n_devices, fed.dirichlet_alpha, seed=2)
    shards = [{"tokens": jnp.asarray(s["tokens"])} for s in split]
    test = {"tokens": jnp.asarray(
        synthetic_lm(64, 32, BENCH_CFG.vocab_size, seed=99)["tokens"])}
    trainer = FederatedTrainer(LMAdapter(BENCH_CFG), fed, shards)
    t0 = time.time()
    history = trainer.run(rounds, eval_every=2, test_batch=test)
    wall = time.time() - t0
    return {"algorithm": algorithm, "history": history,
            "bytes_per_round": trainer.bytes_per_round,
            "total_bytes": trainer.total_bytes,
            "wall_per_round_us": wall / rounds * 1e6}


def table_rows(iid: bool, targets=TARGETS, rounds: int = 40
               ) -> List[Dict]:
    results = {a: run_protocol(a, iid=iid, rounds=rounds)
               for a in ("fedhen", "noside", "decouple")}
    rows = []
    for head, key in (("simple", "acc_simple"), ("complex", "acc_complex")):
        for tgt in targets:
            row = {"model": head, "target": tgt}
            for a, res in results.items():
                row[a] = rounds_to_target(res["history"], key, tgt)
            base = [row[a] for a in ("noside", "decouple") if row[a] > 0]
            row["gain"] = (min(base) / row["fedhen"]
                           if row["fedhen"] > 0 and base else float("nan"))
            rows.append(row)
    rows.append({"_meta": {a: {"us_per_round": r["wall_per_round_us"],
                               "bytes_per_round": r["bytes_per_round"]}
                           for a, r in results.items()}})
    return rows
