"""Generate the EXPERIMENTS.md roofline tables from results/dryrun/*.json.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [results/dryrun]
"""

from __future__ import annotations

import json
import os
import sys

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(path: str):
    recs = []
    for f in sorted(os.listdir(path)):
        if f.endswith(".json"):
            with open(os.path.join(path, f)) as fh:
                recs.append(json.load(fh))
    return recs


def fmt_t(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def table(recs, mesh: str):
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], ORDER_SHAPES.index(r["shape"])))
    print(f"\n### Mesh {mesh} ({rows[0]['chips']} chips)\n")
    print("| arch | shape | t_compute | t_memory | t_coll | bottleneck | "
          "useful-FLOPs | peak GiB | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        note = "longctx-variant" if r.get("longctx_variant") else ""
        print(f"| {r['arch']} | {r['shape']} | {fmt_t(r['t_compute'])} | "
              f"{fmt_t(r['t_memory'])} | {fmt_t(r['t_collective'])} | "
              f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.3f} | "
              f"{r['peak_memory_per_chip'] / 2**30:.1f} | {note} |")


def interesting(recs):
    single = [r for r in recs if r["mesh"] == "16x16"]
    worst_useful = min(single, key=lambda r: r["useful_flops_ratio"] or 1)
    most_coll = max(single, key=lambda r: (r["t_collective"] /
                                           max(r["t_compute"],
                                               r["t_memory"], 1e-12)))
    train = [r for r in single if r["shape"] == "train_4k"]
    worst_train = min(train, key=lambda r: r["useful_flops_ratio"] or 1)
    print("\n### Hillclimb candidates\n")
    print(f"- worst useful-FLOPs ratio: {worst_useful['arch']} x "
          f"{worst_useful['shape']} ({worst_useful['useful_flops_ratio']:.3f})")
    print(f"- most collective-bound: {most_coll['arch']} x "
          f"{most_coll['shape']} (t_coll {fmt_t(most_coll['t_collective'])})")
    print(f"- worst train (technique-representative): {worst_train['arch']} "
          f"x train_4k ({worst_train['useful_flops_ratio']:.3f})")


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(path)
    print(f"{len(recs)} dry-run records from {path}")
    for mesh in ("16x16", "2x16x16"):
        if any(r["mesh"] == mesh for r in recs):
            table(recs, mesh)
    interesting(recs)


if __name__ == "__main__":
    main()
