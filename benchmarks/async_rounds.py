"""Async round engine: lag=0 parity gate, accuracy-vs-lag curves, and the
simulated straggler round-clock speedup (the reason the engine exists).

Three measurements, one ``BENCH_async.json``:

1. **lag=0 parity (CI gate).**  The async engine run at ``lag=0`` must
   reproduce the synchronous engine's server params **bit-for-bit**
   (``max_abs_diff == 0.0``) through its own code path — version stack,
   dynamic version select, float staleness weights.  This is the oracle
   that says the async machinery adds no numerical drift before any lag
   is turned on.

2. **Accuracy vs lag.**  The full FedHeN protocol on the synthetic task
   at ``lag`` in {0, 1, 2}: end loss/accuracy per lag, so the cost of
   staleness is documented next to the speedup it buys.  Also records the
   measured (version-aware) download bytes — stale-broadcast reuse shows
   up as a per-round saving.

3. **Straggler round-clock speedup (simulated).**  A discrete-event model
   of the fold stream: chunk ``t`` of round ``r`` can start training as
   soon as its (possibly stale) broadcast version exists — ``close(r) =
   max_t(close(r - 1 - staleness(t)) + time(t))``, with the true
   ``fold_schedule``.  One chunk is a straggler (the big-architecture
   cohort, ``STRAGGLER_FACTOR`` x slower).  Synchronously the straggler
   gates every round; with lag covering its position it trains against
   the previous round's broadcast while the server folds ahead, halving
   the steady-state period.  Position matters below ``lag < F``:
   ``straggler-first`` (slow chunk at the head of the stream, where the
   lag window sits) overlaps, ``straggler-last`` does not — both are
   reported.

Run as a script to emit ``BENCH_async.json`` and exit nonzero on a gate
failure (the CI smoke): ``python benchmarks/async_rounds.py --fast``.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core import async_rounds
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer, chunk_geometry
from repro.data.federated import iid_split
from repro.data.synthetic import synthetic_lm

LAGS = (0, 1, 2)

CFG = ModelConfig(name="attn4", n_layers=4, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=256,
                  pattern=(LayerSpec("attn"),), exit_layer=2,
                  compute_dtype="float32")

# straggler model: one chunk this many times slower than the rest (the
# complex-architecture cohort members of a heterogeneous round)
STRAGGLER_FACTOR = 4.0
SIM_ROUNDS = 64

# gates (script exit code, enforced in CI)
GATE_PARITY_MAX_ABS_DIFF = 0.0      # bit-for-bit, not "close"
GATE_MIN_OVERLAP_SPEEDUP = 1.5      # straggler-first speedup at lag >= 1


def make_trainer(lag: int, *, rounds: int, seed: int = 0
                 ) -> FederatedTrainer:
    fed = FedConfig(n_devices=8, n_simple=4, participation=1.0,
                    rounds=rounds, local_epochs=1, lr=0.1, batch_size=8,
                    algorithm="fedhen", seed=seed, cohort_chunk=2,
                    async_lag=lag)
    data = synthetic_lm(fed.n_devices * 16, 32, CFG.vocab_size, seed=1)
    shards = [{"tokens": jnp.asarray(s["tokens"])}
              for s in iid_split(data, fed.n_devices, seed=2)]
    return FederatedTrainer(LMAdapter(CFG), fed, shards)


# ---------------------------------------------------------------------------
# 1. lag=0 parity
# ---------------------------------------------------------------------------

def lag0_parity_max_abs_diff(rounds: int) -> float:
    """Run the synchronous engine and the async engine at lag=0 side by
    side; return the max absolute server-param difference (must be 0.0)."""
    sync = make_trainer(0, rounds=rounds)
    tr = make_trainer(0, rounds=rounds)
    eng = async_rounds.AsyncRoundEngine(tr, lag=0)
    for _ in range(rounds):
        sync.run_round()
        eng.run_round()
    return max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(sync.server.complex),
                               jax.tree.leaves(tr.server.complex)))


# ---------------------------------------------------------------------------
# 2. Accuracy vs lag
# ---------------------------------------------------------------------------

def run_lag_point(lag: int, *, rounds: int) -> Dict:
    trainer = make_trainer(lag, rounds=rounds)
    test = synthetic_lm(64, 32, CFG.vocab_size, seed=999)
    test_batch = {"tokens": jnp.asarray(test["tokens"])}
    t0 = time.time()
    loss = float("nan")
    for _ in range(rounds):
        loss = trainer.run_round()["loss_complex"]
    dt = time.time() - t0
    ev = trainer.evaluate(test_batch)
    eng = trainer.async_engine
    # the real fold-stream length, also for the lag=0 (sync-engine) row —
    # all rows must simulate the same stream or their speedups are not
    # comparable
    folds = (eng.folds_per_round if eng else
             chunk_geometry(trainer.k_simple, trainer.cohort_chunk)[1]
             + chunk_geometry(trainer.k_complex, trainer.cohort_chunk)[1])
    return {
        "label": f"lag{lag}",
        "lag": lag,
        "rounds": rounds,
        "folds_per_round": folds,
        "n_versions": (eng.n_versions if eng else 1),
        "loss_complex": loss,
        "acc_simple": ev["acc_simple"],
        "acc_complex": ev["acc_complex"],
        "mbytes_down": ev["mbytes_down"],
        "mbytes_up": ev["mbytes_up"],
        "us_per_round": dt / rounds * 1e6,
    }


# ---------------------------------------------------------------------------
# 3. Straggler round-clock simulation
# ---------------------------------------------------------------------------

def simulate_round_period(chunk_times: List[float], lag: int,
                          rounds: int = SIM_ROUNDS) -> float:
    """Steady-state round period of the fold stream under bounded lag.

    ``close(r) = max_t(close(r - 1 - s_t) + time_t)`` with ``s_t`` from
    the engine's real ``fold_schedule`` (and closes kept monotone: the
    server folds the stream in order).  Returns the mean period over the
    second half (transients discarded).
    """
    n_folds = len(chunk_times)
    close: List[float] = []

    def closed_at(r: int) -> float:
        return 0.0 if r < 0 else close[r]

    for r in range(rounds):
        s = async_rounds.fold_schedule(n_folds, lag, r)
        t_close = max(closed_at(r - 1 - int(s[i])) + chunk_times[i]
                      for i in range(n_folds))
        close.append(max(t_close, closed_at(r - 1)))
    half = rounds // 2
    return (close[rounds - 1] - close[half - 1]) / (rounds - half)


def straggler_speedups(lag: int, n_folds: int) -> Dict[str, float]:
    """Round-clock speedup vs the synchronous engine with ONE straggler
    chunk, placed first vs last in the fold stream."""
    fast, slow = 1.0, STRAGGLER_FACTOR
    first = [slow] + [fast] * (n_folds - 1)
    last = [fast] * (n_folds - 1) + [slow]
    out = {}
    for name, times in (("straggler_first", first),
                        ("straggler_last", last)):
        sync_p = simulate_round_period(times, 0)
        async_p = simulate_round_period(times, lag)
        out[f"speedup_{name}"] = sync_p / async_p
    return out


# ---------------------------------------------------------------------------
# Driver + gates
# ---------------------------------------------------------------------------

def check_gates(payload: Dict) -> List[str]:
    failures = []
    parity = payload["lag0_parity_max_abs_diff"]
    if parity > GATE_PARITY_MAX_ABS_DIFF:
        failures.append(f"lag=0 parity broken: async engine diverges from "
                        f"the synchronous engine by {parity:g} (must be "
                        f"bit-for-bit)")
    for r in payload["rows"]:
        if not np.isfinite(r["loss_complex"]):
            failures.append(f"{r['label']}: non-finite end loss")
        if r["lag"] >= 1 and \
                r["speedup_straggler_first"] < GATE_MIN_OVERLAP_SPEEDUP:
            failures.append(
                f"{r['label']}: straggler-first round-clock speedup "
                f"{r['speedup_straggler_first']:.2f} < "
                f"{GATE_MIN_OVERLAP_SPEEDUP}")
        if r["speedup_straggler_last"] < 1.0 - 1e-9:
            failures.append(f"{r['label']}: straggler-last speedup "
                            f"{r['speedup_straggler_last']:.2f} < 1 "
                            f"(async made the round clock WORSE)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="4 rounds per lag point (CI smoke)")
    ap.add_argument("--out", default="BENCH_async.json")
    args = ap.parse_args(argv)

    rounds = 4 if args.fast else 12
    parity = lag0_parity_max_abs_diff(min(rounds, 3))
    rows = []
    for lag in LAGS:
        row = run_lag_point(lag, rounds=rounds)
        row.update(straggler_speedups(lag,
                                      n_folds=row["folds_per_round"]))
        rows.append(row)
    base = rows[0]
    for row in rows:
        row["loss_delta_vs_lag0"] = row["loss_complex"] - base["loss_complex"]
        row["acc_simple_delta_vs_lag0"] = (row["acc_simple"]
                                           - base["acc_simple"])

    payload = {
        "bench": "async_rounds",
        "backend": jax.default_backend(),
        "straggler_factor": STRAGGLER_FACTOR,
        "gate_parity_max_abs_diff": GATE_PARITY_MAX_ABS_DIFF,
        "gate_min_overlap_speedup": GATE_MIN_OVERLAP_SPEEDUP,
        "lag0_parity_max_abs_diff": parity,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)

    print(f"lag=0 parity max |diff|: {parity:g} (gate: == 0)")
    for r in rows:
        print(f"{r['label']:>5}: loss {r['loss_complex']:.4f} "
              f"(d={r['loss_delta_vs_lag0']:+.4f}), "
              f"acc_simple {r['acc_simple']:.4f}, "
              f"down {r['mbytes_down']:.3f} MB, "
              f"speedup first/last "
              f"{r['speedup_straggler_first']:.2f}x/"
              f"{r['speedup_straggler_last']:.2f}x")

    failures = check_gates(payload)
    if failures:
        print(f"REGRESSION: {failures} (see {args.out})")
        return 1
    print(f"ok — wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
