"""The paper's own setting: PreActResNet18 (GroupNorm) complex model,
first-2-stages + mix-pool simple model, federated over heterogeneous
clients on CIFAR-shaped data (non-IID Dirichlet split).

This is the full 11.1M/0.7M model pair — a handful of rounds takes a few
minutes on CPU.  For the paper protocol (100 clients, 1000 rounds) run
``launch/train.py --model resnet`` on real hardware.

Run:  PYTHONPATH=src python examples/federated_cifar.py [rounds]
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    rounds = sys.argv[1] if len(sys.argv) > 1 else "3"
    # current driver surface (see launch/train.py --help): the flat-buffer
    # fold streamed in chunks of 2 clients, the f32 (paper-accounting)
    # wire, synchronous rounds.  Swap "--comm-dtype" to int8 for the
    # quantized wire, or add "--async-lag 1" for bounded-lag async rounds.
    main(["--model", "resnet", "--algorithm", "fedhen",
          "--rounds", rounds, "--clients", "8", "--participation", "0.25",
          "--local-epochs", "1", "--batch-size", "32",
          "--data-points", "1024", "--non-iid", "--eval-every", "1",
          "--cohort-chunk", "2", "--agg-engine", "flat",
          "--comm-dtype", "float32", "--async-lag", "0"])
