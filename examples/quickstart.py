"""Quickstart: FedHeN vs NoSide vs Decouple on a tiny federated LM.

Reproduces the paper's qualitative result in ~2 minutes on CPU: with the
side objective (FedHeN), the *simple* server model reaches a target
accuracy in fewer communication rounds than either baseline, because it
trains on complex devices' data too (Eq. 2).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer, rounds_to_target
from repro.data.federated import iid_split
from repro.data.synthetic import synthetic_lm

CFG = ModelConfig(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab_size=256, pattern=(LayerSpec("attn"),), exit_layer=2,
                  compute_dtype="float32")
ROUNDS = 36
TARGET = 0.15   # held-out token accuracy (chain optimum ~0.75)

# Current engine surface (docs/ARCHITECTURE.md maps every knob): stream
# the cohort in chunks of 2 clients through the flat-buffer fold, over
# the paper-accounting f32 wire, fully synchronous rounds.  Try
# comm_dtype="int8" for ~3.9x smaller payloads, or async_lag=1 to let
# the first chunk overlap the previous round's server fold.
ENGINE = dict(cohort_chunk=2, agg_engine="flat", comm_dtype="float32",
              async_lag=0)


def run(algorithm: str):
    fed = FedConfig(n_devices=20, n_simple=10, participation=0.2,
                    rounds=ROUNDS, local_epochs=1, lr=0.1, batch_size=8,
                    algorithm=algorithm, seed=0, **ENGINE)
    data = synthetic_lm(400, 32, CFG.vocab_size, seed=1)
    shards = [
        {"tokens": jnp.asarray(s["tokens"])}
        for s in iid_split(data, fed.n_devices, seed=2)]
    test = {"tokens": jnp.asarray(
        synthetic_lm(64, 32, CFG.vocab_size, seed=99)["tokens"])}
    trainer = FederatedTrainer(LMAdapter(CFG), fed, shards)
    history = trainer.run(ROUNDS, eval_every=2, test_batch=test)
    r = rounds_to_target(history, "acc_simple", TARGET)
    final = [h for h in history if "acc_simple" in h][-1]
    return {"algorithm": algorithm, "rounds_to_target": r,
            "final_acc_simple": final["acc_simple"],
            "final_acc_complex": final["acc_complex"],
            "mbytes": trainer.total_bytes / 1e6}


def main():
    print(f"target: simple-model accuracy >= {TARGET} "
          f"(rounds to target, lower is better)\n")
    results = [run(a) for a in ("fedhen", "noside", "decouple")]
    hdr = f"{'algorithm':10s} {'rounds->tgt':>11s} {'simple':>8s} " \
          f"{'complex':>8s} {'comm MB':>9s}"
    print(hdr)
    print("-" * len(hdr))
    for r in results:
        rt = r["rounds_to_target"]
        print(f"{r['algorithm']:10s} {rt if rt > 0 else '>'+str(ROUNDS):>11} "
              f"{r['final_acc_simple']:8.3f} {r['final_acc_complex']:8.3f} "
              f"{r['mbytes']:9.1f}")
    best_baseline = min(
        (r["rounds_to_target"] for r in results[1:]
         if r["rounds_to_target"] > 0), default=-1)
    fh = results[0]["rounds_to_target"]
    if fh > 0 and best_baseline > 0:
        print(f"\nFedHeN communication gain vs best baseline: "
              f"{best_baseline / fh:.2f}x  (paper reports 1.1-3.3x)")


if __name__ == "__main__":
    main()
