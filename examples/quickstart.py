"""Quickstart: FedHeN vs NoSide vs Decouple on a tiny federated LM.

Reproduces the paper's qualitative result in ~2 minutes on CPU: with the
side objective (FedHeN), the *simple* server model reaches a target
accuracy in fewer communication rounds than either baseline, because it
trains on complex devices' data too (Eq. 2).

Run:  PYTHONPATH=src python examples/quickstart.py

Add ``--telemetry --telemetry-out run.jsonl`` to record the structured
event stream (round-phase spans, client-health counters, byte ledgers)
and render it with ``python tools/obs_report.py run.jsonl``.
"""

import argparse

import jax.numpy as jnp

from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer, rounds_to_target
from repro.data.federated import iid_split
from repro.data.synthetic import synthetic_lm
from repro.obs import telemetry as obslib

CFG = ModelConfig(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab_size=256, pattern=(LayerSpec("attn"),), exit_layer=2,
                  compute_dtype="float32")
ROUNDS = 36
TARGET = 0.15   # held-out token accuracy (chain optimum ~0.75)

# Current engine surface (docs/ARCHITECTURE.md maps every knob): stream
# the cohort in chunks of 2 clients through the flat-buffer fold, over
# the paper-accounting f32 wire, fully synchronous rounds.  Try
# comm_dtype="int8" for ~3.9x smaller payloads, or async_lag=1 to let
# the first chunk overlap the previous round's server fold.
ENGINE = dict(cohort_chunk=2, agg_engine="flat", comm_dtype="float32",
              async_lag=0)


def run(algorithm: str, rounds: int = ROUNDS, telemetry=None):
    fed = FedConfig(n_devices=20, n_simple=10, participation=0.2,
                    rounds=rounds, local_epochs=1, lr=0.1, batch_size=8,
                    algorithm=algorithm, seed=0, **ENGINE)
    data = synthetic_lm(400, 32, CFG.vocab_size, seed=1)
    shards = [
        {"tokens": jnp.asarray(s["tokens"])}
        for s in iid_split(data, fed.n_devices, seed=2)]
    test = {"tokens": jnp.asarray(
        synthetic_lm(64, 32, CFG.vocab_size, seed=99)["tokens"])}
    trainer = FederatedTrainer(LMAdapter(CFG), fed, shards,
                               telemetry=telemetry)
    history = trainer.run(rounds, eval_every=2, test_batch=test)
    r = rounds_to_target(history, "acc_simple", TARGET)
    final = [h for h in history if "acc_simple" in h][-1]
    return {"algorithm": algorithm, "rounds_to_target": r,
            "final_acc_simple": final["acc_simple"],
            "final_acc_complex": final["acc_complex"],
            "mbytes": trainer.total_bytes / 1e6}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--telemetry", action="store_true",
                    help="instrument the fedhen run with the repro/obs "
                         "telemetry layer")
    ap.add_argument("--telemetry-out", default="",
                    help="write the fedhen run's event stream as JSONL "
                         "here (implies --telemetry; render with "
                         "tools/obs_report.py)")
    args = ap.parse_args(argv)
    tel = None
    if args.telemetry or args.telemetry_out:
        sinks = ([obslib.JsonlSink(args.telemetry_out)]
                 if args.telemetry_out else [])
        tel = obslib.Telemetry(sinks)

    print(f"target: simple-model accuracy >= {TARGET} "
          f"(rounds to target, lower is better)\n")
    # one event stream per run: only the fedhen leg is instrumented, so
    # the JSONL log stays reconcilable against one trainer's accounting
    results = [run(a, rounds=args.rounds,
                   telemetry=tel if a == "fedhen" else None)
               for a in ("fedhen", "noside", "decouple")]
    if tel is not None:
        tel.close()
    hdr = f"{'algorithm':10s} {'rounds->tgt':>11s} {'simple':>8s} " \
          f"{'complex':>8s} {'comm MB':>9s}"
    print(hdr)
    print("-" * len(hdr))
    for r in results:
        rt = r["rounds_to_target"]
        print(f"{r['algorithm']:10s} "
              f"{rt if rt > 0 else '>'+str(args.rounds):>11} "
              f"{r['final_acc_simple']:8.3f} {r['final_acc_complex']:8.3f} "
              f"{r['mbytes']:9.1f}")
    best_baseline = min(
        (r["rounds_to_target"] for r in results[1:]
         if r["rounds_to_target"] > 0), default=-1)
    fh = results[0]["rounds_to_target"]
    if fh > 0 and best_baseline > 0:
        print(f"\nFedHeN communication gain vs best baseline: "
              f"{best_baseline / fh:.2f}x  (paper reports 1.1-3.3x)")


if __name__ == "__main__":
    main()
