"""Serve a small model with batched requests + FedHeN early-exit decoding.

The side objective trains the exit head jointly with the full model, so
one checkpoint serves two quality/latency operating points; the adaptive
mode exits early whenever the exit head is confident (Kaya et al. 2019).

Run:  PYTHONPATH=src python examples/serve_early_exit.py
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "gemma2-2b", "--reduced", "--batch", "8",
          "--prompt-len", "32", "--gen", "24",
          "--adaptive-threshold", "0.5"])
