"""Lower + compile one (arch x shape) combination on the production mesh
and print its roofline terms — the programmatic dry-run API.

Run:  python examples/multipod_dryrun.py [arch] [shape] [single|multi]
(note: sets XLA_FLAGS itself; run as a fresh process, not under pytest)
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import INPUT_SHAPES          # noqa: E402
from repro.launch.dryrun import lower_one            # noqa: E402


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "gemma2-2b"
    shape = INPUT_SHAPES[sys.argv[2] if len(sys.argv) > 2 else "decode_32k"]
    multi = (len(sys.argv) > 3 and sys.argv[3] == "multi")
    rec = lower_one(arch, shape, multi_pod=multi)
    print("\nroofline terms (seconds/step):")
    for k in ("t_compute", "t_memory", "t_collective"):
        print(f"  {k:13s} {rec[k]:.4f}")
    print(f"  bottleneck    {rec['bottleneck']}")
    print(f"  useful-FLOPs  {rec['useful_flops_ratio']:.2%}")


if __name__ == "__main__":
    main()
