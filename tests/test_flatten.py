"""Flat-buffer packing layout (core/flatten.py): offsets, pack/unpack,
mask lowering, and the memory-budget chunk heuristic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flatten


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(3, 5)).astype(np.float32)),
            "b": (jnp.asarray(rng.normal(size=(7,)).astype(np.float32)),
                  jnp.asarray(rng.normal(size=(2, 2, 2)).astype(np.float32))),
            "c": jnp.asarray(rng.normal(size=(1,)).astype(np.float32))}


def test_layout_offsets_are_aligned_and_disjoint():
    layout = flatten.build_layout(_tree(), align=128, total_multiple=512)
    offset = 0
    for slot in layout.slots:
        assert slot.offset == offset
        assert slot.offset % 128 == 0
        assert slot.padded % 128 == 0
        assert slot.padded >= slot.size == int(np.prod(slot.shape))
        offset += slot.padded
    assert layout.n_flat % 512 == 0
    assert layout.n_flat >= offset
    assert layout.n_params == 15 + 7 + 8 + 1


def test_layout_is_static_per_treedef():
    """The flat contract: offsets are a pure function of (treedef, shapes,
    align, total_multiple) — two builds agree, and the cache returns one
    object."""
    a = flatten.build_layout(_tree(0), total_multiple=256)
    b = flatten.build_layout(_tree(1), total_multiple=256)
    assert a.slots == b.slots and a.n_flat == b.n_flat
    assert flatten.layout_of(_tree(2), total_multiple=256) is \
        flatten.layout_of(_tree(3), total_multiple=256)


def test_pack_unpack_roundtrip_exact():
    tree = _tree()
    layout = flatten.build_layout(tree, total_multiple=2048)
    flat = flatten.pack(layout, tree)
    assert flat.shape == (layout.n_flat,) and flat.dtype == jnp.float32
    back = flatten.unpack(layout, flat)
    for got, want in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pack_stacked_padding_is_zero():
    tree = _tree()
    stacked = jax.tree.map(
        lambda x: jnp.stack([x, 2 * x, -x]), tree)
    layout = flatten.build_layout(tree, total_multiple=256)
    buf = flatten.pack_stacked(layout, stacked)
    assert buf.shape == (3, layout.n_flat)
    # every element outside a slot's true extent is exactly zero
    live = np.zeros(layout.n_flat, bool)
    for slot in layout.slots:
        live[slot.offset:slot.offset + slot.size] = True
    np.testing.assert_array_equal(np.asarray(buf)[:, ~live], 0.0)
    # and each row round-trips to the matching cohort member
    for z in range(3):
        back = flatten.unpack(layout, buf[z])
        for got, want in zip(jax.tree.leaves(back),
                             jax.tree.leaves(stacked)):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want[z]))


def test_pack_mask_matches_broadcast_leaves():
    tree = _tree()
    mask = {"a": jnp.asarray(True),
            "b": (jnp.asarray(False),
                  jnp.asarray([True, False])[:, None, None]),
            "c": jnp.asarray(False)}
    layout = flatten.build_layout(tree, total_multiple=256)
    flat_mask = np.asarray(flatten.pack_mask(layout, mask))
    assert flat_mask.shape == (layout.n_flat,)
    for leaf, mleaf, slot in zip(
            jax.tree.leaves(tree), jax.tree.leaves(mask), layout.slots):
        want = np.broadcast_to(np.asarray(mleaf), leaf.shape).reshape(-1)
        np.testing.assert_array_equal(
            flat_mask[slot.offset:slot.offset + slot.size], want)
        # alignment padding is never inside M
        assert not flat_mask[slot.offset + slot.size:
                             slot.offset + slot.padded].any()


def test_stacked_layout_strips_cohort_axis():
    tree = _tree()
    stacked = jax.tree.map(lambda x: jnp.stack([x, x]), tree)
    a = flatten.layout_of(tree, total_multiple=256)
    b = flatten.layout_of(stacked, total_multiple=256, stacked=True)
    assert a is b


def test_bf16_pack_keeps_f32_shapes():
    tree = _tree()
    layout = flatten.build_layout(tree, total_multiple=256)
    flat = flatten.pack(layout, tree, dtype=jnp.bfloat16)
    assert flat.dtype == jnp.bfloat16
    back = flatten.unpack(layout, flat)
    for got, want in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        assert got.dtype == want.dtype  # cast back to the layout dtypes
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-2, atol=1e-2)


def test_stream_bytes_accounts_quantized_sidecar():
    """Under an int8 wire the stream buffer carries an f32 scale per
    quant_block elements — auto chunking must budget payload + sidecar."""
    layout = flatten.build_layout(_tree(), total_multiple=2048)
    assert layout.stream_bytes(jnp.float32) == layout.n_flat * 4
    assert layout.stream_bytes(jnp.int8) == layout.n_flat          # no qb
    assert layout.stream_bytes(jnp.int8, quant_block=128) == \
        layout.n_flat + layout.n_flat // 128 * 4
    # the sidecar kwarg is ignored for non-quantized dtypes
    assert layout.stream_bytes(jnp.bfloat16, quant_block=128) == \
        layout.n_flat * 2
    # sidecar flows into the auto-chunk footprint: int8 still beats f32
    per_f32 = flatten.auto_cohort_chunk(layout, budget_bytes=1e7, k=1000)
    per_int8 = flatten.auto_cohort_chunk(layout, budget_bytes=1e7, k=1000,
                                         stream_dtype=jnp.int8,
                                         quant_block=128)
    assert per_int8 >= per_f32


def test_auto_cohort_chunk_clamps_to_budget():
    layout = flatten.build_layout(_tree(), total_multiple=2048)
    per_client = layout.stream_bytes() * flatten.CLIENT_FOOTPRINT_MULTIPLIER
    # tiny budget -> floor of 1; exactly 3 clients' worth -> 3; huge -> k
    assert flatten.auto_cohort_chunk(layout, budget_bytes=1.0, k=10) == 1
    assert flatten.auto_cohort_chunk(layout, budget_bytes=3 * per_client,
                                     k=10) == 3
    assert flatten.auto_cohort_chunk(layout, budget_bytes=1e15, k=10) == 10
