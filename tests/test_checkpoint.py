"""Checkpoint roundtrips, including bf16 leaves and federated state."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import (restore_server, restore_tree,
                                         save_server, save_tree)
from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer
from repro.data.federated import iid_split
from repro.data.synthetic import synthetic_lm


def test_tree_roundtrip_with_bf16(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16) * 1.5,
                  "d": jnp.arange(7, dtype=jnp.int32)},
            "list": [jnp.zeros((2, 2)), jnp.ones((1,))]}
    path = str(tmp_path / "ckpt.npz")
    save_tree(path, tree, {"round": 7})
    restored, meta = restore_tree(path, tree)
    assert meta["round"] == 7
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_shape_mismatch_rejected(tmp_path):
    tree = {"a": jnp.ones((3,))}
    path = str(tmp_path / "c.npz")
    save_tree(path, tree)
    try:
        restore_tree(path, {"a": jnp.ones((4,))})
        raise AssertionError("should have raised")
    except ValueError:
        pass


def test_federated_resume(tmp_path):
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab_size=64, pattern=(LayerSpec("attn"),),
                      exit_layer=1, compute_dtype="float32")
    fed = FedConfig(n_devices=4, n_simple=2, participation=0.5, rounds=3,
                    local_epochs=1, batch_size=4, algorithm="fedhen")
    data = synthetic_lm(32, 16, 64, seed=1)
    shards = [{"tokens": jnp.asarray(s["tokens"])}
              for s in iid_split(data, 4, seed=2)]
    tr = FederatedTrainer(LMAdapter(cfg), fed, shards)
    tr.run_round()
    tr.run_round()
    path = str(tmp_path / "server.npz")
    save_server(path, tr.server)

    tr2 = FederatedTrainer(LMAdapter(cfg), fed, shards)
    tr2.server = restore_server(path, tr2.server)
    assert tr2.server.round == 2
    for a, b in zip(jax.tree.leaves(tr2.server.complex),
                    jax.tree.leaves(tr.server.complex)):
        np.testing.assert_array_equal(a, b)
    # resumed trainer keeps training
    m = tr2.run_round()
    assert np.isfinite(m["loss_complex"])
