"""Checkpoint roundtrips, including bf16 leaves and federated state."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import (restore_server, restore_tree,
                                         save_server, save_tree)
from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer
from repro.data.federated import iid_split
from repro.data.synthetic import synthetic_lm


def test_tree_roundtrip_with_bf16(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16) * 1.5,
                  "d": jnp.arange(7, dtype=jnp.int32)},
            "list": [jnp.zeros((2, 2)), jnp.ones((1,))]}
    path = str(tmp_path / "ckpt.npz")
    save_tree(path, tree, {"round": 7})
    restored, meta = restore_tree(path, tree)
    assert meta["round"] == 7
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_shape_mismatch_rejected(tmp_path):
    tree = {"a": jnp.ones((3,))}
    path = str(tmp_path / "c.npz")
    save_tree(path, tree)
    try:
        restore_tree(path, {"a": jnp.ones((4,))})
        raise AssertionError("should have raised")
    except ValueError:
        pass


def test_federated_resume(tmp_path):
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab_size=64, pattern=(LayerSpec("attn"),),
                      exit_layer=1, compute_dtype="float32")
    fed = FedConfig(n_devices=4, n_simple=2, participation=0.5, rounds=3,
                    local_epochs=1, batch_size=4, algorithm="fedhen")
    data = synthetic_lm(32, 16, 64, seed=1)
    shards = [{"tokens": jnp.asarray(s["tokens"])}
              for s in iid_split(data, 4, seed=2)]
    tr = FederatedTrainer(LMAdapter(cfg), fed, shards)
    tr.run_round()
    tr.run_round()
    path = str(tmp_path / "server.npz")
    save_server(path, tr.server)

    tr2 = FederatedTrainer(LMAdapter(cfg), fed, shards)
    tr2.server = restore_server(path, tr2.server)
    assert tr2.server.round == 2
    for a, b in zip(jax.tree.leaves(tr2.server.complex),
                    jax.tree.leaves(tr.server.complex)):
        np.testing.assert_array_equal(a, b)
    # resumed trainer keeps training
    m = tr2.run_round()
    assert np.isfinite(m["loss_complex"])


# ---------------------------------------------------------------------------
# Flat-buffer checkpoints (wire-encoded packed vectors)
# ---------------------------------------------------------------------------

def _tiny_trainer(algorithm="fedhen"):
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab_size=64, pattern=(LayerSpec("attn"),),
                      exit_layer=1, compute_dtype="float32")
    fed = FedConfig(n_devices=4, n_simple=2, participation=0.5, rounds=3,
                    local_epochs=1, batch_size=4, algorithm=algorithm)
    data = synthetic_lm(32, 16, 64, seed=1)
    shards = [{"tokens": jnp.asarray(s["tokens"])}
              for s in iid_split(data, 4, seed=2)]
    return FederatedTrainer(LMAdapter(cfg), fed, shards), cfg, fed, shards


def test_flat_checkpoint_f32_roundtrip_exact(tmp_path):
    from repro.checkpoint.checkpoint import (restore_server_flat,
                                             save_server_flat)
    tr, cfg, fed, shards = _tiny_trainer()
    tr.run_round()
    path = str(tmp_path / "flat.npz")
    save_server_flat(path, tr.server, tr.layout)     # default f32 wire
    tr2, *_ = _tiny_trainer()
    tr2.server = restore_server_flat(path, tr2.server, tr2.layout)
    assert tr2.server.round == 1
    for a, b in zip(jax.tree.leaves(tr2.server.complex),
                    jax.tree.leaves(tr.server.complex)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    m = tr2.run_round()                              # resumes training
    assert np.isfinite(m["loss_complex"])


def test_flat_checkpoint_decouple_carries_host(tmp_path):
    from repro.checkpoint.checkpoint import (restore_server_flat,
                                             save_server_flat)
    tr, *_ = _tiny_trainer("decouple")
    tr.run_round()
    path = str(tmp_path / "flat.npz")
    save_server_flat(path, tr.server, tr.layout)
    tr2, *_ = _tiny_trainer("decouple")
    tr2.server = restore_server_flat(path, tr2.server, tr2.layout)
    for a, b in zip(jax.tree.leaves(tr2.server.simple_host),
                    jax.tree.leaves(tr.server.simple_host)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flat_checkpoint_wire_dtypes_lossy_but_bounded(tmp_path):
    from repro.checkpoint.checkpoint import (restore_server_flat,
                                             save_server_flat)
    from repro.core import comm
    import os
    tr, *_ = _tiny_trainer()
    tr.run_round()
    sizes = {}
    for dtype in ("float32", "bfloat16", "int8"):
        path = str(tmp_path / f"flat_{dtype}.npz")
        save_server_flat(path, tr.server, tr.layout,
                         wire=comm.WireSpec(dtype, 128))
        sizes[dtype] = os.path.getsize(path)
        tr2, *_ = _tiny_trainer()
        tr2.server = restore_server_flat(path, tr2.server, tr2.layout)
        for a, b in zip(jax.tree.leaves(tr2.server.complex),
                        jax.tree.leaves(tr.server.complex)):
            amax = float(jnp.max(jnp.abs(b))) + 1e-12
            err = float(jnp.max(jnp.abs(a - b)))
            tol = {"float32": 0.0, "bfloat16": amax * 8e-3,
                   "int8": amax / 127.0}[dtype]
            assert err <= tol, (dtype, err, tol)
    assert sizes["int8"] < sizes["bfloat16"] < sizes["float32"]


def test_flat_checkpoint_layout_mismatch_rejected(tmp_path):
    """Both mismatch layers: a different n_flat, AND — the dangerous case
    — a different slot table that collides on n_flat (rounded up to
    total_multiple), which must be caught by the layout fingerprint
    instead of silently unpacking scrambled parameters."""
    from repro.checkpoint.checkpoint import (restore_server_flat,
                                             save_server_flat)
    from repro.core import flatten
    tr, *_ = _tiny_trainer()
    path = str(tmp_path / "flat.npz")
    save_server_flat(path, tr.server, tr.layout)
    bigger = flatten.build_layout(tr.server.complex,
                                  total_multiple=2 * tr.layout.n_flat)
    assert bigger.n_flat != tr.layout.n_flat
    with np.testing.assert_raises(ValueError):
        restore_server_flat(path, tr.server, bigger)
    # same n_flat, different packing: a toy tree rounded up to the same
    # total collides on length but not on the slot fingerprint
    collider = flatten.build_layout({"x": jnp.zeros((7,))},
                                    total_multiple=tr.layout.n_flat)
    assert collider.n_flat == tr.layout.n_flat
    assert collider.signature != tr.layout.signature
    with np.testing.assert_raises(ValueError):
        restore_server_flat(path, tr.server, collider)


def test_checkpoints_save_at_verbatim_path(tmp_path):
    """np.savez appends '.npz' to bare filenames, which would break the
    resume guard (saver writes x.npz, restore stats x): both savers must
    write the exact path they were given."""
    from repro.checkpoint.checkpoint import (restore_server_flat,
                                             save_server_flat)
    tr, *_ = _tiny_trainer()
    bare = str(tmp_path / "server.ckpt")         # no .npz suffix
    save_server(bare, tr.server)
    assert os.path.exists(bare)
    restored = restore_server(bare, tr.server)
    assert restored.round == tr.server.round
    bare_flat = str(tmp_path / "server_flat.ckpt")
    save_server_flat(bare_flat, tr.server, tr.layout)
    assert os.path.exists(bare_flat)
    restored = restore_server_flat(bare_flat, tr.server, tr.layout)
    assert restored.round == tr.server.round


# ---------------------------------------------------------------------------
# Trainer checkpoints (sampler purity + client-state matrix)
# ---------------------------------------------------------------------------

def test_trainer_resume_equals_uninterrupted(tmp_path):
    """The resume bugfix, end to end: interrupting a run at round 2 and
    restoring into a FRESH process must reproduce the uninterrupted run's
    rounds 3..4 exactly — same cohort ids, same metrics, same server
    params bit-for-bit.  (The old sequential host RNG replayed round 0's
    cohort sequence after restore, silently changing which clients
    trained.)"""
    from repro.checkpoint.checkpoint import restore_trainer, save_trainer

    tr_a, *_ = _tiny_trainer()
    hist_a = [tr_a.run_round() for _ in range(4)]
    plans_a = [tr_a.sampler.plan(r) for r in range(4)]

    tr_b, *_ = _tiny_trainer()
    hist_b = [tr_b.run_round() for _ in range(2)]
    path = str(tmp_path / "trainer.npz")
    save_trainer(path, tr_b)

    tr_c, *_ = _tiny_trainer()
    restore_trainer(path, tr_c)
    assert tr_c.server.round == 2
    # the restored sampler continues A's cohort sequence, not round 0's
    for r in (2, 3):
        p_c, p_a = tr_c.sampler.plan(r), plans_a[r]
        np.testing.assert_array_equal(p_c.simple_ids, p_a.simple_ids)
        np.testing.assert_array_equal(p_c.complex_ids, p_a.complex_ids)
    hist_c = [tr_c.run_round() for _ in range(2)]
    for m_a, m_c in zip(hist_a[2:], hist_c):
        assert m_a == m_c, (m_a, m_c)
    for a, c in zip(jax.tree.leaves(tr_a.server.complex),
                    jax.tree.leaves(tr_c.server.complex)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    # participation counters resumed: 4 recorded rounds total, same as A
    np.testing.assert_array_equal(
        tr_c.client_state.column("participation"),
        tr_a.client_state.column("participation"))


def test_trainer_checkpoint_flat_format(tmp_path):
    from repro.checkpoint.checkpoint import restore_trainer, save_trainer
    tr, *_ = _tiny_trainer()
    tr.run_round()
    path = str(tmp_path / "trainer_flat.npz")
    save_trainer(path, tr, fmt="flat")
    tr2, *_ = _tiny_trainer()
    restore_trainer(path, tr2, fmt="flat")
    assert tr2.server.round == 1
    for a, b in zip(jax.tree.leaves(tr2.server.complex),
                    jax.tree.leaves(tr.server.complex)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(tr2.client_state.array,
                                  tr.client_state.array)


def test_trainer_checkpoint_rejects_sampler_mismatch(tmp_path):
    """A checkpoint written under one sampling config must not silently
    resume under another (different seed/mode = different cohort
    sequence mid-run)."""
    import dataclasses
    from repro.checkpoint.checkpoint import restore_trainer, save_trainer
    tr, cfg, fed, shards = _tiny_trainer()
    path = str(tmp_path / "trainer.npz")
    save_trainer(path, tr)
    fed2 = dataclasses.replace(fed, seed=fed.seed + 1)
    tr2 = FederatedTrainer(LMAdapter(cfg), fed2, shards)
    with np.testing.assert_raises(ValueError):
        restore_trainer(path, tr2)


def test_restore_trainer_accepts_legacy_server_checkpoint(tmp_path):
    """Pre-trainer checkpoints (plain save_server) restore fine: no
    sampler meta to validate, no client-state sidecar to load."""
    from repro.checkpoint.checkpoint import restore_trainer
    tr, *_ = _tiny_trainer()
    tr.run_round()
    path = str(tmp_path / "legacy.npz")
    save_server(path, tr.server)
    tr2, *_ = _tiny_trainer()
    restore_trainer(path, tr2)
    assert tr2.server.round == 1
    assert tr2.client_state.tracked_clients() == 0  # fresh matrix kept
