"""Server aggregation unit tests against numpy oracles (Alg. 1 ln. 16-22),
one-shot AND streaming paths (flat + tree engines).  Referenced by the
``fedhen_server_update`` docstring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregate, flatten, masking


def _random_case(seed, z=9):
    rng = np.random.default_rng(seed)
    cohort = {"a": jnp.asarray(rng.normal(size=(z, 4, 3)).astype(np.float32)),
              "b": jnp.asarray(rng.normal(size=(z, 5)).astype(np.float32))}
    mask = {"a": jnp.asarray(True), "b": jnp.asarray(False)}
    is_simple = jnp.asarray(np.arange(z) < z // 2)
    valid = jnp.ones(z, bool)
    return cohort, mask, is_simple, valid


def _np_group_mean(x, sel):
    sel = np.asarray(sel)
    if not sel.any():
        return np.zeros(x.shape[1:], x.dtype)
    return np.asarray(x)[sel].mean(0)


# ---------------------------------------------------------------------------
# One-shot path vs numpy oracle
# ---------------------------------------------------------------------------

def test_fedhen_m_slice_invariant():
    """The server simple model IS the M slice of the new complex model:
    inside M the update is the all-devices mean, outside the complex-only
    mean — exactly Alg. 1 ln. 18-22."""
    cohort, mask, is_simple, valid = _random_case(0)
    new = aggregate.fedhen_server_update(cohort, is_simple, valid, mask)
    v, s = np.asarray(valid), np.asarray(is_simple)
    np.testing.assert_allclose(  # M slice ("a"): mean over ALL valid
        new["a"], _np_group_mean(cohort["a"], v), rtol=1e-5)
    np.testing.assert_allclose(  # M' ("b"): complex-only mean
        new["b"], _np_group_mean(cohort["b"], v & ~s), rtol=1e-5)


def test_nan_device_exclusion():
    cohort, mask, is_simple, _ = _random_case(1)
    cohort["a"] = cohort["a"].at[2].set(jnp.nan)
    cohort["b"] = cohort["b"].at[7, 0].set(jnp.inf)
    valid = jax.vmap(masking.tree_isfinite)(cohort)
    assert not bool(valid[2]) and not bool(valid[7])
    new = aggregate.fedhen_server_update(cohort, is_simple, valid, mask)
    for leaf in jax.tree.leaves(new):
        assert np.isfinite(np.asarray(leaf)).all()
    v, s = np.asarray(valid), np.asarray(is_simple)
    ok = np.isfinite(np.asarray(cohort["a"])).all(axis=(1, 2))
    np.testing.assert_allclose(
        new["a"], _np_group_mean(cohort["a"], v & ok), rtol=1e-5)


def test_decouple_group_means():
    """Decouple = two independent FedAvg runs: M slice averages simple
    devices only, everything else complex devices only."""
    cohort, mask, is_simple, valid = _random_case(2)
    host, new_complex = aggregate.decouple_server_update(
        cohort, is_simple, valid, mask)
    v, s = np.asarray(valid), np.asarray(is_simple)
    np.testing.assert_allclose(
        host["a"], _np_group_mean(cohort["a"], v & s), rtol=1e-5)
    np.testing.assert_allclose(
        host["b"], _np_group_mean(cohort["b"], v & ~s), rtol=1e-5)
    for key in ("a", "b"):  # complex model: complex-only mean everywhere
        np.testing.assert_allclose(
            new_complex[key], _np_group_mean(cohort[key], v & ~s), rtol=1e-5)


# ---------------------------------------------------------------------------
# Streaming path == one-shot path
# ---------------------------------------------------------------------------

def _stream(cohort, mask, is_simple, valid, algo, chunk, **fold_kw):
    z = jax.tree.leaves(cohort)[0].shape[0]
    template = jax.tree.map(lambda x: x[0], cohort)
    state = aggregate.streaming_init(template, algo)
    for lo in range(0, z, chunk):
        sl = slice(lo, min(lo + chunk, z))
        state = aggregate.streaming_fold(
            state, jax.tree.map(lambda x: x[sl], cohort),
            is_simple[sl], valid[sl], mask, algorithm=algo, **fold_kw)
    return aggregate.streaming_finalize(state, mask, template,
                                        algorithm=algo)


@pytest.mark.parametrize("algo", ["fedhen", "noside", "decouple"])
@pytest.mark.parametrize("chunk", [1, 2, 9])
def test_streaming_matches_one_shot(algo, chunk):
    cohort, mask, is_simple, valid = _random_case(3)
    valid = valid.at[4].set(False)  # one dropped device crosses chunks
    if algo == "decouple":
        want_host, want_c = aggregate.decouple_server_update(
            cohort, is_simple, valid, mask)
    else:
        want_c = aggregate.fedhen_server_update(cohort, is_simple, valid,
                                                mask)
        want_host = None
    got_c, got_host = _stream(cohort, mask, is_simple, valid, algo, chunk)
    for g, w in zip(jax.tree.leaves(got_c), jax.tree.leaves(want_c)):
        np.testing.assert_allclose(g, w, rtol=2e-5, atol=2e-6)
    if want_host is None:
        assert got_host is None
    else:
        for g, w in zip(jax.tree.leaves(got_host),
                        jax.tree.leaves(want_host)):
            np.testing.assert_allclose(g, w, rtol=2e-5, atol=2e-6)


def test_streaming_fold_pallas_interpret():
    """The fold's kernel dispatch (interpret mode) matches the XLA path."""
    cohort, mask, is_simple, valid = _random_case(4)
    ref_c, _ = _stream(cohort, mask, is_simple, valid, "fedhen", 3)
    ker_c, _ = _stream(cohort, mask, is_simple, valid, "fedhen", 3,
                       force_pallas_interpret=True)
    for g, w in zip(jax.tree.leaves(ker_c), jax.tree.leaves(ref_c)):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-6)


def test_streaming_zero_weight_group_is_zero():
    """An empty group (no valid complex devices) yields zeros, like the
    one-shot ``_norm_weights`` guard — never NaN from 0/0."""
    cohort, mask, is_simple, _ = _random_case(5)
    valid = jnp.asarray(np.asarray(is_simple))  # only simple devices valid
    got_c, _ = _stream(cohort, mask, is_simple, valid, "fedhen", 2)
    np.testing.assert_allclose(got_c["b"], np.zeros_like(got_c["b"]))
    v = np.asarray(valid)
    np.testing.assert_allclose(got_c["a"], _np_group_mean(cohort["a"], v),
                               rtol=1e-5)


def test_streaming_rejects_unknown_algorithm():
    cohort, mask, is_simple, valid = _random_case(6)
    with pytest.raises(ValueError):
        aggregate.streaming_init(jax.tree.map(lambda x: x[0], cohort),
                                 "fedavg")
    with pytest.raises(ValueError):
        aggregate.streaming_fold(
            aggregate.streaming_init(jax.tree.map(lambda x: x[0], cohort),
                                     "fedhen"),
            cohort, is_simple, valid, mask, algorithm="fedavg")
    with pytest.raises(ValueError):
        aggregate.tree_streaming_init(jax.tree.map(lambda x: x[0], cohort),
                                      "fedavg")


# ---------------------------------------------------------------------------
# Flat engine == tree engine == one-shot oracle
# ---------------------------------------------------------------------------

def _stream_tree(cohort, mask, is_simple, valid, algo, chunk):
    """The PR 2 per-leaf streaming engine (parity reference)."""
    z = jax.tree.leaves(cohort)[0].shape[0]
    template = jax.tree.map(lambda x: x[0], cohort)
    state = aggregate.tree_streaming_init(template, algo)
    for lo in range(0, z, chunk):
        sl = slice(lo, min(lo + chunk, z))
        state = aggregate.tree_streaming_fold(
            state, jax.tree.map(lambda x: x[sl], cohort),
            is_simple[sl], valid[sl], mask, algorithm=algo)
    return aggregate.tree_streaming_finalize(state, mask, template,
                                             algorithm=algo)


def _hard_case(seed, z=9):
    """NaN device + zero-weight padding device crossing chunk boundaries."""
    cohort, mask, is_simple, valid = _random_case(seed, z)
    cohort["a"] = cohort["a"].at[3].set(jnp.nan)   # NaN device
    valid = valid.at[3].set(False)
    valid = valid.at[z - 1].set(False)             # zero-weight padding
    return cohort, mask, is_simple, valid


def _assert_tree_allclose(got, want, rtol=2e-5, atol=2e-6):
    if want is None:
        assert got is None
        return
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("algo", ["fedhen", "noside", "decouple"])
@pytest.mark.parametrize("chunk", [2, 4])
def test_flat_vs_tree_vs_oracle(algo, chunk):
    """The three paths agree — with a NaN device and a zero-weight padding
    device in the cohort (both must be invisible to every path)."""
    cohort, mask, is_simple, valid = _hard_case(7)
    if algo == "decouple":
        want_host, want_c = aggregate.decouple_server_update(
            cohort, is_simple, valid, mask)
    else:
        want_c = aggregate.fedhen_server_update(cohort, is_simple, valid,
                                                mask)
        want_host = None
    flat_c, flat_host = _stream(cohort, mask, is_simple, valid, algo, chunk)
    tree_c, tree_host = _stream_tree(cohort, mask, is_simple, valid, algo,
                                     chunk)
    for got_c, got_host in ((flat_c, flat_host), (tree_c, tree_host)):
        _assert_tree_allclose(got_c, want_c)
        _assert_tree_allclose(got_host, want_host)
    # flat vs tree directly: identical summation order per element
    _assert_tree_allclose(flat_c, tree_c, rtol=1e-6, atol=1e-7)
    _assert_tree_allclose(flat_host, tree_host, rtol=1e-6, atol=1e-7)
    for leaf in jax.tree.leaves(flat_c):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("algo", ["fedhen", "decouple"])
def test_bf16_stream_f32_accumulation(algo):
    """bf16 chunk streaming: inputs are rounded to bf16 but the running
    sums stay f32 — the result matches the f32 path at bf16 tolerance and
    beats accumulating in bf16 outright."""
    cohort, mask, is_simple, valid = _random_case(8)
    template = jax.tree.map(lambda x: x[0], cohort)
    state = aggregate.streaming_init(template, algo)
    for lo in range(0, 9, 3):
        sl = slice(lo, lo + 3)
        state = aggregate.streaming_fold(
            state, jax.tree.map(lambda x: x[sl], cohort),
            is_simple[sl], valid[sl], mask, algorithm=algo,
            stream_dtype=jnp.bfloat16)
    assert state.acc.dtype == jnp.float32
    got_c, got_host = aggregate.streaming_finalize(state, mask, template,
                                                   algorithm=algo)
    want_c, want_host = _stream(cohort, mask, is_simple, valid, algo, 3)
    _assert_tree_allclose(got_c, want_c, rtol=2e-2, atol=2e-2)
    if algo == "decouple":
        _assert_tree_allclose(got_host, want_host, rtol=2e-2, atol=2e-2)


def _count_pallas_calls(fn, *args, **kw):
    jaxpr = jax.make_jaxpr(lambda *a: fn(*a, **kw))(*args)
    return sum(1 for eqn in jaxpr.jaxpr.eqns
               if eqn.primitive.name == "pallas_call")


@pytest.mark.parametrize("algo,n_launches", [("fedhen", 1), ("noside", 1),
                                             ("decouple", 2)])
def test_flat_fold_is_one_kernel_launch(algo, n_launches):
    """The tentpole claim: ONE masked-agg launch per fold for the whole
    model (two for decouple's extra accumulator), vs one per leaf in the
    tree engine."""
    cohort, mask, is_simple, valid = _random_case(9)
    template = jax.tree.map(lambda x: x[0], cohort)
    state = aggregate.streaming_init(template, algo)
    n_flat = _count_pallas_calls(
        aggregate.streaming_fold, state, cohort, is_simple, valid, mask,
        algorithm=algo, force_pallas_interpret=True)
    assert n_flat == n_launches
    tstate = aggregate.tree_streaming_init(template, algo)
    n_tree = _count_pallas_calls(
        aggregate.tree_streaming_fold, tstate, cohort, is_simple, valid,
        mask, algorithm=algo, force_pallas_interpret=True)
    # tree engine: one launch per leaf — grows with the tree; flat doesn't
    assert n_tree == len(jax.tree.leaves(cohort))


# ---------------------------------------------------------------------------
# Float validity weights (the async engine's staleness path)
# ---------------------------------------------------------------------------

def _np_weighted_mean(x, w):
    w = np.asarray(w, np.float64)
    x = np.where((w > 0).reshape((-1,) + (1,) * (np.asarray(x).ndim - 1)),
                 np.asarray(x, np.float64), 0.0)
    tot = w.sum()
    if tot <= 0:
        return np.zeros(x.shape[1:])
    return (x * w.reshape((-1,) + (1,) * (x.ndim - 1))).sum(0) / tot


@pytest.mark.parametrize("algo", ["fedhen", "noside", "decouple"])
@pytest.mark.parametrize("engine", ["flat", "tree"])
def test_float_staleness_weights_match_oracle(algo, engine):
    """``valid`` as f32 per-client weights (validity x staleness decay):
    both streaming engines implement the weighted mean, with a NaN device
    and a zero-weight device gated out — the async engine's whole fold
    contract in one case."""
    cohort, mask, is_simple, _ = _random_case(11)
    cohort["a"] = cohort["a"].at[2].set(jnp.nan)     # NaN device
    # fractional staleness weights; device 2 (NaN) and 5 at weight 0
    w = jnp.asarray([1.0, 0.5, 0.0, 0.25, 1.0, 0.0, 0.5, 1.0, 0.25],
                    jnp.float32)
    stream = _stream if engine == "flat" else _stream_tree
    got_c, got_host = stream(cohort, mask, is_simple, w, algo, 3)
    s = np.asarray(is_simple)
    w_np = np.asarray(w)
    w_in = w_np * s if algo == "decouple" else w_np
    w_out = w_np * ~s
    if algo == "decouple":
        # new complex model: complex-group weighted mean everywhere
        want_a = _np_weighted_mean(cohort["a"], w_out)
        want_b = _np_weighted_mean(cohort["b"], w_out)
    else:
        want_a = _np_weighted_mean(cohort["a"], w_in)    # inside M
        want_b = _np_weighted_mean(cohort["b"], w_out)   # outside M
    np.testing.assert_allclose(np.asarray(got_c["a"]), want_a,
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(got_c["b"]), want_b,
                               rtol=2e-5, atol=2e-6)
    for leaf in jax.tree.leaves(got_c):
        assert np.isfinite(np.asarray(leaf)).all()
    if algo == "decouple":
        # the simple host: simple-group mean in M, complex-group outside
        np.testing.assert_allclose(
            np.asarray(got_host["a"]),
            _np_weighted_mean(cohort["a"], w_in), rtol=2e-5, atol=2e-6)
        np.testing.assert_allclose(
            np.asarray(got_host["b"]),
            _np_weighted_mean(cohort["b"], w_out), rtol=2e-5, atol=2e-6)


def test_all_one_float_weights_bit_match_bool_valid():
    """The lag=0 parity primitive: f32 all-ones weights are bit-identical
    to bool validity through the fold."""
    cohort, mask, is_simple, valid = _random_case(12)
    got_b, _ = _stream(cohort, mask, is_simple, valid, "fedhen", 3)
    got_f, _ = _stream(cohort, mask, is_simple,
                       valid.astype(jnp.float32) * 1.0, "fedhen", 3)
    for a, b in zip(jax.tree.leaves(got_b), jax.tree.leaves(got_f)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flat_fold_uses_prebuilt_layout_and_mask():
    """The trainer path: one static layout + precomputed flat bitvector
    give the same result as the self-deriving defaults."""
    cohort, mask, is_simple, valid = _random_case(10)
    template = jax.tree.map(lambda x: x[0], cohort)
    layout = flatten.layout_of(template, total_multiple=512)
    flat_mask = flatten.pack_mask(layout, mask)
    state = aggregate.streaming_init(template, "fedhen", layout=layout)
    state = aggregate.streaming_fold(
        state, cohort, is_simple, valid, mask, algorithm="fedhen",
        layout=layout, flat_mask=flat_mask, block_n=512)
    got_c, _ = aggregate.streaming_finalize(
        state, mask, template, algorithm="fedhen", layout=layout,
        flat_mask=flat_mask)
    want_c, _ = _stream(cohort, mask, is_simple, valid, "fedhen", 9)
    _assert_tree_allclose(got_c, want_c, rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# EngineSpec consolidation: the legacy loose-kwarg shims warn, the spec
# path is warning-free, and both build literally the same program
# ---------------------------------------------------------------------------

import warnings


def _spec_for(cohort, mask, algo="fedhen", engine="flat"):
    template = jax.tree.map(lambda x: x[0], cohort)
    layout = flatten.layout_of(template, total_multiple=512)
    return template, aggregate.EngineSpec(
        engine=engine, algorithm=algo, mask=mask, layout=layout,
        flat_mask=flatten.pack_mask(layout, mask), block_n=512)


def test_engine_spec_jaxpr_identity_with_legacy_kwargs():
    """The refactor is pure plumbing: the spec-driven fold traces to the
    IDENTICAL jaxpr as the deprecated loose-kwarg calls."""
    cohort, mask, is_simple, valid = _random_case(11)
    template, spec = _spec_for(cohort, mask)

    def via_spec(cohort, is_simple, valid):
        init, fold, finalize = aggregate.make_engine(spec)
        state = init(template)
        state = fold(state, cohort, is_simple, valid)
        return finalize(state, template=template)

    def via_legacy(cohort, is_simple, valid):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            state = aggregate.streaming_init(
                template, "fedhen", layout=spec.layout, block_n=512)
            state = aggregate.streaming_fold(
                state, cohort, is_simple, valid, mask, algorithm="fedhen",
                layout=spec.layout, flat_mask=spec.flat_mask, block_n=512)
            return aggregate.streaming_finalize(
                state, mask, template, algorithm="fedhen",
                layout=spec.layout, flat_mask=spec.flat_mask, block_n=512)

    a = str(jax.make_jaxpr(via_spec)(cohort, is_simple, valid))
    b = str(jax.make_jaxpr(via_legacy)(cohort, is_simple, valid))
    assert a == b


def test_legacy_entry_points_warn_and_match_spec():
    """Every legacy signature emits DeprecationWarning naming its call
    site — and still returns the spec path's exact result."""
    cohort, mask, is_simple, valid = _random_case(12)
    template, spec = _spec_for(cohort, mask)

    with pytest.warns(DeprecationWarning, match="streaming_init"):
        state = aggregate.streaming_init(template, "fedhen",
                                         layout=spec.layout, block_n=512)
    with pytest.warns(DeprecationWarning, match="streaming_fold"):
        state = aggregate.streaming_fold(
            state, cohort, is_simple, valid, mask, algorithm="fedhen",
            layout=spec.layout, flat_mask=spec.flat_mask, block_n=512)
    with pytest.warns(DeprecationWarning, match="streaming_finalize"):
        legacy_c, _ = aggregate.streaming_finalize(
            state, mask, template, algorithm="fedhen", layout=spec.layout,
            flat_mask=spec.flat_mask, block_n=512)

    init, fold, finalize = aggregate.make_engine(spec)
    spec_c, _ = finalize(fold(init(template), cohort, is_simple, valid),
                         template=template)
    _assert_tree_allclose(legacy_c, spec_c, rtol=0, atol=0)

    with pytest.warns(DeprecationWarning, match="make_engine"):
        aggregate.make_engine("flat", algorithm="fedhen", mask=mask)
    with pytest.warns(DeprecationWarning, match="engine_attrs"):
        attrs = aggregate.engine_attrs("flat", algorithm="fedhen")
    assert attrs["agg_engine"] == "flat" and attrs["agg_block_n"] == 2048

    with pytest.warns(DeprecationWarning, match="tree_streaming_init"):
        ts = aggregate.tree_streaming_init(template, "fedhen")
    with pytest.warns(DeprecationWarning, match="tree_streaming_fold"):
        ts = aggregate.tree_streaming_fold(ts, cohort, is_simple, valid,
                                           mask, algorithm="fedhen")
    with pytest.warns(DeprecationWarning, match="tree_streaming_finalize"):
        aggregate.tree_streaming_finalize(ts, mask, template,
                                          algorithm="fedhen")


def test_spec_path_emits_no_deprecation():
    """The modern path (what the trainer and launch/steps.py run) must
    never trip the shims."""
    cohort, mask, is_simple, valid = _random_case(13)
    template, spec = _spec_for(cohort, mask)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        init, fold, finalize = aggregate.make_engine(spec)
        state = init(template)
        state = fold(state, cohort, is_simple, valid)
        finalize(state, template=template)
        aggregate.engine_attrs(spec)
        tspec = spec.bind(engine="tree")
        tinit, tfold, tfin = aggregate.make_engine(tspec)
        tfin(tfold(tinit(template), cohort, is_simple, valid),
             template=template)
    ours = [w for w in caught
            if issubclass(w.category, DeprecationWarning)
            and "EngineSpec" in str(w.message)]
    assert not ours, [str(w.message) for w in ours]


def test_engine_attrs_records_the_full_spec():
    cohort, mask, _, _ = _random_case(14)
    template, spec = _spec_for(cohort, mask)
    from repro.core import comm
    spec = spec.bind(wire=comm.WireSpec("int8", 128),
                     variance_reduction="scaffold")
    attrs = aggregate.engine_attrs(spec)
    assert attrs == {
        "agg_engine": "flat", "algorithm": "fedhen", "agg_block_n": 512,
        "agg_stream_dtype": "float32", "variance_reduction": "scaffold",
        "wire_dtype": "int8", "wire_quantized": True,
        "wire_quant_block": 128, "wire_topk_frac": 1.0,
        "wire_stochastic": False, "wire_error_feedback": False,
    }


def test_engine_spec_rejects_bad_combinations():
    with pytest.raises(ValueError, match="unknown agg engine"):
        aggregate.EngineSpec(engine="sparse")
    with pytest.raises(ValueError):
        aggregate.EngineSpec(algorithm="fedavg")
    from repro.core import comm
    with pytest.raises(ValueError, match="int8 wire requires the flat"):
        aggregate.EngineSpec(engine="tree", wire=comm.WireSpec("int8", 128))
