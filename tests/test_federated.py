"""FedHeN core: masking, aggregation (Alg. 1), algorithms end-to-end."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core import aggregate, masking
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer, rounds_to_target
from repro.data.synthetic import synthetic_lm
from repro.data.federated import dirichlet_split, iid_split


TINY = ModelConfig(n_layers=4, d_model=32, n_heads=2, n_kv_heads=2,
                   d_ff=64, vocab_size=64, pattern=(LayerSpec("attn"),),
                   exit_layer=2, compute_dtype="float32")


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------

def test_mask_size_matches_analytic():
    adapter = LMAdapter(TINY)
    params = adapter.init(jax.random.PRNGKey(0))
    mask = adapter.subnet_mask(params)
    got = masking.mask_size(mask, params)
    assert got == TINY.simple_param_count(), (got, TINY.simple_param_count())


def test_extract_embed_roundtrip():
    params = LMAdapter(TINY).init(jax.random.PRNGKey(0))
    simple = masking.extract_simple(params, TINY)
    rebuilt = masking.embed_simple(simple, params, TINY)
    for a, b in zip(jax.tree.leaves(rebuilt), jax.tree.leaves(params)):
        np.testing.assert_array_equal(a, b)


def test_extracted_simple_runs_forward_simple():
    from repro.models import transformer as tfm
    params = LMAdapter(TINY).init(jax.random.PRNGKey(0))
    simple = masking.extract_simple(params, TINY)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    h_full = tfm.forward_simple(params, TINY, tokens)
    h_sub = tfm.forward_simple(simple, TINY, tokens)
    np.testing.assert_allclose(h_full, h_sub, rtol=1e-6)


def test_simple_loss_grad_zero_outside_mask():
    """f([w_c]_M)'s gradient must vanish on M' (the paper's simple-client
    update touches only shared weights)."""
    adapter = LMAdapter(TINY)
    params = adapter.init(jax.random.PRNGKey(0))
    mask = adapter.subnet_mask(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, 64)
    grads = jax.grad(adapter.loss_simple)(params, {"tokens": tokens})
    for g, m in zip(jax.tree.leaves(grads), jax.tree.leaves(mask)):
        outside = jnp.where(jnp.broadcast_to(m, g.shape), 0.0,
                            g.astype(jnp.float32))
        assert float(jnp.max(jnp.abs(outside))) == 0.0


# ---------------------------------------------------------------------------
# Server aggregation (Alg. 1 ln. 16-22)
# ---------------------------------------------------------------------------

def _toy_cohort():
    # tree: {"a": scalar-ish leaf in M, "b": leaf outside M}
    cohort = {"a": jnp.array([[1.0], [2.0], [3.0], [4.0]]),
              "b": jnp.array([[10.0], [20.0], [30.0], [40.0]])}
    mask = {"a": jnp.asarray(True), "b": jnp.asarray(False)}
    is_simple = jnp.array([True, True, False, False])
    valid = jnp.array([True, True, True, True])
    return cohort, mask, is_simple, valid


def test_fedhen_server_update_lines_18_22():
    cohort, mask, is_simple, valid = _toy_cohort()
    new = aggregate.fedhen_server_update(cohort, is_simple, valid, mask)
    # ln.18: M slice averaged over ALL devices
    np.testing.assert_allclose(new["a"], [2.5])
    # ln.22: M' averaged over complex devices only
    np.testing.assert_allclose(new["b"], [35.0])


def test_decouple_server_update():
    cohort, mask, is_simple, valid = _toy_cohort()
    simple_host, complex_new = aggregate.decouple_server_update(
        cohort, is_simple, valid, mask)
    np.testing.assert_allclose(simple_host["a"], [1.5])   # simple-only mean
    np.testing.assert_allclose(complex_new["a"], [3.5])   # complex-only mean
    np.testing.assert_allclose(complex_new["b"], [35.0])


def test_nan_device_excluded():
    cohort, mask, is_simple, valid = _toy_cohort()
    cohort["a"] = cohort["a"].at[0, 0].set(jnp.nan)
    valid = jax.vmap(masking.tree_isfinite)(cohort)
    assert list(np.asarray(valid)) == [False, True, True, True]
    new = aggregate.fedhen_server_update(cohort, is_simple, valid, mask)
    np.testing.assert_allclose(new["a"], [3.0])  # mean of 2,3,4
    assert np.isfinite(new["a"]).all()


# ---------------------------------------------------------------------------
# End-to-end rounds (tiny LM, all three algorithms)
# ---------------------------------------------------------------------------

def _make_trainer(algorithm, rounds_data_seed=0):
    fed = FedConfig(n_devices=4, n_simple=2, participation=0.5, rounds=3,
                    local_epochs=1, lr=0.1, clip_norm=10.0, batch_size=4,
                    algorithm=algorithm, seed=rounds_data_seed)
    data = synthetic_lm(32, 16, TINY.vocab_size, seed=1)
    shards = iid_split(data, fed.n_devices, seed=2)
    adapter = LMAdapter(TINY)
    return FederatedTrainer(adapter, fed, shards)


@pytest.mark.parametrize("algorithm", ["fedhen", "noside", "decouple"])
def test_algorithms_run_and_update(algorithm):
    tr = _make_trainer(algorithm)
    before = jax.tree.map(jnp.copy, tr.server.complex)
    m = tr.run_round()
    assert np.isfinite(m["loss_complex"]) and np.isfinite(m["loss_simple"])
    assert m["n_valid"] == tr.k_simple + tr.k_complex
    changed = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(before),
                        jax.tree.leaves(tr.server.complex)))
    assert changed
    test = {"tokens": jnp.asarray(synthetic_lm(8, 16, TINY.vocab_size,
                                               seed=9)["tokens"])}
    ev = tr.evaluate(test)
    assert 0.0 <= ev["acc_complex"] <= 1.0
    assert ev["mbytes"] > 0


def test_fedhen_loss_decreases():
    tr = _make_trainer("fedhen")
    losses = [tr.run_round()["loss_complex"] for _ in range(6)]
    assert losses[-1] < losses[0], losses


def test_fedhen_simple_host_is_m_slice():
    """Alg. 1 ln. 20 invariant: server simple model == complex M slice, so
    extract(complex) round-trips through a training round."""
    tr = _make_trainer("fedhen")
    tr.run_round()
    simple = masking.extract_simple(tr.server.complex, TINY)
    rebuilt = masking.embed_simple(simple, tr.server.complex, TINY)
    for a, b in zip(jax.tree.leaves(rebuilt),
                    jax.tree.leaves(tr.server.complex)):
        np.testing.assert_array_equal(a, b)


def test_comm_accounting():
    tr = _make_trainer("fedhen")
    per = tr.bytes_per_round
    simple_bytes = TINY.simple_param_count() * 4
    total_bytes = TINY.param_count() * 4
    expected = 2.0 * (tr.k_simple * simple_bytes + tr.k_complex * total_bytes)
    assert per == expected, (per, expected)


def _make_uniform_trainer(participation, seed=0, **fed_kw):
    fed = FedConfig(n_devices=8, n_simple=4, participation=participation,
                    rounds=3, local_epochs=1, lr=0.1, clip_norm=10.0,
                    batch_size=4, algorithm="fedhen", seed=seed,
                    sample_uniform=True, **fed_kw)
    data = synthetic_lm(32, 16, TINY.vocab_size, seed=1)
    shards = iid_split(data, fed.n_devices, seed=2)
    return FederatedTrainer(LMAdapter(TINY), fed, shards)


def test_uniform_mode_runs_and_bills_realized_cohort():
    """Uniform super-cohort rounds: pad slots are weight-0 (they never
    reach the loss or the aggregate) and move no bytes — only the
    realized clients are billed."""
    tr = _make_uniform_trainer(0.5)         # k_super = 4 over 8 clients
    expect = 0.0
    for r in range(2):
        plan = tr.sampler.plan(r)
        expect += 2.0 * (plan.n_real_simple * tr.per_simple_bytes
                         + plan.n_real_complex * tr.per_complex_bytes)
        m = tr.run_round()
        assert np.isfinite(m["loss_complex"]) and np.isfinite(m["loss_simple"])
        # every valid device is a REAL sampled client, never a pad slot
        assert m["n_valid"] == plan.n_real_simple + plan.n_real_complex
    assert tr.total_bytes == expect, (tr.total_bytes, expect)
    # the matrix tracked exactly the sampled clients
    assert tr.client_state.tracked_clients() == len(np.unique(
        np.concatenate([tr.sampler.plan(r).real_ids() for r in range(2)])))


def test_uniform_full_participation_matches_stratified():
    """At participation=1.0 the uniform draw enumerates the population in
    the stratified order, so the two modes must produce bit-identical
    server params and metrics."""
    fed = FedConfig(n_devices=4, n_simple=2, participation=1.0, rounds=2,
                    local_epochs=1, lr=0.1, clip_norm=10.0, batch_size=4,
                    algorithm="fedhen", seed=0)
    data = synthetic_lm(32, 16, TINY.vocab_size, seed=1)
    shards = iid_split(data, fed.n_devices, seed=2)
    import dataclasses
    tr_s = FederatedTrainer(LMAdapter(TINY), fed, shards)
    tr_u = FederatedTrainer(
        LMAdapter(TINY),
        dataclasses.replace(fed, sample_uniform=True), shards)
    for _ in range(2):
        ms, mu = tr_s.run_round(), tr_u.run_round()
        assert ms == mu, (ms, mu)
    for a, b in zip(jax.tree.leaves(tr_s.server.complex),
                    jax.tree.leaves(tr_u.server.complex)):
        np.testing.assert_array_equal(a, b)
    assert tr_s.total_bytes == tr_u.total_bytes


def test_rounds_to_target():
    hist = [{"round": 1, "acc_simple": 0.1}, {"round": 2, "acc_simple": 0.5},
            {"round": 3, "acc_simple": 0.7}]
    assert rounds_to_target(hist, "acc_simple", 0.5) == 2
    assert rounds_to_target(hist, "acc_simple", 0.9) == -1


def test_rounds_to_target_loss_direction():
    """Loss-style metrics decrease toward the target: the threshold is
    'at or UNDER', matching obs.report's direction inference."""
    hist = [{"round": 1, "loss_simple": 2.0}, {"round": 2, "loss_simple": 0.8},
            {"round": 3, "loss_simple": 0.3}]
    assert rounds_to_target(hist, "loss_simple", 1.0) == 2
    assert rounds_to_target(hist, "loss_simple", 0.3) == 3
    assert rounds_to_target(hist, "loss_simple", 0.1) == -1


# ---------------------------------------------------------------------------
# Streaming cohort engine: chunked rounds == one-shot rounds
# ---------------------------------------------------------------------------

def _make_chunked_trainer(algorithm, chunk, *, n_devices=12, **fed_kw):
    """ks = kc = n_devices/4 active clients per population."""
    fed = FedConfig(n_devices=n_devices, n_simple=n_devices // 2,
                    participation=0.5, rounds=3, local_epochs=1, lr=0.1,
                    clip_norm=10.0, batch_size=4, algorithm=algorithm,
                    seed=0, cohort_chunk=chunk, **fed_kw)
    data = synthetic_lm(n_devices * 4, 16, TINY.vocab_size, seed=1)
    shards = iid_split(data, fed.n_devices, seed=2)
    return FederatedTrainer(LMAdapter(TINY), fed, shards)


def _assert_server_allclose(a, b, rtol=3e-5, atol=3e-6):
    for x, y in zip(jax.tree.leaves(a.server.complex),
                    jax.tree.leaves(b.server.complex)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)
    if a.server.simple_host is not None:
        for x, y in zip(jax.tree.leaves(a.server.simple_host),
                        jax.tree.leaves(b.server.simple_host)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=rtol, atol=atol)


@pytest.mark.parametrize("algorithm", ["fedhen", "noside", "decouple"])
@pytest.mark.parametrize("chunk", [1, 3, 0])   # 0 = whole population (k)
def test_chunked_round_matches_one_shot(algorithm, chunk):
    """cohort_chunk only changes the execution schedule, never the round's
    result: server state after a chunked round == the one-shot round."""
    ref = _make_chunked_trainer(algorithm, 0)
    tr = _make_chunked_trainer(algorithm, chunk)
    m_ref = ref.run_round()
    m = tr.run_round()
    _assert_server_allclose(ref, tr)
    assert m["n_valid"] == m_ref["n_valid"]
    assert abs(m["loss_simple"] - m_ref["loss_simple"]) < 1e-4
    assert abs(m["loss_complex"] - m_ref["loss_complex"]) < 1e-4


@pytest.mark.parametrize("algorithm", ["fedhen", "noside", "decouple"])
def test_chunk_not_dividing_k_is_padded(algorithm):
    """ks = kc = 3 with chunk 2: populations are padded with zero-validity
    clients; the padding must not change the aggregate or the metrics."""
    ref = _make_chunked_trainer(algorithm, 0)
    tr = _make_chunked_trainer(algorithm, 2)   # 2 does not divide 3
    m_ref = ref.run_round()
    m = tr.run_round()
    _assert_server_allclose(ref, tr)
    assert m["n_valid"] == m_ref["n_valid"] == tr.k_simple + tr.k_complex
    assert abs(m["loss_simple"] - m_ref["loss_simple"]) < 1e-4


def test_chunked_multi_round_stays_on_trajectory():
    """Chunking composes over rounds (the carry is re-chunked each round)."""
    ref = _make_chunked_trainer("fedhen", 0)
    tr = _make_chunked_trainer("fedhen", 2)
    for _ in range(3):
        ref.run_round()
        tr.run_round()
    _assert_server_allclose(ref, tr, rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# Flat aggregation engine (layout threading, auto chunk, HLO claim)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ["fedhen", "decouple"])
def test_flat_round_matches_tree_round(algorithm):
    """agg_engine only changes the fold's execution layout, never the
    round's result."""
    ref = _make_chunked_trainer(algorithm, 2, agg_engine="tree")
    tr = _make_chunked_trainer(algorithm, 2, agg_engine="flat")
    m_ref = ref.run_round()
    m = tr.run_round()
    _assert_server_allclose(ref, tr)
    assert m["n_valid"] == m_ref["n_valid"]
    assert abs(m["loss_complex"] - m_ref["loss_complex"]) < 1e-4


def test_auto_cohort_chunk_resolves_from_budget():
    """cohort_chunk="auto": tiny budget floors at 1; huge budget covers the
    whole population; the resolved chunk round still matches one-shot."""
    small = _make_chunked_trainer("fedhen", "auto",
                                  agg_memory_budget_mb=1e-6)
    assert small.cohort_chunk == 1
    big = _make_chunked_trainer("fedhen", "auto",
                                agg_memory_budget_mb=1e9)
    assert big.cohort_chunk == max(big.k_simple, big.k_complex)
    ref = _make_chunked_trainer("fedhen", 0)
    ref.run_round()
    small.run_round()
    _assert_server_allclose(ref, small)


def test_trainer_layout_is_static_and_mask_flat():
    tr = _make_chunked_trainer("fedhen", 2)
    assert tr.layout.n_flat % tr.fed.agg_block_n == 0
    assert tr.flat_mask.shape == (tr.layout.n_flat,)
    assert tr.flat_mask.dtype == jnp.bool_
    from repro.core import masking
    n_in_m = masking.mask_size(tr.mask, tr.server.complex)
    assert int(jnp.sum(tr.flat_mask)) == n_in_m == TINY.simple_param_count()


def test_flat_round_hlo_has_fewer_masked_agg_reductions():
    """Acceptance: the compiled flat round folds the whole model in one
    masked-agg reduction per fold, so its HLO carries strictly fewer
    reduce ops than the per-leaf tree round (one per leaf)."""
    flat = _make_chunked_trainer("fedhen", 2, agg_engine="flat")
    tree = _make_chunked_trainer("fedhen", 2, agg_engine="tree")
    txt_flat = flat.lower_round().compile().as_text()
    txt_tree = tree.lower_round().compile().as_text()
    n_leaves = len(jax.tree.leaves(flat.server.complex))
    n_flat, n_tree = txt_flat.count(" reduce("), txt_tree.count(" reduce(")
    # the non-fold reduces (loss, clipping, validity) are identical in both
    # programs; the fold's per-leaf launches are the difference
    assert n_tree - n_flat >= n_leaves - 2, (n_flat, n_tree, n_leaves)


# ---------------------------------------------------------------------------
# Communication accounting
# ---------------------------------------------------------------------------

class _ToyAdapter:
    """Fixed tiny param tree with a known mask: 4 floats in M ("a"),
    3 floats outside ("b")."""

    def init(self, key):
        return {"a": jnp.zeros((2, 2), jnp.float32),
                "b": jnp.zeros((3,), jnp.float32)}

    def subnet_mask(self, params):
        return {"a": jnp.asarray(True), "b": jnp.asarray(False)}

    loss_simple = loss_complex = loss_side = staticmethod(
        lambda params, batch: jnp.zeros(()))


def test_bytes_per_round_hand_computed():
    """down+up x (k_s x |M| + k_c x |w_c|) x 4 bytes, by hand: k_s = k_c = 1,
    |M| = 16 B, |w_c| = 28 B -> 2 x (16 + 28) = 88 B."""
    fed = FedConfig(n_devices=4, n_simple=2, participation=0.5,
                    algorithm="fedhen")
    tr = FederatedTrainer(_ToyAdapter(), fed, client_data=[])
    assert tr.k_simple == 1 and tr.k_complex == 1
    assert tr.bytes_per_round == 2.0 * (1 * 16 + 1 * 28) == 88.0


def test_total_bytes_invariant_under_chunking():
    """Chunking is an execution detail: what is *communicated* per round
    (and in total) must not depend on cohort_chunk."""
    ref = _make_chunked_trainer("fedhen", 0)
    tr = _make_chunked_trainer("fedhen", 2)
    assert tr.bytes_per_round == ref.bytes_per_round
    for _ in range(2):
        ref.run_round()
        tr.run_round()
    assert tr.total_bytes == ref.total_bytes > 0


# ---------------------------------------------------------------------------
# Splits
# ---------------------------------------------------------------------------

def test_dirichlet_split_is_skewed_but_complete():
    data = synthetic_lm(400, 8, 32, seed=3)
    shards_iid = iid_split(data, 10, seed=4)
    shards_nid = dirichlet_split(data, 10, alpha=0.3, seed=4)
    assert all(len(s["tokens"]) == 40 for s in shards_nid)
    from repro.data.federated import label_distribution
    d_iid = label_distribution(shards_iid, 10)
    d_nid = label_distribution(shards_nid, 10)
    # non-IID shards should be measurably more concentrated
    assert d_nid.max(1).mean() > d_iid.max(1).mean() + 0.1
