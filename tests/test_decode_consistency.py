"""Decode (serve_step) must reproduce prefill logits token-by-token.

This is the core serving invariant: for every mixer family, running the
model autoregressively with its cache yields the same logits as the full
parallel forward.  fp32 + no-drop MoE capacity so comparisons are exact-ish.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig
from repro.models import transformer as tf

S = 16
B = 2


def _roundtrip(cfg, tol):
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
    tokens = jax.random.randint(jax.random.PRNGKey(1), shape, 0,
                                cfg.vocab_size)
    _, final_h, _ = tf.forward(params, cfg, tokens)
    ref = tf.logits_from_hidden(params, cfg, final_h, "final")

    cache = tf.init_cache(cfg, B, S)
    step = jax.jit(lambda c, t, p: tf.decode_step(params, c, cfg, t, p))
    outs = []
    for t in range(S):
        lg, cache = step(cache, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec.astype(jnp.float32) -
                                ref.astype(jnp.float32))))
    assert err < tol, f"decode/prefill mismatch: {err}"
    assert not bool(jnp.isnan(dec).any())


def test_dense_gqa():
    cfg = ModelConfig(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=97, pattern=(LayerSpec("attn"),),
                      exit_layer=2, compute_dtype="float32")
    _roundtrip(cfg, 2e-3)


def test_local_global_softcap():
    cfg = ModelConfig(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab_size=97, window=6,
                      attn_logit_softcap=50.0, final_logit_softcap=30.0,
                      pattern=(LayerSpec("local_attn"), LayerSpec("attn")),
                      exit_layer=2, compute_dtype="float32")
    _roundtrip(cfg, 2e-3)


def test_moe_no_drop():
    cfg = ModelConfig(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab_size=97,
                      pattern=(LayerSpec("attn", "moe"),),
                      moe=MoEConfig(n_experts=4, top_k=2, n_shared=1,
                                    d_expert=64, capacity_factor=64.0),
                      exit_layer=2, compute_dtype="float32")
    _roundtrip(cfg, 2e-3)


def test_hybrid_rglru():
    cfg = ModelConfig(n_layers=6, d_model=64, n_heads=4, n_kv_heads=1,
                      d_ff=128, vocab_size=97, window=6,
                      pattern=(LayerSpec("rglru"), LayerSpec("rglru"),
                               LayerSpec("local_attn")),
                      exit_layer=3, compute_dtype="float32")
    _roundtrip(cfg, 2e-3)


def test_xlstm():
    cfg = ModelConfig(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=0, vocab_size=97, mlstm_chunk=4,
                      pattern=(LayerSpec("mlstm", "none"),
                               LayerSpec("mlstm", "none"),
                               LayerSpec("mlstm", "none"),
                               LayerSpec("slstm", "none")),
                      exit_layer=4, compute_dtype="float32")
    _roundtrip(cfg, 5e-3)


def test_musicgen_codebooks():
    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab_size=32, n_codebooks=4,
                      pattern=(LayerSpec("attn"),),
                      exit_layer=1, compute_dtype="float32")
    _roundtrip(cfg, 2e-3)


def test_ring_buffer_past_window():
    """Decode beyond the window: ring buffer must match windowed prefill."""
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab_size=31, window=5,
                      pattern=(LayerSpec("local_attn"),),
                      exit_layer=1, compute_dtype="float32")
    _roundtrip(cfg, 2e-3)
