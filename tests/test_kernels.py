"""Pallas kernel validation: interpret=True vs pure-jnp oracles, swept over
shapes and dtypes (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.masked_agg.kernel import (masked_agg_acc_deq_pallas,
                                             masked_agg_acc_pallas,
                                             masked_agg_pallas)
from repro.kernels.masked_agg.ops import masked_agg_leaf, masked_agg_tree
from repro.kernels.masked_agg.ref import (masked_agg_acc_deq_ref,
                                          masked_agg_acc_ref,
                                          masked_agg_ref)
from repro.kernels.rglru_scan.kernel import lru_scan_pallas
from repro.kernels.rglru_scan.ref import lru_scan_ref


# ---------------------------------------------------------------------------
# masked_agg
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("z,n", [(4, 256), (10, 2048), (7, 5000), (32, 999)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_agg_sweep(z, n, dtype):
    key = jax.random.PRNGKey(z * 1000 + n)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (z, n), dtype)
    mask = jax.random.bernoulli(ks[1], 0.5, (n,))
    w_m = jax.nn.softmax(jax.random.normal(ks[2], (z,)))
    w_rest = jax.nn.softmax(jax.random.normal(ks[3], (z,)))
    got = masked_agg_pallas(x, mask, w_m, w_rest, block_n=1024,
                            interpret=True)
    want = masked_agg_ref(x, mask, w_m, w_rest)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_masked_agg_nan_gating():
    x = jnp.array([[jnp.nan, 1.0], [2.0, 3.0]])
    mask = jnp.array([True, False])
    got = masked_agg_pallas(x, mask, jnp.array([0.0, 1.0]),
                            jnp.array([0.0, 1.0]), interpret=True)
    np.testing.assert_allclose(got, [2.0, 3.0])


def test_masked_agg_tree_matches_server_update():
    """The kernel path must reproduce core.aggregate.fedhen_server_update."""
    from repro.core import aggregate
    key = jax.random.PRNGKey(0)
    cohort = {"a": jax.random.normal(key, (6, 33)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (6, 17))}
    mask = {"a": jnp.asarray(True), "b": jnp.asarray(False)}
    is_simple = jnp.array([1, 1, 1, 0, 0, 0], bool)
    valid = jnp.ones(6, bool)
    want = aggregate.fedhen_server_update(cohort, is_simple, valid, mask)
    w_m = valid / 6.0
    w_rest = (~is_simple) * valid / 3.0
    got = masked_agg_tree(cohort, mask, w_m, w_rest,
                          force_pallas_interpret=True)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(g, w, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("z,n", [(4, 256), (10, 2048), (7, 5000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_agg_acc_sweep(z, n, dtype):
    """Accumulating variant (the flat fold's kernel): out = acc + masked
    sum, f32 accumulation regardless of the streaming dtype."""
    key = jax.random.PRNGKey(z * 7 + n)
    ks = jax.random.split(key, 5)
    acc = jax.random.normal(ks[0], (n,), jnp.float32)
    x = jax.random.normal(ks[1], (z, n), dtype)
    mask = jax.random.bernoulli(ks[2], 0.5, (n,))
    w_m = jax.nn.softmax(jax.random.normal(ks[3], (z,)))
    w_rest = jax.nn.softmax(jax.random.normal(ks[4], (z,)))
    got = masked_agg_acc_pallas(acc, x, mask, w_m, w_rest, block_n=1024,
                                interpret=True)
    want = masked_agg_acc_ref(acc, x, mask, w_m, w_rest)
    assert got.dtype == jnp.float32
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_masked_agg_acc_folds_match_one_shot():
    """Chained accumulating folds over chunks == one masked_agg over the
    whole cohort plus the starting accumulator."""
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (8, 512))
    mask = jax.random.bernoulli(ks[1], 0.5, (512,))
    w_m = jnp.arange(1.0, 9.0) / 8
    w_rest = jnp.ones((8,)) / 8
    acc = jnp.zeros((512,), jnp.float32)
    for lo in range(0, 8, 2):
        acc = masked_agg_acc_pallas(acc, x[lo:lo + 2], mask,
                                    w_m[lo:lo + 2], w_rest[lo:lo + 2],
                                    block_n=256, interpret=True)
    want = masked_agg_ref(x, mask, w_m, w_rest)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_masked_agg_acc_nan_gating():
    acc = jnp.array([1.0, 2.0])
    x = jnp.array([[jnp.nan, 1.0], [2.0, 3.0]])
    mask = jnp.array([True, False])
    got = masked_agg_acc_pallas(acc, x, mask, jnp.array([0.0, 1.0]),
                                jnp.array([0.0, 1.0]), interpret=True)
    np.testing.assert_allclose(got, [3.0, 5.0])


def test_masked_agg_acc_rejects_non_f32_accumulator():
    with pytest.raises(ValueError):
        masked_agg_acc_pallas(jnp.zeros((4,), jnp.bfloat16),
                              jnp.zeros((2, 4)), jnp.zeros((4,), bool),
                              jnp.ones((2,)), jnp.ones((2,)),
                              interpret=True)


def test_masked_agg_acc_aliases_accumulator():
    """The jitted accumulating kernel declares the acc->out alias: with
    donation, XLA reuses the accumulator buffer (in-place update)."""
    n = 512
    fn = jax.jit(
        lambda acc, x, m, wm, wr: masked_agg_acc_pallas(
            acc, x, m, wm, wr, block_n=256, interpret=True),
        donate_argnums=(0,))
    acc = jnp.ones((n,), jnp.float32)
    x = jnp.ones((3, n))
    out = fn(acc, x, jnp.ones((n,), bool), jnp.ones((3,)) / 3,
             jnp.ones((3,)) / 3)
    np.testing.assert_allclose(np.asarray(out), 2.0)
    if jax.default_backend() != "cpu":   # CPU ignores donation
        assert acc.is_deleted()  # the donated input buffer was consumed


@pytest.mark.parametrize("z,n,quant_block", [(4, 512, 128), (7, 2048, 64),
                                             (3, 1024, 32)])
def test_masked_agg_acc_deq_sweep(z, n, quant_block):
    """Dequantizing accumulate (the quantized-upload fold's kernel):
    interpret mode == the XLA ref, for int8 payload + per-group scales."""
    from repro.core import comm
    key = jax.random.PRNGKey(z * 13 + n)
    ks = jax.random.split(key, 5)
    acc = jax.random.normal(ks[0], (n,), jnp.float32)
    x = jax.random.normal(ks[1], (z, n)) * 10.0
    q, scales = comm.quantize(x, quant_block)
    mask = jax.random.bernoulli(ks[2], 0.5, (n,))
    w_m = jax.nn.softmax(jax.random.normal(ks[3], (z,)))
    w_rest = jax.nn.softmax(jax.random.normal(ks[4], (z,)))
    got = masked_agg_acc_deq_pallas(acc, q, scales, mask, w_m, w_rest,
                                    quant_block=quant_block, block_n=512,
                                    interpret=True)
    want = masked_agg_acc_deq_ref(acc, q, scales, mask, w_m, w_rest,
                                  quant_block=quant_block)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_masked_agg_acc_deq_matches_dequant_then_fold():
    """Fusing the dequant into the accumulate changes nothing numerically:
    deq-fold == dequantize (f32 materialize) then plain acc fold."""
    from repro.core import comm
    key = jax.random.PRNGKey(21)
    ks = jax.random.split(key, 4)
    acc = jax.random.normal(ks[0], (512,), jnp.float32)
    x = jax.random.normal(ks[1], (5, 512)) * 3.0
    q, scales = comm.quantize(x, 128)
    mask = jax.random.bernoulli(ks[2], 0.5, (512,))
    w_m = jax.nn.softmax(jax.random.normal(ks[3], (5,)))
    got = masked_agg_acc_deq_ref(acc, q, scales, mask, w_m, w_m,
                                 quant_block=128)
    want = masked_agg_acc_ref(acc, comm.dequantize(q, scales, 128), mask,
                              w_m, w_m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_masked_agg_acc_deq_nan_scale_gating():
    """A NaN device's scales are NaN (quantize of NaN rows): weight-0
    gating must kill the row before the multiply on both paths."""
    acc = jnp.array([1.0, 2.0] * 64)
    q = jnp.ones((2, 128), jnp.int8)
    scales = jnp.array([[jnp.nan], [2.0]])
    mask = jnp.ones((128,), bool)
    w = jnp.array([0.0, 1.0])
    for fn in (lambda: masked_agg_acc_deq_ref(
                   acc, q, scales, mask, w, w, quant_block=128),
               lambda: masked_agg_acc_deq_pallas(
                   acc, q, scales, mask, w, w, quant_block=128,
                   block_n=128, interpret=True)):
        got = np.asarray(fn())
        assert np.isfinite(got).all()
        np.testing.assert_allclose(got, np.asarray(acc) + 2.0)


def test_masked_agg_acc_deq_validates_inputs():
    acc = jnp.zeros((256,), jnp.float32)
    q = jnp.zeros((2, 256), jnp.int8)
    scales = jnp.zeros((2, 2))
    mask = jnp.zeros((256,), bool)
    w = jnp.ones((2,))
    with pytest.raises(ValueError):   # non-f32 accumulator
        masked_agg_acc_deq_pallas(acc.astype(jnp.bfloat16), q, scales,
                                  mask, w, w, quant_block=128,
                                  interpret=True)
    with pytest.raises(ValueError):   # non-int8 payload
        masked_agg_acc_deq_pallas(acc, q.astype(jnp.float32), scales,
                                  mask, w, w, quant_block=128,
                                  interpret=True)
    with pytest.raises(ValueError):   # block_n not a group multiple
        masked_agg_acc_deq_pallas(acc, q, scales, mask, w, w,
                                  quant_block=96, block_n=128,
                                  interpret=True)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,h,kh,dh,window", [
    (128, 4, 4, 64, 0),
    (128, 4, 2, 64, 0),
    (256, 8, 2, 32, 64),
    (128, 4, 1, 128, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, h, kh, dh, window, dtype):
    key = jax.random.PRNGKey(s + h)
    ks = jax.random.split(key, 3)
    b = 2
    q = jax.random.normal(ks[0], (b, s, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, kh, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, kh, dh), dtype)
    got = flash_attention_pallas(q, k, v, window=window, block_q=64,
                                 block_k=64, interpret=True)
    want = flash_attention_ref(q, k, v, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_softcap():
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64)) * 4
    k = jax.random.normal(ks[1], (1, 128, 2, 64)) * 4
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    got = flash_attention_pallas(q, k, v, softcap=30.0, block_q=64,
                                 block_k=64, interpret=True)
    want = flash_attention_ref(q, k, v, softcap=30.0)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_matches_model_attention():
    """Kernel semantics == the model's XLA chunked path (same contract)."""
    from repro.models.attention import chunked_causal_attention
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    got = flash_attention_pallas(q, k, v, window=48, block_q=32,
                                 block_k=32, interpret=True)
    want = chunked_causal_attention(q, k, v, window=48, q_chunk=32)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,d,block_s,block_d", [
    (2, 64, 256, 16, 128),
    (1, 128, 512, 32, 512),
    (3, 32, 384, 8, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lru_scan_sweep(b, s, d, block_s, block_d, dtype):
    key = jax.random.PRNGKey(b * 100 + s)
    ka, kb = jax.random.split(key)
    a = jax.nn.sigmoid(jax.random.normal(ka, (b, s, d))).astype(dtype)
    bb = (jax.random.normal(kb, (b, s, d)) * 0.1).astype(dtype)
    got = lru_scan_pallas(a, bb, block_d=block_d, block_s=block_s,
                          interpret=True)
    want = lru_scan_ref(a.astype(jnp.float32), bb.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=tol, atol=tol)


def test_lru_scan_matches_model_path():
    """Kernel == the associative-scan path used by models/rglru.py."""
    from repro.kernels.rglru_scan.ops import lru_scan
    key = jax.random.PRNGKey(3)
    ka, kb = jax.random.split(key)
    a = jax.nn.sigmoid(jax.random.normal(ka, (2, 64, 256)))
    b = jax.random.normal(kb, (2, 64, 256)) * 0.2
    got = lru_scan_pallas(a, b, block_d=128, block_s=16, interpret=True)
    want = lru_scan(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
