"""Validate the loop-aware HLO cost walker against known graphs."""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo_walk


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_dot_flops():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    txt = _compile_text(lambda x, y: x @ y, a, b)
    got = hlo_walk.analyze(txt)["flops"]
    assert got == 2 * 128 * 256 * 64, got


def test_scan_multiplies_by_trip_count():
    a = jnp.zeros((128, 128), jnp.float32)
    w = jnp.zeros((10, 128, 128), jnp.float32)

    def f(a, w):
        def body(h, wi):
            return h @ wi, None
        h, _ = jax.lax.scan(body, a, w)
        return h

    txt = _compile_text(f, a, w)
    got = hlo_walk.analyze(txt)["flops"]
    expect = 10 * 2 * 128 ** 3
    # allow small over/under from loop bookkeeping fusions
    assert abs(got - expect) / expect < 0.05, (got, expect)
    # sanity: XLA's own cost analysis misses the trip count (the reason
    # this walker exists); the shim normalizes the per-device-list vs
    # plain-dict return across jax versions
    ca = hlo_walk.xla_cost_analysis(jax.jit(f).lower(a, w).compile())
    assert ca["flops"] < 0.3 * expect


def test_nested_scan():
    a = jnp.zeros((64, 64), jnp.float32)
    w = jnp.zeros((4, 3, 64, 64), jnp.float32)

    def f(a, w):
        def outer(h, wo):
            def inner(h2, wi):
                return h2 @ wi, None
            h, _ = jax.lax.scan(inner, h, wo)
            return h, None
        h, _ = jax.lax.scan(outer, a, w)
        return h

    txt = _compile_text(f, a, w)
    got = hlo_walk.analyze(txt)["flops"]
    expect = 12 * 2 * 64 ** 3
    assert abs(got - expect) / expect < 0.05, (got, expect)


def test_grad_flops_roughly_triple():
    a = jnp.zeros((64, 512), jnp.float32)
    w = jnp.zeros((512, 512), jnp.float32)

    def loss(w, a):
        return jnp.sum((a @ w) ** 2)

    fwd = hlo_walk.analyze(_compile_text(loss, w, a))["flops"]
    bwd = hlo_walk.analyze(
        _compile_text(jax.grad(loss, argnums=(0, 1)), w, a))["flops"]
    assert 2.4 < bwd / fwd < 3.6, (fwd, bwd)


def test_collectives_counted_with_trips():
    devs = jax.local_device_count()
    if devs < 2:
        pytest.skip("needs >= 2 host devices")


def test_hbm_bytes_scale_with_tensor_size():
    a = jnp.zeros((1024, 1024), jnp.float32)
    txt = _compile_text(lambda x: x * 2.0 + 1.0, a)
    got = hlo_walk.analyze(txt)["hbm_bytes"]
    # one read + one write of 4MB, give or take bookkeeping
    assert 0.5 * 8e6 < got < 4 * 8e6, got
