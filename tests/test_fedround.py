"""The production round-step (fedround_dryrun's payload) is semantically a
FedHeN round: branchless objective select + masked aggregation."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.core import masking


def test_round_step_tiny():
    # import the factory without triggering the module-level XLA_FLAGS
    import importlib.util
    import os
    spec = importlib.util.find_spec("repro.launch.fedround_dryrun")
    # the XLA flag assignment at module top is harmless after jax init
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab_size=64, pattern=(LayerSpec("attn"),),
                      exit_layer=1, compute_dtype="float32")
    from repro.models import transformer as tfm
    from repro.models.common import NO_POLICY

    k_clients, batch, steps, seq = 4, 2, 2, 16
    step = mod.make_round_step(cfg, NO_POLICY, local_steps=steps)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    cohort = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (k_clients,) + x.shape), params)
    data = jax.random.randint(jax.random.PRNGKey(1),
                              (k_clients, batch, steps, seq + 1), 0, 64)
    is_simple = jnp.array([True, True, False, False])

    new_complex, loss = jax.jit(step)(cohort, data, is_simple)
    assert np.isfinite(float(loss))
    for x in jax.tree.leaves(new_complex):
        assert np.isfinite(np.asarray(x, np.float32)).all()
    # simple clients must not have moved the M' (complex-only) slice:
    # aggregation takes M' from complex clients only, so M' != init
    # while the M slice mixes all four — both should differ from init
    changed = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(new_complex),
                        jax.tree.leaves(params)))
    assert changed


def test_round_step_int8_wire_matches_f32():
    """The launch-side round folds encoded uploads: the int8 wire's
    dequantizing fold lands near the f32 round and stays finite."""
    from repro.launch.steps import make_fed_round_step
    from repro.models import transformer as tfm
    from repro.models.common import NO_POLICY

    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab_size=64, pattern=(LayerSpec("attn"),),
                      exit_layer=1, compute_dtype="float32")
    k_clients, batch, steps, seq = 4, 2, 2, 16
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    cohort = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (k_clients,) + x.shape), params)
    data = jax.random.randint(jax.random.PRNGKey(1),
                              (k_clients, batch, steps, seq + 1), 0, 64)
    is_simple = jnp.array([True, True, False, False])

    ref_step = make_fed_round_step(cfg, NO_POLICY, local_steps=steps,
                                   cohort_chunk=2)
    q_step = make_fed_round_step(cfg, NO_POLICY, local_steps=steps,
                                 cohort_chunk=2, comm_dtype="int8")
    ref_c, ref_loss = jax.jit(ref_step)(cohort, data, is_simple)
    q_c, q_loss = jax.jit(q_step)(cohort, data, is_simple)
    assert np.isfinite(float(q_loss))
    # uploads are quantized but training is identical: same loss metric
    np.testing.assert_allclose(float(q_loss), float(ref_loss), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(q_c), jax.tree.leaves(ref_c)):
        amax = float(jnp.max(jnp.abs(b))) + 1e-12
        assert float(jnp.max(jnp.abs(a - b))) <= amax / 100.0


def test_make_fed_round_step_engine_spec_shim():
    """The launch-side factory takes ONE EngineSpec; the old loose kwargs
    still work behind a DeprecationWarning, and mixing both is an
    error."""
    import pytest

    from repro.core import aggregate, comm
    from repro.launch.steps import make_fed_round_step
    from repro.models.common import NO_POLICY

    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab_size=64, pattern=(LayerSpec("attn"),),
                      exit_layer=1, compute_dtype="float32")
    spec = aggregate.EngineSpec(algorithm="fedhen", block_n=512,
                                wire=comm.WireSpec("float32", 128))
    make_fed_round_step(cfg, NO_POLICY, local_steps=1, engine=spec)

    with pytest.warns(DeprecationWarning, match="make_fed_round_step"):
        make_fed_round_step(cfg, NO_POLICY, local_steps=1,
                            agg_engine="flat", agg_block_n=512)

    with pytest.raises(ValueError, match="either"):
        make_fed_round_step(cfg, NO_POLICY, local_steps=1, engine=spec,
                            agg_engine="flat")
