"""Unit tests for individual model layers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig
from repro.models import attention, common, resnet, rglru, xlstm


# ---------------------------------------------------------------------------
# mLSTM: chunked parallel form == recurrent oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [1, 4, 8, 16])
def test_mlstm_chunked_matches_recurrent(chunk):
    key = jax.random.PRNGKey(0)
    b, s, nh, dh = 2, 16, 3, 8
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, nh, dh))
    k = jax.random.normal(ks[1], (b, s, nh, dh))
    v = jax.random.normal(ks[2], (b, s, nh, dh))
    i_raw = jax.random.normal(ks[3], (b, s, nh)) * 2.0
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, s, nh)) + 2.0)

    h_ref, st_ref = xlstm.mlstm_recurrent(q, k, v, i_raw, log_f)
    h_chk, st_chk = xlstm.mlstm_chunked(q, k, v, i_raw, log_f, chunk=chunk)
    np.testing.assert_allclose(h_chk, h_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_chk["C"], st_ref["C"], rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(st_chk["n"], st_ref["n"], rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(st_chk["m"], st_ref["m"], rtol=1e-4, atol=1e-4)


def test_mlstm_chunked_carries_state():
    """Two half-sequence chunked calls == one full call."""
    key = jax.random.PRNGKey(1)
    b, s, nh, dh = 1, 16, 2, 4
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, nh, dh))
    k = jax.random.normal(ks[1], (b, s, nh, dh))
    v = jax.random.normal(ks[2], (b, s, nh, dh))
    i_raw = jax.random.normal(ks[3], (b, s, nh))
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, s, nh)) + 1.0)

    h_full, _ = xlstm.mlstm_chunked(q, k, v, i_raw, log_f, chunk=4)
    h1, st = xlstm.mlstm_chunked(q[:, :8], k[:, :8], v[:, :8],
                                 i_raw[:, :8], log_f[:, :8], chunk=4)
    h2, _ = xlstm.mlstm_chunked(q[:, 8:], k[:, 8:], v[:, 8:],
                                i_raw[:, 8:], log_f[:, 8:], chunk=4, state=st)
    np.testing.assert_allclose(jnp.concatenate([h1, h2], 1), h_full,
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Attention: chunked == naive; window semantics
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, window=0, softcap_val=0.0):
    b, s, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / dh ** 0.5
    logits = common.softcap(logits, softcap_val)
    pos = jnp.arange(s)
    mask = pos[:, None] >= pos[None, :]
    if window:
        mask &= (pos[:, None] - pos[None, :]) < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, s, h, dh)


@pytest.mark.parametrize("window", [0, 8, 64])
@pytest.mark.parametrize("softcap_val", [0.0, 30.0])
def test_chunked_attention_matches_naive(window, softcap_val):
    key = jax.random.PRNGKey(2)
    b, s, h, kh, dh = 2, 128, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kh, dh))
    v = jax.random.normal(ks[2], (b, s, kh, dh))
    ref = _naive_attention(q, k, v, window, softcap_val)
    out = attention.chunked_causal_attention(
        q, k, v, window=window, softcap_val=softcap_val, q_chunk=32)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# RG-LRU: associative scan == sequential reference
# ---------------------------------------------------------------------------

def test_lru_scan_matches_sequential():
    cfg = ModelConfig(d_model=16, d_rnn=24, compute_dtype="float32")
    p = rglru.init_rglru(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, 24))
    y = rglru.lru_scan(p, x)

    a, b = rglru._gates(p, x)
    ys = []
    state = jnp.zeros((2, 24))
    for t in range(32):
        state = a[:, t] * state + b[:, t]
        ys.append(state)
    ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y, ref, rtol=1e-5, atol=1e-5)


def test_lru_decode_matches_scan():
    cfg = ModelConfig(d_model=16, d_rnn=24, compute_dtype="float32")
    p = rglru.init_rglru(jax.random.PRNGKey(5), cfg)
    h = jax.random.normal(jax.random.PRNGKey(6), (2, 12, 16))
    full = rglru.apply_rglru(p, h, cfg)
    cache = rglru.init_rglru_cache(cfg, 2)
    outs = []
    for t in range(12):
        o, cache = rglru.apply_rglru_decode(p, h[:, t:t + 1], cache, cfg)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full,
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ResNet (paper model)
# ---------------------------------------------------------------------------

def test_resnet_shapes_and_param_counts():
    params = resnet.init_params(jax.random.PRNGKey(0), n_classes=10)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 3))
    exit_logits, final_logits = resnet.forward(params, x)
    assert exit_logits.shape == (4, 10)
    assert final_logits.shape == (4, 10)
    assert not bool(jnp.isnan(final_logits).any())

    total = resnet.param_count(params)
    # paper: complex ~11.1M
    assert 10.5e6 < total < 11.8e6, total

    mask = resnet.subnet_mask(params)
    simple = sum(x.size for x, m in
                 zip(jax.tree.leaves(params), jax.tree.leaves(mask)) if m)
    # paper: simple ~0.7M
    assert 0.55e6 < simple < 0.85e6, simple


def test_resnet_simple_forward_matches_exit_head():
    params = resnet.init_params(jax.random.PRNGKey(0), n_classes=10)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    exit_logits, _ = resnet.forward(params, x)
    simple_logits = resnet.forward_simple(params, x)
    np.testing.assert_allclose(simple_logits, exit_logits, rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# RoPE / norms sanity
# ---------------------------------------------------------------------------

def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 8, 2, 16))
    y = common.apply_rope(x, jnp.arange(8), 10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_rope_relative_shift_invariance():
    """RoPE dot products depend only on relative positions."""
    q = jax.random.normal(jax.random.PRNGKey(8), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(9), (1, 1, 1, 16))
    def dot_at(pq, pk):
        qr = common.apply_rope(q, jnp.array([pq]), 10000.0)
        kr = common.apply_rope(k, jnp.array([pk]), 10000.0)
        return jnp.sum(qr * kr)
    np.testing.assert_allclose(dot_at(5, 3), dot_at(105, 103), rtol=1e-4)


def test_groupnorm_normalizes():
    p = common.init_groupnorm(16, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 4, 4, 16)) * 5 + 3
    y = common.apply_groupnorm(p, x, groups=4)
    assert abs(float(jnp.mean(y))) < 1e-4
    np.testing.assert_allclose(float(jnp.var(y)), 1.0, rtol=1e-2)
