"""Per-client flat-vector state store (core/state_store.py): backend
resolution, the gather/scatter round-jit seam on every backend, byte
counters, checkpoint payloads, and the mmap lifecycle."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import state_store
from repro.core.state_store import (DEVICE_LIMIT_BYTES, HOST_LIMIT_BYTES,
                                    FlatStateStore, resolve_backend)


def test_resolve_backend_auto_thresholds():
    assert resolve_backend("auto", DEVICE_LIMIT_BYTES) == "device"
    assert resolve_backend("auto", DEVICE_LIMIT_BYTES + 1) == "host"
    assert resolve_backend("auto", HOST_LIMIT_BYTES) == "host"
    assert resolve_backend("auto", HOST_LIMIT_BYTES + 1) == "mmap"
    for explicit in ("device", "host", "mmap"):
        assert resolve_backend(explicit, 10**18) == explicit
    with pytest.raises(ValueError, match="unknown state-store backend"):
        resolve_backend("gpu", 0)


def test_bad_geometry_rejected():
    with pytest.raises(ValueError, match="n_clients"):
        FlatStateStore(0, 8)
    with pytest.raises(ValueError, match="n_flat"):
        FlatStateStore(8, 0)


@pytest.mark.parametrize("backend", ["device", "host", "mmap"])
def test_gather_scatter_roundtrip(backend):
    store = FlatStateStore(10, 16, backend=backend)
    assert store.backend == backend
    assert store.nbytes == 10 * 16 * 4
    ids = np.array([3, 7, 0])
    rows = store.gather(ids)
    assert isinstance(rows, jax.Array)
    assert rows.shape == (3, 16)
    np.testing.assert_array_equal(np.asarray(rows), 0.0)

    new = np.arange(3 * 16, dtype=np.float32).reshape(3, 16)
    store.scatter(ids, new)
    np.testing.assert_array_equal(np.asarray(store.gather(ids)), new)
    # untouched rows stay zero
    np.testing.assert_array_equal(
        np.asarray(store.gather(np.array([1, 9]))), 0.0)
    # counters: 3 gathers of 3,3,2 rows + one scatter of 3
    row_bytes = 16 * 4
    assert store.gathered_bytes == (3 + 3 + 2) * row_bytes
    assert store.scattered_bytes == 3 * row_bytes
    store.close()


@pytest.mark.parametrize("backend", ["device", "host", "mmap"])
def test_to_array_load_roundtrip(backend):
    store = FlatStateStore(4, 8, backend=backend)
    store.scatter(np.array([1, 2]), np.ones((2, 8), np.float32))
    payload = store.to_array()
    assert payload.shape == (4, 8)

    fresh = FlatStateStore(4, 8, backend=backend)
    fresh.load(payload)
    np.testing.assert_array_equal(fresh.to_array(), payload)
    with pytest.raises(ValueError, match="shape mismatch"):
        fresh.load(np.zeros((5, 8), np.float32))
    store.close()
    fresh.close()


def test_mmap_backing_file_lifecycle():
    store = FlatStateStore(4, 8, backend="mmap")
    path = store._mmap_path
    assert path is not None and os.path.exists(path)
    store.scatter(np.array([0]), np.ones((1, 8), np.float32))
    store.close()
    assert not os.path.exists(path)
    assert store._mmap_path is None
    store.close()  # idempotent


def test_gather_returns_copy_not_view():
    """A later scatter must not mutate rows a round already gathered
    (the round jit's inputs are by-value)."""
    store = FlatStateStore(4, 8, backend="host")
    before = store.gather(np.array([2]))
    store.scatter(np.array([2]), np.full((1, 8), 7.0, np.float32))
    np.testing.assert_array_equal(np.asarray(before), 0.0)
