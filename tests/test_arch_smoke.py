"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(2-3 layers, d_model <= 256, <= 4 experts) and run one forward and one
FedHeN side-objective train step on CPU, asserting output shapes and the
absence of NaNs.  The FULL configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation) — here we only sanity-check their
analytical parameter counts against the published sizes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import INPUT_SHAPES
from repro.core.adapters import LMAdapter
from repro.models import transformer as tfm
from repro.optim.sgd import sgd_update


def _batch(cfg, b=2, s=16, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {}
    s_tok = s
    if cfg.frontend is not None:
        s_tok = s - cfg.frontend.n_tokens
        batch["extra_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (b, cfg.frontend.n_tokens, cfg.frontend.d_in),
            jnp.dtype(cfg.compute_dtype))
    shape = (b, s_tok + 1)
    if cfg.n_codebooks > 1:
        shape = shape + (cfg.n_codebooks,)
    batch["tokens"] = jax.random.randint(key, shape, 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_reduced_forward_and_fedhen_step(name):
    cfg = configs.get_reduced(name)
    assert cfg.n_layers <= 3 and cfg.d_model <= 256
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    adapter = LMAdapter(cfg)
    params = adapter.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    # forward shapes
    inputs = batch["tokens"][:, :-1]
    exit_h, final_h, _ = tfm.forward(params, cfg, inputs,
                                     extra_embeds=batch.get("extra_embeds"))
    s_total = inputs.shape[1] + (cfg.frontend.n_tokens if cfg.frontend else 0)
    assert final_h.shape == (2, s_total, cfg.d_model)
    assert exit_h.shape == final_h.shape
    logits = tfm.logits_from_hidden(params, cfg, final_h, "final")
    expected = ((2, s_total, cfg.n_codebooks, cfg.vocab_size)
                if cfg.n_codebooks > 1 else (2, s_total, cfg.vocab_size))
    assert logits.shape == expected
    assert not bool(jnp.isnan(logits).any())

    # one FedHeN side-objective SGD step
    loss, grads = jax.value_and_grad(adapter.loss_side)(params, batch)
    assert np.isfinite(float(loss))
    new_params = sgd_update(params, grads, 0.1, clip_norm=10.0)
    for x in jax.tree.leaves(new_params):
        assert not bool(jnp.isnan(x).any())
    loss2 = adapter.loss_side(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_reduced_decode_step(name):
    cfg = configs.get_reduced(name)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    b = 2
    cache = tfm.init_cache(cfg, b, 32)
    shape = (b, 1, cfg.n_codebooks) if cfg.n_codebooks > 1 else (b, 1)
    tok = jax.random.randint(jax.random.PRNGKey(1), shape, 0, cfg.vocab_size)
    logits, new_cache = tfm.decode_step(params, cache, cfg, tok, jnp.int32(0))
    assert not bool(jnp.isnan(logits).any())
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


# ---------------------------------------------------------------------------
# Full-config analytical parameter counts vs published sizes
# ---------------------------------------------------------------------------

EXPECTED_PARAMS = {  # (low, high) bounds in billions, generous
    "recurrentgemma-2b": (2.0, 3.6),
    "qwen2-moe-a2.7b": (12.0, 16.5),      # 14.3B total / 2.7B active
    "starcoder2-15b": (13.0, 17.5),
    "gemma2-2b": (2.0, 3.6),
    "xlstm-1.3b": (1.0, 2.0),   # block-diag qkv, pf=2 (see config note)
    "llava-next-34b": (30.0, 40.0),
    "kimi-k2-1t-a32b": (950.0, 1150.0),
    "gemma3-4b": (3.0, 5.0),
    "musicgen-large": (1.5, 2.8),
    "minitron-8b": (7.0, 10.0),
}


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_full_config_param_counts(name):
    cfg = configs.get_config(name)
    n = cfg.param_count() / 1e9
    lo, hi = EXPECTED_PARAMS[name]
    assert lo <= n <= hi, f"{name}: {n:.2f}B not in [{lo}, {hi}]"
    # FedHeN subnet is a strict, nontrivial sub-network
    s = cfg.simple_param_count()
    assert 0 < s < cfg.param_count()


def test_moe_active_params():
    cfg = configs.get_config("kimi-k2-1t-a32b")
    active = cfg.active_param_count() / 1e9
    assert 25.0 <= active <= 45.0, active   # A32B

    qwen = configs.get_config("qwen2-moe-a2.7b")
    assert 1.8 <= qwen.active_param_count() / 1e9 <= 3.8


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_input_specs_cover_all_shapes(name):
    cfg = configs.get_config(name)
    for shape in INPUT_SHAPES.values():
        specs = configs.input_specs(cfg, shape)
        assert "tokens" in specs
        t = specs["tokens"]
        assert t.shape[0] == shape.global_batch
        if shape.kind == "decode":
            assert t.shape[1] == 1
        # no allocation happened
        assert isinstance(t, jax.ShapeDtypeStruct)


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_exit_layer_on_period_boundary(name):
    for cfg in (configs.get_config(name), configs.get_reduced(name)):
        k = cfg.resolved_exit_layer
        assert k % cfg.period == 0
        assert cfg.period <= k <= cfg.n_layers
