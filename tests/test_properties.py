"""Property-based tests (hypothesis) for the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import aggregate, comm, flatten, masking
from repro.models import common

jax.config.update("jax_platform_name", "cpu")

_settings = settings(max_examples=25, deadline=None,
                     derandomize=True)


# ---------------------------------------------------------------------------
# Server aggregation (Alg. 1) invariants
# ---------------------------------------------------------------------------

@_settings
@given(z=st.integers(2, 12), n=st.integers(1, 40), seed=st.integers(0, 999))
def test_fedhen_update_is_convex_combination(z, n, seed):
    """Every output coordinate lies in the convex hull of the valid
    cohort's coordinates (means can't extrapolate)."""
    rng = np.random.default_rng(seed)
    cohort = {"w": jnp.asarray(rng.normal(size=(z, n)).astype(np.float32))}
    mask = {"w": jnp.asarray(rng.random(n) < 0.5)}
    is_simple = jnp.asarray(rng.random(z) < 0.5)
    valid = jnp.asarray(np.ones(z, bool))
    if not bool(jnp.any(~is_simple)):
        is_simple = is_simple.at[0].set(False)
    out = aggregate.fedhen_server_update(cohort, is_simple, valid, mask)
    lo = jnp.min(cohort["w"], axis=0) - 1e-5
    hi = jnp.max(cohort["w"], axis=0) + 1e-5
    assert bool(jnp.all((out["w"] >= lo) & (out["w"] <= hi)))


@_settings
@given(z=st.integers(2, 10), seed=st.integers(0, 999))
def test_consensus_is_fixed_point(z, seed):
    """If every client returns the same model, the server keeps it
    (for every algorithm)."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(7,)).astype(np.float32)
    cohort = {"w": jnp.asarray(np.tile(w, (z, 1)))}
    mask = {"w": jnp.asarray(np.array([1, 1, 1, 0, 0, 0, 0], bool))}
    is_simple = jnp.asarray(rng.random(z) < 0.5)
    if not bool(jnp.any(~is_simple)):
        is_simple = is_simple.at[0].set(False)
    valid = jnp.ones(z, bool)
    out = aggregate.fedhen_server_update(cohort, is_simple, valid, mask)
    np.testing.assert_allclose(out["w"], w, rtol=1e-6)


@_settings
@given(z=st.integers(3, 10), bad=st.integers(0, 2), seed=st.integers(0, 99))
def test_invalid_devices_never_contribute(z, bad, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(z, 5)).astype(np.float32)
    x[:bad] = np.inf
    cohort = {"w": jnp.asarray(x)}
    mask = {"w": jnp.asarray(np.ones(5, bool))}
    is_simple = jnp.zeros(z, bool)
    valid = jax.vmap(masking.tree_isfinite)(cohort)
    out = aggregate.fedhen_server_update(cohort, is_simple, valid, mask)
    assert np.isfinite(np.asarray(out["w"])).all()
    np.testing.assert_allclose(out["w"], x[bad:].mean(0), rtol=1e-5)


# ---------------------------------------------------------------------------
# Flat packing layout invariants
# ---------------------------------------------------------------------------

_shapes = st.lists(
    st.lists(st.integers(1, 6), min_size=0, max_size=3).map(tuple),
    min_size=1, max_size=6)


@_settings
@given(shapes=_shapes, seed=st.integers(0, 999),
       block=st.sampled_from([128, 256, 1024]))
def test_pack_unpack_roundtrip(shapes, seed, block):
    """unpack(pack(tree)) == tree exactly (f32), for any tree shape mix
    and any kernel block size — the flat layout loses nothing."""
    rng = np.random.default_rng(seed)
    tree = {f"l{i}": jnp.asarray(rng.normal(size=s).astype(np.float32))
            for i, s in enumerate(shapes)}
    layout = flatten.build_layout(tree, total_multiple=block)
    assert layout.n_flat % block == 0
    flat = flatten.pack(layout, tree)
    back = flatten.unpack(layout, flat)
    for got, want in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@_settings
@given(shapes=_shapes, z=st.integers(1, 5), seed=st.integers(0, 999))
def test_flat_fold_matches_tree_fold(shapes, z, seed):
    """One flat fold == one tree fold for random trees/weights (the packed
    buffer and bitvector preserve the masked-sum semantics per element)."""
    rng = np.random.default_rng(seed)
    cohort = {f"l{i}": jnp.asarray(
        rng.normal(size=(z,) + s).astype(np.float32))
        for i, s in enumerate(shapes)}
    mask = {f"l{i}": jnp.asarray(bool(rng.integers(2)))
            for i in range(len(shapes))}
    is_simple = jnp.asarray(rng.integers(2, size=z).astype(bool))
    valid = jnp.asarray(rng.integers(2, size=z).astype(bool))
    template = jax.tree.map(lambda x: x[0], cohort)
    f = aggregate.streaming_fold(
        aggregate.streaming_init(template, "fedhen"), cohort, is_simple,
        valid, mask, algorithm="fedhen")
    t = aggregate.tree_streaming_fold(
        aggregate.tree_streaming_init(template, "fedhen"), cohort,
        is_simple, valid, mask, algorithm="fedhen")
    got, _ = aggregate.streaming_finalize(f, mask, template,
                                          algorithm="fedhen")
    want, _ = aggregate.tree_streaming_finalize(t, mask, template,
                                                algorithm="fedhen")
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Wire v2 invariants (encode/decode, stochastic rounding, top-k, EF)
# ---------------------------------------------------------------------------

_qblocks = st.sampled_from([16, 32, 64, 128])   # divisors of the lane width


def _flat(seed, n, scale=10.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((scale * rng.normal(size=(n,))).astype(np.float32))


@_settings
@given(dtype=st.sampled_from(["float32", "bfloat16", "int8"]),
       qb=_qblocks, groups=st.integers(1, 6), seed=st.integers(0, 999))
def test_wire_roundtrip_error_bound(dtype, qb, groups, seed):
    """decode(encode(x)) stays within the wire's per-group error bound:
    exact for f32, half a mantissa step for bf16 (relative), half a
    quantization step of the element's OWN group for int8."""
    x = _flat(seed, groups * qb)
    spec = comm.WireSpec(dtype, qb)
    back = np.asarray(comm.decode(spec, comm.encode(spec, x)))
    x_np = np.asarray(x)
    if dtype == "float32":
        np.testing.assert_array_equal(back, x_np)
    elif dtype == "bfloat16":
        np.testing.assert_allclose(back, x_np, rtol=2 ** -8, atol=1e-30)
    else:
        err = np.abs(back - x_np).reshape(groups, qb)
        step = np.abs(x_np).reshape(groups, qb).max(axis=1) / 127.0
        assert (err <= 0.5 * step[:, None] + 1e-7).all()


@_settings
@given(dtype=st.sampled_from(["bfloat16", "int8"]), qb=_qblocks,
       seed=st.integers(0, 99))
def test_stochastic_rounding_is_unbiased(dtype, qb, seed):
    """The mean of decode(encode(x, key)) over many seeds converges on x
    (round-to-nearest would sit a deterministic half-step away).  The
    int8 bound: averaging 96 uniform [0, 1) draws has std
    step/sqrt(12*96) ~ 0.03 step — 0.25 step is ~8.5 sigma, far outside
    chance but far inside round-to-nearest's worst case (0.5 step);
    bf16's relative step drives its bound the same way."""
    x = _flat(seed, 2 * qb)
    spec = comm.WireSpec(dtype, qb, stochastic=True)
    n_keys = 96
    keys = jax.vmap(jax.random.PRNGKey)(
        jnp.arange(n_keys) + seed * n_keys)
    dec = jax.vmap(
        lambda k: comm.decode(spec, comm.encode(spec, x, key=k)))(keys)
    mean = np.asarray(jnp.mean(dec, axis=0))
    x_np = np.asarray(x)
    if dtype == "int8":
        step = np.abs(x_np).reshape(2, qb).max(axis=1) / 127.0
        tol = 0.25 * np.repeat(step, qb) + 1e-7
    else:
        tol = 0.25 * np.abs(x_np) * 2 ** -8 * 256 + 1e-6
    assert (np.abs(mean - x_np) <= tol).all()


@_settings
@given(seed=st.integers(0, 999), n_lanes=st.integers(2, 8),
       frac_kept=st.integers(1, 7))
def test_topk_payload_is_exactly_the_k_largest(seed, n_lanes, frac_kept):
    """On the f32 wire the sparse payload reproduces the k largest-|x|
    entries bit for bit, and nothing else ships."""
    n = n_lanes * 128
    x = _flat(seed, n)
    spec = comm.WireSpec("float32", topk_frac=frac_kept / 8.0)
    k = comm.topk_count(spec, n)
    buf = comm.sparse_encode(spec, x, k)
    idx = np.asarray(buf.indices)
    x_np = np.asarray(x)
    want = np.sort(np.abs(x_np))[::-1][:k]
    np.testing.assert_array_equal(
        np.sort(np.abs(np.asarray(buf.payload)))[::-1], want)
    np.testing.assert_array_equal(np.asarray(buf.payload), x_np[idx])
    dense = np.asarray(comm.sparse_decode(spec, buf, n))
    np.testing.assert_array_equal(dense[idx], x_np[idx])
    assert np.count_nonzero(dense) <= k


@_settings
@given(dtype=st.sampled_from(["float32", "bfloat16", "int8"]),
       frac_kept=st.integers(1, 8), seed=st.integers(0, 999),
       stochastic=st.booleans())
def test_error_feedback_conserves_the_delta(dtype, frac_kept, seed,
                                            stochastic):
    """The EF update's conservation law: whatever the wire drops stays in
    the residual — ``residual' + decode(payload) == delta + residual``
    for every dtype, sparsity and rounding mode.  This is the invariant
    that makes compressed SGD converge (Karimireddy et al. 2019)."""
    if stochastic and dtype == "float32":
        stochastic = False               # invalid combination
    n = 512
    d = _flat(seed, n)
    r = _flat(seed + 10_000, n, scale=3.0)
    spec = comm.WireSpec(dtype, 64, topk_frac=frac_kept / 8.0,
                         stochastic=stochastic, error_feedback=True)
    d_in = d + r
    key = jax.random.PRNGKey(seed)
    if spec.is_sparse:
        k = comm.topk_count(spec, n)
        buf = comm.sparse_encode(spec, d_in, k, key=key)
        vals = comm.sparse_decode_values(spec, buf)
        r_new = d_in.at[buf.indices].add(-vals)
        decoded = comm.sparse_decode(spec, buf, n)
    else:
        buf = comm.encode(spec, d_in, key=key)
        decoded = comm.decode(spec, buf)
        r_new = d_in - decoded
    got = np.asarray(r_new + decoded)
    want = np.asarray(d_in)
    # float cancellation only: (a - v) + v vs a, ~1 ulp of the magnitudes
    tol = 1e-5 * max(1.0, np.abs(want).max())
    np.testing.assert_allclose(got, want, rtol=0, atol=tol)


# ---------------------------------------------------------------------------
# Cross-entropy invariants
# ---------------------------------------------------------------------------

@_settings
@given(b=st.integers(1, 4), s=st.integers(1, 8), v=st.integers(2, 33),
       seed=st.integers(0, 999))
def test_ce_matches_naive(b, s, v, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(b, s, v)).astype(np.float32) * 3)
    labels = jnp.asarray(rng.integers(0, v, size=(b, s)))
    got = common.softmax_cross_entropy(logits, labels)
    lp = jax.nn.log_softmax(logits, axis=-1)
    want = -jnp.mean(jnp.take_along_axis(lp, labels[..., None], -1))
    # fp32: one-hot-contraction vs take_along_axis differ by a few ulp
    np.testing.assert_allclose(float(got), float(want), rtol=5e-5,
                               atol=1e-6)


@_settings
@given(shift=st.floats(-50, 50), seed=st.integers(0, 99))
def test_ce_shift_invariance(shift, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, 3, 17)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 17, size=(2, 3)))
    a = common.softmax_cross_entropy(logits, labels)
    b = common.softmax_cross_entropy(logits + shift, labels)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Sharding policy invariants
# ---------------------------------------------------------------------------

@_settings
@given(dims=st.lists(st.sampled_from([1, 3, 8, 16, 64, 256]),
                     min_size=2, max_size=4),
       mode=st.sampled_from(["auto", "replicate", "seq2d", "dp2d",
                             "head_dim"]))
def test_policy_specs_always_valid(dims, mode):
    """Resolved specs never reuse a mesh axis and always divide the dim."""
    import os
    if jax.device_count() < 4:
        # policy math is device-independent; build a fake mesh via
        # make_mesh on available devices if possible
        return
    from repro.configs.base import ModelConfig
    from repro.launch.sharding import MeshPolicy, _axis_size
    mesh = jax.make_mesh(
        (2, 2), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = ModelConfig(attn_shard=mode, n_heads=4, n_kv_heads=4)
    pol = MeshPolicy(mesh, cfg)
    names = ["batch", "seq", "heads", "ffn"][:len(dims)]
    spec = pol.spec(tuple(dims), tuple(names))
    used = []
    for dim, ax in zip(dims, tuple(spec)):
        if ax is None:
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        assert dim % _axis_size(mesh, axes) == 0
        for a in axes:
            assert a not in used
            used.append(a)
