"""Expert padding (H4): padded MoE == unpadded MoE, bit for bit in routing."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig
from repro.models import mlp


def _cfg(pad_to=0):
    return ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                       d_ff=48, vocab_size=64,
                       pattern=(LayerSpec("attn", "moe"),),
                       moe=MoEConfig(n_experts=6, top_k=2, n_shared=1,
                                     d_expert=48, capacity_factor=8.0,
                                     pad_to=pad_to),
                       exit_layer=1, compute_dtype="float32")


def test_padded_moe_matches_unpadded():
    cfg0, cfg1 = _cfg(0), _cfg(8)
    p0 = mlp.init_moe(jax.random.PRNGKey(0), cfg0)
    p1 = mlp.init_moe(jax.random.PRNGKey(0), cfg1)
    # graft the real experts' weights so both compute the same function
    p1 = dict(p1)
    p1["router"] = p0["router"]
    p1["experts"] = jax.tree.map(
        lambda pad, real: pad.at[:real.shape[0]].set(real),
        p1["experts"], p0["experts"])
    p1["shared"] = p0["shared"]

    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y0, aux0 = mlp.apply_moe(p0, x, cfg0)
    y1, aux1 = mlp.apply_moe(p1, x, cfg1)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux1["load_balance"]),
                               float(aux0["load_balance"]), rtol=1e-6)


def test_pad_experts_receive_no_tokens_and_no_grads():
    cfg = _cfg(8)
    p = mlp.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))

    def loss(p):
        y, aux = mlp.apply_moe(p, x, cfg)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(p)
    for name in ("gate", "up", "down"):
        pad_grads = g["experts"][name][cfg.moe.n_experts:]
        assert float(jnp.max(jnp.abs(pad_grads))) == 0.0, name
