"""FedConfig validation (configs/base.py) and the CLI contract: every
rejection rule in ``FedConfig.validate()`` has exactly one test here,
and every FedConfig field must be reachable from the launch/train.py
command line (or be explicitly exempted below) so the config and the
driver cannot drift apart silently."""

import dataclasses

import pytest

from repro.configs.base import FedConfig
from repro.launch.train import build_parser


def _cfg(**kw) -> FedConfig:
    return FedConfig(n_devices=4, n_simple=2, rounds=1, **kw)


# ---------------------------------------------------------------------------
# validate(): one test per rejection message
# ---------------------------------------------------------------------------

def test_valid_config_passes():
    fed = _cfg()
    fed.validate()  # explicit call is idempotent with __post_init__


def test_rejects_unknown_algorithm():
    with pytest.raises(ValueError, match="unknown algorithm 'fedavg'"):
        _cfg(algorithm="fedavg")


def test_rejects_unknown_agg_engine():
    with pytest.raises(ValueError, match="unknown agg_engine 'sparse'"):
        _cfg(agg_engine="sparse")


@pytest.mark.parametrize("bad", [0, -128, 100])
def test_rejects_bad_agg_block_n(bad):
    with pytest.raises(ValueError,
                       match="agg_block_n must be a positive multiple of 128"):
        _cfg(agg_block_n=bad)


def test_rejects_bad_agg_stream_dtype():
    with pytest.raises(ValueError,
                       match="agg_stream_dtype must be float32 or"):
        _cfg(agg_stream_dtype="float16")


def test_rejects_bad_cohort_chunk_string():
    with pytest.raises(ValueError,
                       match="cohort_chunk must be an int or 'auto'"):
        _cfg(cohort_chunk="all")


def test_rejects_unknown_comm_dtype():
    # delegated to WireSpec — one source of truth for the wire dtype set
    with pytest.raises(ValueError, match="wire dtype must be one of"):
        _cfg(comm_dtype="float16")


def test_rejects_bad_quant_block():
    # delegated to WireSpec: one f32 scale group must never cross the
    # flat layout's 128-lane alignment
    with pytest.raises(ValueError,
                       match="quant_block must divide the lane alignment"):
        _cfg(comm_dtype="int8", quant_block=96)


def test_rejects_int8_on_tree_engine():
    with pytest.raises(ValueError,
                       match="comm_dtype=int8 requires agg_engine='flat'"):
        _cfg(comm_dtype="int8", agg_engine="tree")


@pytest.mark.parametrize("bad", [0.0, -0.25, 1.5])
def test_rejects_bad_topk_frac(bad):
    # delegated to WireSpec — one source of truth for the sparsity knob
    with pytest.raises(ValueError, match="topk_frac must be in"):
        _cfg(topk_frac=bad)


def test_rejects_stochastic_rounding_on_f32_wire():
    with pytest.raises(ValueError,
                       match="stochastic rounding requires a lossy wire"):
        _cfg(stochastic_rounding=True)


def test_rejects_error_feedback_on_lossless_wire():
    # f32 + dense: the residual would be identically zero
    with pytest.raises(ValueError,
                       match="error_feedback requires a lossy upload"):
        _cfg(error_feedback=True)
    _cfg(error_feedback=True, comm_dtype="int8")        # lossy: fine
    _cfg(error_feedback=True, topk_frac=0.5)            # sparse: fine


def test_rejects_compressed_uploads_on_tree_engine():
    with pytest.raises(ValueError,
                       match="compressed uploads .* require.*flat"):
        _cfg(topk_frac=0.5, agg_engine="tree")
    with pytest.raises(ValueError,
                       match="compressed uploads .* require.*flat"):
        _cfg(comm_dtype="bfloat16", stochastic_rounding=True,
             agg_engine="tree")


def test_rejects_negative_async_lag():
    with pytest.raises(ValueError, match="async_lag must be >= 0"):
        _cfg(async_lag=-1)


def test_rejects_unknown_async_staleness():
    with pytest.raises(ValueError,
                       match="async_staleness must be 'poly' or 'none'"):
        _cfg(async_staleness="linear")


def test_rejects_negative_async_decay():
    with pytest.raises(ValueError, match="async_decay must be >= 0"):
        _cfg(async_decay=-0.5)


def test_rejects_unknown_variance_reduction():
    with pytest.raises(ValueError,
                       match="variance_reduction must be 'none' or"):
        _cfg(variance_reduction="svrg")


def test_rejects_unknown_state_store_backend():
    with pytest.raises(ValueError,
                       match="state_store_backend must be one of"):
        _cfg(state_store_backend="gpu")


def test_rejects_scaffold_with_nonpositive_lr():
    with pytest.raises(ValueError,
                       match="variance_reduction='scaffold' requires lr > 0"):
        _cfg(variance_reduction="scaffold", lr=0.0)


def test_replace_reruns_validation():
    """dataclasses.replace re-triggers __post_init__ -> validate(), so a
    config mutated after construction hits the same wall as the CLI."""
    fed = _cfg()
    with pytest.raises(ValueError, match="unknown agg_engine"):
        dataclasses.replace(fed, agg_engine="sparse")


# ---------------------------------------------------------------------------
# CLI drift: every FedConfig field has a launch/train.py flag (or is
# explicitly exempted here, with the reason)
# ---------------------------------------------------------------------------

# field -> flag, where the flag name is not the mechanical --kebab-case
ALIASES = {
    "n_devices": "--clients",
    "iid": "--non-iid",                 # inverted boolean
    "dirichlet_alpha": "--alpha",
    "async_staleness": "--staleness",
    "async_decay": "--staleness-decay",
}

# fields deliberately NOT exposed as flags (keep this list honest: a new
# field lands here only with a reason, otherwise add the flag)
EXEMPT = {
    "n_simple": "derived as clients // 2 (the paper's 50/50 split)",
    "clip_norm": "Appendix A constant (10.0) — not an experiment knob",
    "skip_nan_devices": "Appendix A protocol constant, always on",
    "prox_mu": "beyond-paper FedProx term, library-only for now",
}


def test_every_fed_config_field_has_a_cli_flag():
    flags = set()
    for action in build_parser()._actions:
        flags.update(action.option_strings)

    missing = []
    for field in dataclasses.fields(FedConfig):
        if field.name in EXEMPT:
            assert field.name not in ALIASES
            continue
        flag = ALIASES.get(field.name,
                           "--" + field.name.replace("_", "-"))
        if flag not in flags:
            missing.append(f"{field.name} (expected {flag})")
    assert not missing, (
        "FedConfig fields without a launch/train.py flag (add the flag "
        f"or an EXEMPT entry with a reason): {missing}")


def test_exempt_list_matches_reality():
    """Exempted fields must still exist on the dataclass (catches a
    rename leaving a stale exemption behind)."""
    names = {f.name for f in dataclasses.fields(FedConfig)}
    stale = set(EXEMPT) - names
    assert not stale, f"EXEMPT names no longer on FedConfig: {stale}"


def test_cli_flags_construct_a_valid_config():
    """The parser's defaults round-trip into a FedConfig that passes
    validate() via build_trainer's construction path."""
    args = build_parser().parse_args([])
    fed = FedConfig(
        n_devices=args.clients, n_simple=args.clients // 2,
        participation=args.participation, rounds=args.rounds,
        local_epochs=args.local_epochs, lr=args.lr,
        batch_size=args.batch_size, iid=not args.non_iid,
        dirichlet_alpha=args.alpha, algorithm=args.algorithm,
        seed=args.seed, cohort_chunk=args.cohort_chunk,
        sample_uniform=args.sample_uniform,
        agg_engine=args.agg_engine, agg_block_n=args.agg_block_n,
        agg_stream_dtype=args.agg_stream_dtype,
        agg_memory_budget_mb=args.agg_memory_budget_mb,
        comm_dtype=args.comm_dtype, quant_block=args.quant_block,
        topk_frac=args.topk_frac,
        stochastic_rounding=args.stochastic_rounding,
        error_feedback=args.error_feedback,
        async_lag=args.async_lag, async_staleness=args.staleness,
        async_decay=args.staleness_decay,
        variance_reduction=args.variance_reduction,
        state_store_backend=args.state_store_backend)
    fed.validate()
