"""Cohort sampler: purity, unbiasedness, uniform super-cohort routing,
and the per-client state matrix (billing parity vs the retired
VersionCache dict)."""

import numpy as np

from repro.core import comm
from repro.core.client_state import ClientStateMatrix
from repro.core.sampling import (CohortSampler, draw_without_replacement,
                                 round_rng)


# ---------------------------------------------------------------------------
# draw_without_replacement
# ---------------------------------------------------------------------------

def test_draw_is_sorted_unique_in_range():
    for n, k in ((10, 10), (100, 30), (10_000, 50), (7, 0)):
        ids = draw_without_replacement(round_rng(0, 0), n, k)
        assert ids.shape == (k,) and ids.dtype == np.int64
        assert (np.diff(ids) > 0).all()          # sorted, no repeats
        assert (ids >= 0).all() and (ids < n).all()


def test_draw_rejects_bad_k():
    with np.testing.assert_raises(ValueError):
        draw_without_replacement(round_rng(0, 0), 10, 11)
    with np.testing.assert_raises(ValueError):
        draw_without_replacement(round_rng(0, 0), 10, -1)


def test_rejection_path_is_unbiased_chi_square():
    """The sparse (batched-rejection) path must be uniform over ids — an
    order-dependent dedupe bug would skew the marginal.  Chi-square on
    the pooled selection counts, normal-approximation threshold (no
    scipy): stat ~ chi2(df) => mean df, var 2df; 5 sigma is a ~1e-6
    false-positive gate."""
    n, k, rounds = 500, 20, 4000      # 4k << n: always the sparse path
    counts = np.zeros(n)
    for r in range(rounds):
        ids = draw_without_replacement(round_rng(123, r), n, k)
        counts[ids] += 1
    expected = rounds * k / n
    stat = float(((counts - expected) ** 2 / expected).sum())
    df = n - 1
    assert stat < df + 5 * np.sqrt(2 * df), (stat, df)


# ---------------------------------------------------------------------------
# CohortSampler
# ---------------------------------------------------------------------------

def test_plan_is_pure_in_seed_and_round():
    s = CohortSampler(n_devices=100, n_simple=50, participation=0.1, seed=9)
    a, b = s.plan(5), s.plan(5)
    np.testing.assert_array_equal(a.simple_ids, b.simple_ids)
    np.testing.assert_array_equal(a.complex_ids, b.complex_ids)
    # call order is irrelevant (no sequential stream): a second sampler
    # visiting rounds backwards draws the same plans
    s2 = CohortSampler(n_devices=100, n_simple=50, participation=0.1, seed=9)
    for r in (7, 3, 5):
        np.testing.assert_array_equal(s2.plan(r).simple_ids,
                                      s.plan(r).simple_ids)
    # different rounds / seeds give different cohorts
    assert not np.array_equal(s.plan(0).simple_ids, s.plan(1).simple_ids) \
        or not np.array_equal(s.plan(0).complex_ids, s.plan(1).complex_ids)


def test_stratified_capacities_match_trainer_rule():
    s = CohortSampler(n_devices=100, n_simple=50, participation=0.1, seed=0)
    assert (s.cap_simple, s.cap_complex) == (5, 5)
    assert s.plan(0).all_real
    # tiny populations floor at 1 per arch (the old trainer's rule)
    s = CohortSampler(n_devices=4, n_simple=2, participation=0.01, seed=0)
    assert (s.cap_simple, s.cap_complex) == (1, 1)


def test_uniform_plan_routes_and_pads():
    s = CohortSampler(n_devices=100, n_simple=50, participation=0.1,
                      seed=11, uniform=True)
    assert s.k_super == 10
    for r in range(20):
        p = s.plan(r)
        # realized split sums to the super-cohort size
        assert p.n_real_simple + p.n_real_complex == s.k_super
        # routing: real simple slots < n_simple, real complex slots >=
        assert (p.simple_ids[p.simple_real] < 50).all()
        assert (p.complex_ids[p.complex_real] >= 50).all()
        # real ids are distinct clients; pad slots wrap real ids
        rid = p.real_ids()
        assert np.unique(rid).size == rid.size
        assert np.isin(p.simple_ids[~p.simple_real],
                       np.concatenate([p.simple_ids[p.simple_real],
                                       [0]])).all()


def test_uniform_participation_is_unbiased_chi_square():
    """The paper's protocol: every client equally likely per round,
    regardless of architecture.  Chi-square over participation counts
    accumulated in the client-state matrix."""
    n, rounds = 200, 3000
    s = CohortSampler(n_devices=n, n_simple=100, participation=0.05,
                      seed=42, uniform=True)
    m = ClientStateMatrix(n)
    for r in range(rounds):
        m.record_round(s.plan(r).real_ids(), r)
    counts = m.column("participation")
    expected = rounds * s.k_super / n
    stat = float(((counts - expected) ** 2 / expected).sum())
    df = n - 1
    assert stat < df + 5 * np.sqrt(2 * df), (stat, df)


def test_uniform_equals_stratified_at_full_participation():
    """At participation=1.0 both modes enumerate the whole population:
    the bit-parity hook for the mode switch."""
    kw = dict(n_devices=20, n_simple=8, participation=1.0, seed=5)
    s, u = CohortSampler(**kw), CohortSampler(uniform=True, **kw)
    for r in range(4):
        a, b = s.plan(r), u.plan(r)
        assert b.all_real
        np.testing.assert_array_equal(a.simple_ids, b.simple_ids)
        np.testing.assert_array_equal(a.complex_ids, b.complex_ids)


def test_state_dict_validation():
    s = CohortSampler(n_devices=100, n_simple=50, participation=0.1, seed=1)
    s.validate_state(s.state_dict())         # self-consistent
    s.validate_state(None)                   # pre-sampler checkpoint
    s.validate_state({})
    bad = dict(s.state_dict(), seed=2)
    with np.testing.assert_raises(ValueError):
        s.validate_state(bad)


# ---------------------------------------------------------------------------
# ClientStateMatrix
# ---------------------------------------------------------------------------

def test_record_round_and_histogram():
    m = ClientStateMatrix(10)
    m.record_round(np.array([1, 2, 3]), 0)
    m.record_round(np.array([2, 3, 4]), 1)
    assert m.tracked_clients() == 4
    assert m.participation_histogram() == {"0": 6, "1": 2, "2": 2}
    np.testing.assert_array_equal(m.column("last_round")[[1, 2, 4]],
                                  [0.0, 1.0, 1.0])
    assert m.column("last_round")[0] == -1.0     # never participated


def test_billing_parity_vs_version_cache():
    """The vectorized tag-compare must bill byte-for-byte like the
    retired per-client VersionCache dict on identical fetch sequences —
    including hit/miss tallies (the telemetry deltas)."""
    m = ClientStateMatrix(64)
    vc = comm.VersionCache()
    rng = np.random.default_rng(3)
    hits = misses = 0
    for r in range(50):
        ids = rng.choice(64, size=12, replace=False)
        tags = rng.integers(0, 5, size=12)
        ref = sum(vc.bill(int(c), float(t), 37.0)
                  for c, t in zip(ids, tags))
        got, h, mi = m.bill_downloads(ids, tags.astype(float), 37.0)
        assert got == ref
        hits += h
        misses += mi
    assert (hits, misses) == (vc.hits, vc.misses)


def test_billing_reset_forgets_versions():
    m = ClientStateMatrix(8)
    ids = np.arange(4)
    billed, _, _ = m.bill_downloads(ids, np.zeros(4), 10.0)
    assert billed == 40.0
    billed, _, _ = m.bill_downloads(ids, np.zeros(4), 10.0)
    assert billed == 0.0                         # all cached
    m.reset_version_tags()
    billed, _, _ = m.bill_downloads(ids, np.zeros(4), 10.0)
    assert billed == 40.0                        # history wiped


def test_load_matches_columns_by_name():
    m = ClientStateMatrix(5)
    m.record_round(np.array([0, 1]), 3)
    # a checkpoint written under a REORDERED schema restores by name
    cols = list(reversed(m.columns))
    payload = m.array[:, ::-1].copy()
    m2 = ClientStateMatrix(5)
    m2.load(payload, cols)
    np.testing.assert_array_equal(m2.array, m.array)
    with np.testing.assert_raises(ValueError):
        m2.load(payload, cols[:-1])              # width mismatch
    with np.testing.assert_raises(ValueError):
        ClientStateMatrix(6).load(payload, cols)  # size mismatch


def test_gather_scatter_roundtrip():
    m = ClientStateMatrix(6)
    ids = np.array([1, 4, m.sentinel])           # sentinel row is scratch
    rows = m.gather(ids)
    rows[:, 0] = 9.0
    m.scatter(ids, rows)
    np.testing.assert_array_equal(m.column("participation")[[1, 4]],
                                  [9.0, 9.0])
    assert m.tracked_clients() == 2              # sentinel masked out
