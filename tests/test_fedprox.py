"""FedProx proximal term (beyond-paper option) behaves as specified."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer, make_client_trainer
from repro.data.federated import iid_split
from repro.data.synthetic import synthetic_lm

CFG = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                  vocab_size=64, pattern=(LayerSpec("attn"),), exit_layer=1,
                  compute_dtype="float32")


def _drift(mu):
    fed = FedConfig(n_devices=2, n_simple=1, participation=1.0,
                    local_epochs=3, batch_size=4, lr=0.2, prox_mu=mu)
    adapter = LMAdapter(CFG)
    params = adapter.init(jax.random.PRNGKey(0))
    data = {"tokens": jnp.asarray(
        synthetic_lm(16, 16, 64, seed=1)["tokens"])}
    train = make_client_trainer(adapter.loss_complex, fed)
    new, _ = train(params, data, jax.random.PRNGKey(2))
    return float(sum(
        jnp.sum(jnp.square(a - b)) for a, b in
        zip(jax.tree.leaves(new), jax.tree.leaves(params))))


def test_prox_term_limits_client_drift():
    d0 = _drift(0.0)
    d_strong = _drift(10.0)
    assert d_strong < d0, (d_strong, d0)


def test_prox_composes_with_fedhen():
    fed = FedConfig(n_devices=4, n_simple=2, participation=0.5, rounds=2,
                    local_epochs=1, batch_size=4, algorithm="fedhen",
                    prox_mu=0.1)
    data = synthetic_lm(32, 16, 64, seed=1)
    shards = [{"tokens": jnp.asarray(s["tokens"])}
              for s in iid_split(data, 4, seed=2)]
    tr = FederatedTrainer(LMAdapter(CFG), fed, shards)
    m = tr.run_round()
    assert np.isfinite(m["loss_complex"]) and np.isfinite(m["loss_simple"])
