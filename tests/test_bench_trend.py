"""Unit tests for the bench-trend gate's compare() — in particular the
zero-baseline byte slack, whose old ``endswith("bytes")`` match silently
skipped ``bytes_per_round``-style metrics."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "benchmarks"))

from bench_trend import ZERO_SLACK_BYTES, compare  # noqa: E402


def _payload(bench, rows):
    return {"bench": bench, "rows": rows}


def test_compare_flags_cost_regression():
    base = _payload("client_scale", [{"label": "n1e3", "state_bytes": 1000}])
    fresh = _payload("client_scale", [{"label": "n1e3", "state_bytes": 2000}])
    assert compare(base, fresh, 0.10)
    assert not compare(base, base, 0.10)


def test_compare_flags_savings_drop_and_missing_row():
    base = _payload("comm_savings", [
        {"arch": "simple", "comm_dtype": "f16",
         "bytes_per_round": 100.0, "ratio_vs_f32": 2.0},
        {"arch": "complex", "comm_dtype": "f16",
         "bytes_per_round": 100.0, "ratio_vs_f32": 2.0}])
    fresh = _payload("comm_savings", [
        {"arch": "simple", "comm_dtype": "f16",
         "bytes_per_round": 100.0, "ratio_vs_f32": 1.0}])
    failures = compare(base, fresh, 0.10)
    assert any("ratio_vs_f32" in f for f in failures)
    assert any("missing" in f for f in failures)


def test_zero_baseline_slack_covers_infix_bytes_tokens():
    """Token match, not suffix match: a 0 -> small-jitter move in
    ``bytes_down_per_round`` must get the same absolute slack as
    ``temp_bytes`` (relative tolerance on a 0 baseline is 0)."""
    base = _payload("comm_savings", [
        {"arch": "simple", "comm_dtype": "f16",
         "bytes_per_round": 0.0, "bytes_down_per_round": 0.0,
         "bytes_up_per_round": 0.0, "ratio_vs_f32": 1.0}])
    jitter = float(ZERO_SLACK_BYTES // 2)
    fresh = _payload("comm_savings", [
        {"arch": "simple", "comm_dtype": "f16",
         "bytes_per_round": jitter, "bytes_down_per_round": jitter,
         "bytes_up_per_round": jitter, "ratio_vs_f32": 1.0}])
    assert compare(base, fresh, 0.10) == []
    # but a real regression still trips past the slack
    fresh["rows"][0]["bytes_down_per_round"] = float(ZERO_SLACK_BYTES * 2)
    failures = compare(base, fresh, 0.10)
    assert any("bytes_down_per_round" in f for f in failures)


def test_compare_ignores_metrics_absent_from_baseline():
    # a baseline that predates a metric must not gate it
    base = _payload("client_scale", [{"label": "n1e3"}])
    fresh = _payload("client_scale", [{"label": "n1e3",
                                       "state_bytes": 10**9}])
    assert compare(base, fresh, 0.10) == []


def test_compare_rejects_kind_mismatch():
    a = _payload("client_scale", [])
    b = _payload("comm_savings", [])
    assert compare(a, b, 0.10)
    assert compare(_payload("nonsense", []), _payload("nonsense", []), 0.10)
