"""Compressed wire v2 end-to-end (error feedback + stochastic rounding +
top-k uploads): the dense/deterministic bit-identity pin, a closed-form
residual + server-fold oracle, SCAFFOLD composition, async engine parity,
NaN/pad-slot residual hygiene, and checkpoint resume with the
``__ef_store__`` sidecar."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import restore_trainer, save_trainer
from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core import async_rounds, comm, flatten
from repro.core.federated import (_WIRE_KEY_TAG, FederatedTrainer,
                                  make_client_trainer)
from repro.data.federated import iid_split
from repro.data.synthetic import synthetic_lm

TINY = ModelConfig(n_layers=4, d_model=32, n_heads=2, n_kv_heads=2,
                   d_ff=64, vocab_size=64, pattern=(LayerSpec("attn"),),
                   exit_layer=2, compute_dtype="float32")

# the full stack the benchmark gate ships: int8 payload, 1/16 top-k,
# stochastic rounding, error feedback
FULL = dict(comm_dtype="int8", quant_block=64, topk_frac=1 / 16,
            stochastic_rounding=True, error_feedback=True)


def _make_trainer(algorithm="fedhen", *, n_devices=4, participation=1.0,
                  **fed_kw):
    fed = FedConfig(n_devices=n_devices, n_simple=n_devices // 2,
                    participation=participation, rounds=3, local_epochs=1,
                    lr=0.1, batch_size=4, algorithm=algorithm, seed=0,
                    **fed_kw)
    data = synthetic_lm(n_devices * 8, 16, TINY.vocab_size, seed=1)
    shards = iid_split(data, fed.n_devices, seed=2)
    from repro.core.adapters import LMAdapter
    return FederatedTrainer(LMAdapter(TINY), fed, shards)


def _max_abs_diff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# The bit-identity pin: every v2 knob at its default keeps the pre-v2
# protocol byte-identical (tol=0)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ["fedhen", "decouple"])
def test_default_knobs_bit_identical_to_plain_wire(algorithm):
    """topk_frac=1.0 + deterministic rounding + no EF must trace the
    SAME upload program as before wire v2 existed: two rounds, tol=0."""
    plain = _make_trainer(algorithm)
    pinned = _make_trainer(algorithm, topk_frac=1.0,
                           stochastic_rounding=False, error_feedback=False)
    assert not pinned.wire.uses_deltas
    assert pinned.ef_store is None
    for _ in range(2):
        m_plain = plain.run_round()
        m_pinned = pinned.run_round()
    assert m_plain == m_pinned
    assert _max_abs_diff(plain.server.complex, pinned.server.complex) == 0.0
    assert plain.total_bytes == pinned.total_bytes


def test_near_dense_topk_matches_dense_fold():
    """topk_frac high enough to keep every parameter, on the exact f32
    wire: the delta-space scatter fold must reproduce the dense
    params-space fold up to float summation order."""
    dense = _make_trainer("fedhen")
    sparse = _make_trainer("fedhen", topk_frac=0.9999)
    assert sparse.wire.uses_deltas
    assert sparse.k_top_complex >= sparse.layout.n_params
    for _ in range(2):
        dense.run_round()
        sparse.run_round()
    d = _max_abs_diff(dense.server.complex, sparse.server.complex)
    assert d <= 1e-5, d


# ---------------------------------------------------------------------------
# Closed-form oracle: residual rows and the folded server, one client
# per population (pins packing, key derivation, fold weighting)
# ---------------------------------------------------------------------------

def test_ef_oracle_single_client_populations():
    """One simple + one complex client at full participation under the
    full int8 + top-k + stochastic + EF stack.  The round's residual
    rows must equal the hand-computed ``(d + r) - decode(encode(d + r))``
    and the server must equal the scatter-folded decoded deltas — with
    ``y`` and the encode keys re-derived from scratch, pinning the
    per-client RNG derivation (``fold_in(client_key, _WIRE_KEY_TAG)``)
    and the delta-fold identity."""
    tr = _make_trainer("fedhen", n_devices=2, **FULL)
    fed, layout, wire = tr.fed, tr.layout, tr.wire
    server0 = jax.tree.map(jnp.copy, tr.server.complex)
    plan = tr.sampler.plan(0)
    assert list(plan.simple_ids) == [0] and list(plan.complex_ids) == [1]

    tr.run_round()

    # replicate broadcast + training exactly (same derivation as the
    # SCAFFOLD oracle in tests/test_scaffold.py)
    key = jax.random.PRNGKey(fed.seed * 100003 + 0)
    rs, rc = jax.random.split(key)
    bc = comm.broadcast_roundtrip(wire, layout, server0)
    x_flat = flatten.pack(layout, bc).astype(jnp.float32)
    adapter = tr.adapter
    shard = lambda i: jax.tree.map(lambda v: v[0], tr._gather([i]))

    train_s = make_client_trainer(adapter.loss_simple, fed)
    y_s, _ = train_s(bc, shard(0), jax.random.fold_in(rs, 0))
    train_c = make_client_trainer(adapter.loss_side, fed)
    y_c, _ = train_c(bc, shard(1), jax.random.fold_in(rc, 0))

    d_s = flatten.pack(layout, y_s).astype(jnp.float32) - x_flat
    d_c = flatten.pack(layout, y_c).astype(jnp.float32) - x_flat
    # round 1: residual starts at zero, d_in == d
    enc_s = jax.random.fold_in(jax.random.fold_in(rs, 0), _WIRE_KEY_TAG)
    enc_c = jax.random.fold_in(jax.random.fold_in(rc, 0), _WIRE_KEY_TAG)
    buf_s = comm.sparse_encode(wire, d_s, tr.k_top_simple, key=enc_s)
    buf_c = comm.sparse_encode(wire, d_c, tr.k_top_complex, key=enc_c)
    dhat_s = comm.sparse_decode(wire, buf_s, layout.n_flat)
    dhat_c = comm.sparse_decode(wire, buf_c, layout.n_flat)

    # residual rows: r' = d - scattered decode, exactly
    want_r_s = np.asarray(d_s.at[buf_s.indices].add(
        -comm.sparse_decode_values(wire, buf_s)))
    want_r_c = np.asarray(d_c.at[buf_c.indices].add(
        -comm.sparse_decode_values(wire, buf_c)))
    # the oracle recomputes y outside the round jit, so XLA may fuse the
    # delta subtract differently — rows agree to one f32 ulp of the
    # parameter magnitudes, not bit-exactly
    rows = tr.ef_store.to_array()
    assert float(np.max(np.abs(rows[0] - want_r_s))) <= 1e-7
    assert float(np.max(np.abs(rows[1] - want_r_c))) <= 1e-7
    # ... and the ef_scale column carries their norms
    np.testing.assert_allclose(
        tr.client_state.column("ef_scale")[:2],
        [np.linalg.norm(want_r_s), np.linalg.norm(want_r_c)], rtol=1e-5)

    # server fold: in-M positions average both decoded deltas around x,
    # out-of-M positions take the complex client's alone (d_s is zero
    # outside M, so its top-k never ships signal there)
    mask = np.asarray(tr.flat_mask)
    want_flat = np.where(
        mask, np.asarray(x_flat) + (np.asarray(dhat_s)
                                    + np.asarray(dhat_c)) / 2.0,
        np.asarray(x_flat) + np.asarray(dhat_c))
    got_flat = np.asarray(flatten.pack(layout, tr.server.complex))
    live = np.zeros(layout.n_flat, bool)
    for slot in layout.slots:
        live[slot.offset:slot.offset + slot.size] = True
    np.testing.assert_allclose(got_flat[live], want_flat[live],
                               rtol=1e-5, atol=1e-6)


def test_ef_residual_feeds_the_next_round():
    """Round 2's upload is ``d + r``: zero the store by hand and the
    second round must diverge from the unmodified run."""
    a = _make_trainer("fedhen", **FULL)
    b = _make_trainer("fedhen", **FULL)
    a.run_round()
    b.run_round()
    assert _max_abs_diff(a.server.complex, b.server.complex) == 0.0
    b.ef_store.load(np.zeros_like(b.ef_store.to_array()))
    a.run_round()
    b.run_round()
    assert _max_abs_diff(a.server.complex, b.server.complex) > 0.0


# ---------------------------------------------------------------------------
# SCAFFOLD composition: the cv path is untouched by the compressed wire
# ---------------------------------------------------------------------------

def test_scaffold_composes_with_ef_wire():
    """Control variates are computed client-side from (x, y) — the
    round-1 cv rows under the EF wire must be bit-identical to the
    dense-wire SCAFFOLD run (same broadcast, same training), while the
    server models diverge (compressed uploads)."""
    dense = _make_trainer("fedhen", comm_dtype="int8", quant_block=64,
                          variance_reduction="scaffold")
    ef = _make_trainer("fedhen", variance_reduction="scaffold", **FULL)
    dense.run_round()
    ef.run_round()
    np.testing.assert_array_equal(dense.cv_store.to_array(),
                                  ef.cv_store.to_array())
    np.testing.assert_array_equal(np.asarray(dense.cv_global),
                                  np.asarray(ef.cv_global))
    assert _max_abs_diff(dense.server.complex, ef.server.complex) > 0.0
    # both stores stay finite over further rounds
    ef.run_round()
    assert np.isfinite(ef.cv_store.to_array()).all()
    assert np.isfinite(ef.ef_store.to_array()).all()


# ---------------------------------------------------------------------------
# Async engine: lag=0 bit-parity, lag>0 liveness
# ---------------------------------------------------------------------------

def test_async_lag0_bit_parity_under_full_stack():
    sync = _make_trainer("fedhen", n_devices=6, cohort_chunk=1, **FULL)
    tr = _make_trainer("fedhen", n_devices=6, cohort_chunk=1, **FULL)
    eng = async_rounds.AsyncRoundEngine(tr, lag=0)
    for _ in range(2):
        m_sync = sync.run_round()
        m_async = eng.run_round()
    assert m_sync == m_async
    assert _max_abs_diff(sync.server.complex, tr.server.complex) == 0.0
    np.testing.assert_array_equal(sync.ef_store.to_array(),
                                  tr.ef_store.to_array())
    assert sync.total_bytes == tr.total_bytes


def test_async_lag1_full_stack_stays_finite():
    tr = _make_trainer("fedhen", n_devices=6, cohort_chunk=1,
                       async_lag=1, **FULL)
    assert tr.async_engine is not None
    for _ in range(3):
        m = tr.run_round()
        assert np.isfinite(m["loss_simple"]) and np.isfinite(
            m["loss_complex"])
    assert np.isfinite(tr.ef_store.to_array()).all()
    assert tr.ef_store.scattered_bytes > 0
    assert float(tr.client_state.column("ef_scale").sum()) > 0.0


# ---------------------------------------------------------------------------
# Row hygiene: NaN devices and uniform-sampling pad slots
# ---------------------------------------------------------------------------

class _NanAdapter:
    """Tiny real-training adapter (mirrors tests/test_scaffold.py):
    params drift toward each client's data mean, so a NaN shard produces
    a NaN-trained device whose residual row must be left untouched."""

    def init(self, key):
        return {"a": jnp.zeros((4,), jnp.float32),
                "b": jnp.zeros((4,), jnp.float32)}

    def subnet_mask(self, params):
        return {"a": jnp.asarray(True), "b": jnp.asarray(False)}

    @staticmethod
    def _loss(params, batch):
        x = batch["x"]                       # (B, 4)
        err_a = params["a"][None] - x
        err_b = params["b"][None] - 2.0 * x
        return jnp.mean(err_a ** 2) + jnp.mean(err_b ** 2)

    loss_simple = loss_complex = loss_side = _loss


def test_nan_device_keeps_previous_residual_row():
    fed = FedConfig(n_devices=4, n_simple=2, participation=1.0,
                    local_epochs=1, lr=0.1, batch_size=4,
                    algorithm="fedhen", seed=0, **FULL)
    rng = np.random.default_rng(0)
    shards = [{"x": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))}
              for _ in range(fed.n_devices)]
    shards[1]["x"] = shards[1]["x"].at[0, 0].set(jnp.nan)
    tr = FederatedTrainer(_NanAdapter(), fed, shards)
    m = tr.run_round()
    assert m["n_valid"] == fed.n_devices - 1
    rows = tr.ef_store.to_array()
    assert np.isfinite(rows).all()
    np.testing.assert_array_equal(rows[1], 0.0)   # kept its (zero) row
    assert np.isfinite(jax.tree.leaves(tr.server.complex)[0]).all()
    assert float(tr.client_state.column("ef_scale")[1]) == 0.0


def test_uniform_pad_slots_never_scatter_residuals():
    tr = _make_trainer("fedhen", n_devices=8, participation=0.25,
                       sample_uniform=True, **FULL)
    for r in range(20):
        plan = tr.sampler.plan(tr.server.round)
        if not plan.all_real:
            break
        tr.run_round()
    else:
        pytest.fail("no uniform round with pad slots in 20 draws")
    before = tr.ef_store.to_array().copy()
    tr.run_round()
    after = tr.ef_store.to_array()
    real = set(int(i) for i in plan.real_ids())
    changed = {i for i in range(tr.fed.n_devices)
               if np.abs(after[i] - before[i]).max() > 0.0}
    assert changed <= real, (changed, real)
    assert changed, "no real row updated"


# ---------------------------------------------------------------------------
# Checkpoint: the residual store rides the __ef_store__ sidecar
# ---------------------------------------------------------------------------

def test_checkpoint_resume_reproduces_uninterrupted_ef_run(tmp_path):
    path = str(tmp_path / "ckpt.npz")
    a = _make_trainer("fedhen", **FULL)
    a.run_round()
    a.run_round()
    save_trainer(path, a)
    a.run_round()

    b = _make_trainer("fedhen", **FULL)
    restore_trainer(path, b)
    assert b.server.round == 2
    b.run_round()
    assert _max_abs_diff(a.server.complex, b.server.complex) == 0.0
    np.testing.assert_array_equal(a.ef_store.to_array(),
                                  b.ef_store.to_array())


def test_checkpoint_without_ef_sidecar_rejected(tmp_path):
    """Restoring a plain checkpoint into an EF trainer must fail loudly
    — silently zeroing the residuals would drop un-uploaded signal."""
    path = str(tmp_path / "ckpt.npz")
    plain = _make_trainer("fedhen")
    plain.run_round()
    save_trainer(path, plain)
    ef = _make_trainer("fedhen", **FULL)
    with pytest.raises(ValueError, match="no __ef_store__ sidecar"):
        restore_trainer(path, ef)
