"""Structured telemetry layer (repro/obs): event registry semantics,
round-phase span trees from both engines, client-health counters,
byte-ledger reconciliation against the trainer's accounting, the
no-op-sink bit-parity contract, and the JSONL -> report pipeline."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import async_rounds
from repro.core.federated import FederatedTrainer
from repro.obs import report as obs_report
from repro.obs import telemetry as obslib


class _ToyAdapter:
    """Tiny real-training adapter (mirrors tests/test_async.py): params
    drift toward each client's data mean, so rounds are cheap to compile
    and a NaN shard produces a NaN-trained device."""

    def init(self, key):
        return {"a": jnp.zeros((4,), jnp.float32),
                "b": jnp.zeros((4,), jnp.float32)}

    def subnet_mask(self, params):
        return {"a": jnp.asarray(True), "b": jnp.asarray(False)}

    @staticmethod
    def _loss(params, batch):
        x = batch["x"]
        err_a = params["a"][None] - x
        err_b = params["b"][None] - 2.0 * x
        return jnp.mean(err_a ** 2) + jnp.mean(err_b ** 2)

    loss_simple = loss_complex = loss_side = _loss

    def evaluate(self, params, batch):
        return {"acc_simple": jnp.mean(params["a"]),
                "acc_complex": jnp.mean(params["b"])}


def _shards(n_devices, seed=0, poison=None):
    rng = np.random.default_rng(seed)
    shards = [{"x": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))}
              for _ in range(n_devices)]
    if poison is not None:
        shards[poison]["x"] = shards[poison]["x"].at[0, 0].set(jnp.nan)
    return shards


def _make_trainer(telemetry=None, *, chunk=2, poison=None, **fed_kw):
    fed = FedConfig(n_devices=8, n_simple=4, participation=1.0,
                    local_epochs=1, lr=0.1, batch_size=4,
                    algorithm="fedhen", seed=0, cohort_chunk=chunk,
                    **fed_kw)
    return FederatedTrainer(_ToyAdapter(), fed, _shards(8, poison=poison),
                            telemetry=telemetry)


def _max_abs_diff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# Registry semantics (no jax involved)
# ---------------------------------------------------------------------------

def test_span_paths_nest():
    mem = obslib.MemorySink()
    tel = obslib.Telemetry([mem])
    with tel.span("outer"):
        with tel.span("inner", tag=3):
            tel.counter("c", 1)
        tel.point_span("logical")
    paths = [e.get("path") for e in mem.of_kind("span")]
    # spans emit on exit: inner closes first, then the logical point
    # span, then outer
    assert paths == ["outer/inner", "outer/logical", "outer"]
    inner = mem.named("inner")[0]
    assert inner["dur_s"] >= 0 and inner["tag"] == 3
    assert mem.named("logical")[0]["dur_s"] is None
    assert mem.named("c")[0]["value"] == 1
    # seq is emission order
    assert [e["seq"] for e in mem.events] == list(range(len(mem.events)))


def test_disabled_telemetry_emits_nothing():
    mem = obslib.MemorySink()
    tel = obslib.Telemetry([mem], enabled=False)
    with tel.span("x"):
        tel.counter("c", 1)
        tel.ledger("l", {"a": 1})
        tel.log("hi")
        tel.point_span("p")
    assert mem.events == []
    assert not obslib.NOOP.enabled  # the module singleton stays disabled


def test_jsonable_coerces_array_scalars():
    assert obslib.jsonable(jnp.float32(1.5)) == 1.5
    assert obslib.jsonable(np.int64(7)) == 7
    assert obslib.jsonable({"k": (np.float32(2.0),)}) == {"k": [2.0]}
    json.dumps(obslib.jsonable({"a": jnp.zeros(())}))  # must not raise


# ---------------------------------------------------------------------------
# Sync engine: span tree, counters, byte ledger
# ---------------------------------------------------------------------------

def test_sync_two_round_span_tree_and_ledgers():
    mem = obslib.MemorySink()
    tr = _make_trainer(obslib.Telemetry([mem]))
    tr.run_round()
    tr.run_round()

    # k=4 per population at chunk 2 -> 2 chunks each, 4 folds/round
    want_phases = (["round/sample_gather", "round/execute",
                    "round/broadcast"]
                   + [f"round/train-chunk[{t}]" for t in range(4)]
                   + ["round/fold", "round/finalize", "round"])
    for r in (0, 1):
        paths = [e["path"] for e in mem.of_kind("span")
                 if e["round"] == r and e["name"] not in
                 ("trace_lower", "compile")]
        assert paths == want_phases, (r, paths)
    # the compile split happens exactly once, on the first round
    assert [e["round"] for e in mem.named("trace_lower")] == [0]
    assert [e["round"] for e in mem.named("compile")] == [0]
    # and the roofline ledger rides the compiled first round (the toy
    # adapter has no matmuls, so assert on memory traffic, not flops)
    roof = mem.named("roofline")
    assert len(roof) == 1 and roof[0]["values"]["hbm_bytes"] > 0

    # chunk attributes: population split in scan order, staleness absent
    chunks0 = [e for e in mem.of_kind("span")
               if e["round"] == 0 and e["name"].startswith("train-chunk")]
    assert [c["population"] for c in chunks0] == \
        ["simple", "simple", "complex", "complex"]
    assert all("staleness" not in c for c in chunks0)

    # client health: clean run, no exclusions, chunk 2 divides k=4
    assert [e["value"] for e in mem.named("nan_excluded_devices")] == [0, 0]
    assert [e["value"] for e in mem.named("padding_weight0_clients")] == \
        [0, 0]

    # byte ledger: EXACT equality with the trainer's measured accounting
    ledgers = [e["values"] for e in mem.named("comm_bytes")]
    assert len(ledgers) == 2
    for i, led in enumerate(ledgers, start=1):
        assert led["down"] == tr.bytes_down_per_round
        assert led["up"] == tr.bytes_up_per_round
        assert led["cum_down"] == i * tr.bytes_down_per_round
        assert led["cum_up"] == i * tr.bytes_up_per_round
    assert ledgers[-1]["cum_total"] == tr.total_bytes

    # run_config ledger carries the engine dispatch's own attrs
    cfg = mem.named("run_config")[0]["values"]
    assert cfg["engine"] == "sync" and cfg["agg_engine"] == "flat"
    assert cfg["k_simple"] == 4 and cfg["n_chunks_complex"] == 2


def test_padding_counter_counts_weight0_slots():
    """k=3 per population at chunk 2 -> one zero-validity padding slot
    per population per round."""
    mem = obslib.MemorySink()
    fed = FedConfig(n_devices=6, n_simple=3, participation=1.0,
                    local_epochs=1, lr=0.1, batch_size=4,
                    algorithm="fedhen", seed=0, cohort_chunk=2)
    tr = FederatedTrainer(_ToyAdapter(), fed, _shards(6),
                          telemetry=obslib.Telemetry([mem]))
    tr.run_round()
    assert mem.named("padding_weight0_clients")[0]["value"] == 2


def test_nan_exclusion_counter():
    """A NaN-poisoned client shows up as nan_excluded_devices > 0 in the
    round it is sampled (participation=1.0 -> every round)."""
    mem = obslib.MemorySink()
    tr = _make_trainer(obslib.Telemetry([mem]), chunk=1, poison=1)
    tr.run_round()
    tr.run_round()
    values = [e["value"] for e in mem.named("nan_excluded_devices")]
    assert values == [1, 1]
    for leaf in jax.tree.leaves(tr.server.complex):
        assert np.isfinite(np.asarray(leaf)).all()


# ---------------------------------------------------------------------------
# Async engine: staleness histogram, cache counters, version-aware bytes
# ---------------------------------------------------------------------------

def test_async_lag1_span_tree_and_health():
    mem = obslib.MemorySink()
    tr = _make_trainer(obslib.Telemetry([mem]), async_lag=1)
    tr.run_round()
    tr.run_round()

    rounds = [e for e in mem.named("round")]
    assert [e["engine"] for e in rounds] == ["async", "async"]
    assert [e["lag"] for e in rounds] == [1, 1]

    # staleness histogram matches the fold schedule exactly:
    # round 0 clamps to all-fresh; round 1 has one 1-stale chunk
    hists = [e["values"] for e in mem.named("staleness_hist")]
    assert hists == [{"0": 4}, {"0": 3, "1": 1}]
    # and the first train-chunk of round 1 carries that staleness attr
    chunks1 = [e for e in mem.of_kind("span")
               if e["round"] == 1 and e["name"].startswith("train-chunk")]
    assert [c["staleness"] for c in chunks1] == [1, 0, 0, 0]

    # version-cache counters: round 0 all misses (8 clients); round 1
    # the stale chunk's clients (chunk=2) re-use their held version
    assert [e["value"] for e in mem.named("version_cache_miss")] == [8, 6]
    assert [e["value"] for e in mem.named("version_cache_hit")] == [0, 2]

    # byte ledger equals the engine's version-aware accounting
    eng = tr.async_engine
    led = [e["values"] for e in mem.named("comm_bytes")]
    assert led[-1]["down"] == eng.last_bytes_down
    assert led[-1]["up"] == eng.last_bytes_up
    assert led[-1]["cum_down"] == tr.total_bytes_down
    assert led[-1]["cum_total"] == tr.total_bytes
    # the stale chunk saved exactly its clients' downloads in round 1
    assert led[1]["down"] == led[0]["down"] - 2 * tr.per_simple_bytes


# ---------------------------------------------------------------------------
# The observation contract: sinks never steer the run
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("async_lag", [0, 1])
def test_noop_sink_run_bit_identical_to_telemetry_off(async_lag):
    off = _make_trainer(None, async_lag=async_lag)
    on = _make_trainer(obslib.Telemetry([obslib.NullSink()]),
                       async_lag=async_lag)
    m_off = [off.run_round() for _ in range(2)]
    m_on = [on.run_round() for _ in range(2)]
    assert m_off == m_on
    assert _max_abs_diff(off.server.complex, on.server.complex) == 0.0
    assert off.total_bytes == on.total_bytes


# ---------------------------------------------------------------------------
# run() logging + JSONL -> report pipeline
# ---------------------------------------------------------------------------

def test_run_log_line_format_bit_identical(capsys):
    """The legacy log line routed through a StdoutSink prints exactly
    the string the pre-telemetry log callback received."""
    legacy = []
    off = _make_trainer(None)
    off.run(2, eval_every=1, test_batch={"x": jnp.zeros((4, 4))},
            log=legacy.append)
    on = _make_trainer(obslib.Telemetry([obslib.StdoutSink()]))
    capsys.readouterr()
    on.run(2, eval_every=1, test_batch={"x": jnp.zeros((4, 4))})
    printed = capsys.readouterr().out.splitlines()
    assert printed == legacy
    assert all(line.startswith("round ") for line in printed)


def test_jsonl_roundtrip_and_report(tmp_path):
    path = str(tmp_path / "run.jsonl")
    tel = obslib.Telemetry([obslib.JsonlSink(path)])
    tr = _make_trainer(tel)
    tr.run(2, eval_every=1, test_batch={"x": jnp.zeros((4, 4))})
    tel.close()

    events = obslib.read_jsonl(path)
    assert events, "JSONL run log is empty"
    kinds = {e["kind"] for e in events}
    assert kinds >= {"span", "counter", "ledger", "log"}

    summary = obs_report.summarize(events)
    assert summary["rounds"]["n_rounds"] == 2
    assert summary["comm"]["cum_total"] == tr.total_bytes
    assert summary["health"]["nan_excluded_devices"] == 0
    assert summary["rounds"]["compile_s"] > 0
    # eval ledgers feed the trajectory; acc metrics count as reached
    # at-or-ABOVE the target, so an unreachable ceiling stays None
    summary_t = obs_report.summarize(events, target=1e9,
                                     target_metric="acc_simple")
    assert summary_t["progress"]["rounds_to_target"] is None
    rendered = obs_report.render(summary)
    for needle in ("telemetry run report", "-- rounds --", "-- comm --",
                   "-- client health --"):
        assert needle in rendered
    # the CLI entry point renders the same file without error
    assert "rounds: 2" in obs_report.report_path(path)


def test_report_rounds_to_target():
    """rounds_to_target: first eval round at or under the threshold."""
    events = [
        {"kind": "ledger", "name": "eval", "round": 1,
         "values": {"loss_complex": 0.9}},
        {"kind": "ledger", "name": "eval", "round": 2,
         "values": {"loss_complex": 0.4}},
        {"kind": "ledger", "name": "eval", "round": 3,
         "values": {"loss_complex": 0.2}},
    ]
    s = obs_report.summarize(events, target=0.5)
    assert s["progress"]["rounds_to_target"] == 2
    assert s["progress"]["final"] == 0.2
    s2 = obs_report.summarize(events, target=0.05)
    assert s2["progress"]["rounds_to_target"] is None


def test_report_rounds_to_target_acc_direction():
    """acc* metrics flip the comparison: reached at-or-ABOVE the target."""
    events = [
        {"kind": "ledger", "name": "eval", "round": 1,
         "values": {"acc_simple": 0.1}},
        {"kind": "ledger", "name": "eval", "round": 2,
         "values": {"acc_simple": 0.3}},
        {"kind": "ledger", "name": "eval", "round": 3,
         "values": {"acc_simple": 0.6}},
    ]
    s = obs_report.summarize(events, target=0.25,
                             target_metric="acc_simple")
    assert s["progress"]["rounds_to_target"] == 2
    s2 = obs_report.summarize(events, target=0.9,
                              target_metric="acc_simple")
    assert s2["progress"]["rounds_to_target"] is None


def test_compare_summaries_and_render():
    """--compare diff: config differences listed, per-section a/b/delta
    rows computed B - A, rounds-to-target delta included."""
    def events(vr, down, loss2):
        return [
            {"kind": "ledger", "name": "run_config",
             "values": {"algorithm": "fedhen", "variance_reduction": vr}},
            {"kind": "span", "name": "round", "round": 0, "dur_s": 0.5},
            {"kind": "span", "name": "round", "round": 1, "dur_s": 0.5},
            {"kind": "ledger", "name": "comm_bytes", "round": 1,
             "values": {"down": down, "up": down, "cum_down": 2 * down,
                        "cum_up": 2 * down, "cum_total": 4 * down}},
            {"kind": "ledger", "name": "eval", "round": 1,
             "values": {"loss_complex": 0.9}},
            {"kind": "ledger", "name": "eval", "round": 2,
             "values": {"loss_complex": loss2}},
        ]

    a = obs_report.summarize(events("none", 100.0, 0.6), target=0.5)
    b = obs_report.summarize(events("scaffold", 200.0, 0.4), target=0.5)
    cmp = obs_report.compare_summaries(a, b)
    assert cmp["config_diff"] == {
        "variance_reduction": {"a": "none", "b": "scaffold"}}
    assert cmp["comm"]["bytes_down_per_round"]["delta"] == 100.0
    assert cmp["comm"]["cum_total"]["delta"] == 400.0
    # A never reaches 0.5; B reaches it at round 2
    rt = cmp["progress"]["rounds_to_target"]
    assert rt["a"] is None and rt["b"] == 2 and rt["delta"] is None
    assert cmp["progress"]["final"]["delta"] == pytest.approx(-0.2)
    assert cmp["phases"]["round"]["delta"] == pytest.approx(0.0)

    rendered = obs_report.render_compare(cmp)
    for needle in ("telemetry run comparison", "config differences",
                   "variance_reduction: A=none  B=scaffold",
                   "-- comm --", "rounds_to_target"):
        assert needle in rendered


def test_compare_paths_cli(tmp_path):
    """The file-level entry point diffs two JSONL logs end to end."""
    import subprocess
    import sys

    def write(path, down):
        with open(path, "w") as f:
            for e in (
                    {"kind": "ledger", "name": "run_config",
                     "values": {"algorithm": "fedhen"}},
                    {"kind": "ledger", "name": "comm_bytes", "round": 0,
                     "values": {"down": down, "up": down,
                                "cum_total": 2 * down}}):
                f.write(json.dumps(e) + "\n")

    pa, pb = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    write(pa, 100.0)
    write(pb, 300.0)
    out = obs_report.compare_paths(pa, pb)
    assert "bytes_down_per_round" in out and "+200" in out

    proc = subprocess.run(
        [sys.executable, "tools/obs_report.py", "--compare", pa, pb],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    assert "telemetry run comparison" in proc.stdout
