"""The custom sLSTM block VJP must match plain autodiff exactly."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import xlstm
from repro.models.xlstm import _slstm_step_pure, slstm_block


def _ref_block(xg_b, r, state):
    """Autodiff-able reference: identical math, no custom_vjp."""
    def step(st, xg_t):
        rec = jnp.einsum("bhj,ghij->gbhi", st["h"], r)
        new = _slstm_step_pure(xg_t, rec, st)
        return new, new["h"]
    stT, hs = jax.lax.scan(step, state, xg_b.transpose(1, 0, 2, 3, 4))
    return hs.transpose(1, 0, 2, 3), stT


def test_slstm_block_forward_and_grads():
    key = jax.random.PRNGKey(0)
    b, t, nh, dh = 2, 8, 3, 4
    ks = jax.random.split(key, 3)
    xg = jax.random.normal(ks[0], (b, t, 4, nh, dh))
    r = jax.random.normal(ks[1], (4, nh, dh, dh)) * 0.3
    state = {"c": jnp.zeros((b, nh, dh)), "n": jnp.zeros((b, nh, dh)) + 1e-6,
             "h": jax.random.normal(ks[2], (b, nh, dh)) * 0.1,
             "m": jnp.zeros((b, nh, dh))}

    hs1, st1 = slstm_block(xg, r, state)
    hs2, st2 = _ref_block(xg, r, state)
    np.testing.assert_allclose(hs1, hs2, rtol=1e-6, atol=1e-6)
    for k in st1:
        np.testing.assert_allclose(st1[k], st2[k], rtol=1e-6, atol=1e-6)

    def loss(fn):
        def f(xg, r, state):
            hs, st = fn(xg, r, state)
            return jnp.sum(hs ** 2) + jnp.sum(st["c"] ** 2) \
                + jnp.sum(st["h"] * 0.3) + jnp.sum(st["n"]) \
                + 0.1 * jnp.sum(st["m"])
        return jax.grad(f, argnums=(0, 1, 2))(xg, r, state)

    g1 = loss(slstm_block)
    g2 = loss(_ref_block)
    for a, b_ in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(a, b_, rtol=2e-5, atol=2e-5)


def test_slstm_layer_end_to_end_grads():
    """Through the full sLSTM layer (blocks chained by the outer scan)."""
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(d_model=24, n_heads=2, n_kv_heads=2,
                      compute_dtype="float32")
    p = xlstm.init_slstm(jax.random.PRNGKey(1), cfg)
    h = jax.random.normal(jax.random.PRNGKey(2), (2, 12, 24))

    def f(p, h):
        return jnp.sum(xlstm.apply_slstm(p, h, cfg) ** 2)

    g = jax.grad(f)(p, h)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
    # r must receive gradient through the custom path
    assert float(jnp.max(jnp.abs(g["r"]))) > 0
