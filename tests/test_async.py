"""Asynchronous round engine (core/async_rounds.py): bounded-lag
schedule, staleness weighting, lag=0 bit-parity with the synchronous
engine, and version-aware byte accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core import async_rounds, comm, masking
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer
from repro.data.federated import iid_split
from repro.data.synthetic import synthetic_lm

TINY = ModelConfig(n_layers=4, d_model=32, n_heads=2, n_kv_heads=2,
                   d_ff=64, vocab_size=64, pattern=(LayerSpec("attn"),),
                   exit_layer=2, compute_dtype="float32")


def _make_trainer(algorithm="fedhen", *, n_devices=12, chunk=2,
                  participation=0.5, **fed_kw):
    fed = FedConfig(n_devices=n_devices, n_simple=n_devices // 2,
                    participation=participation, rounds=3, local_epochs=1,
                    lr=0.1, batch_size=4, algorithm=algorithm, seed=0,
                    cohort_chunk=chunk, **fed_kw)
    data = synthetic_lm(n_devices * 4, 16, TINY.vocab_size, seed=1)
    shards = iid_split(data, fed.n_devices, seed=2)
    return FederatedTrainer(LMAdapter(TINY), fed, shards)


def _max_abs_diff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# Schedule + weights (host-side units)
# ---------------------------------------------------------------------------

def test_fold_schedule_values():
    """The bounded-lag rule: position t is ceil((lag - t)/F) rounds stale,
    clamped by the round index."""
    np.testing.assert_array_equal(async_rounds.fold_schedule(4, 0, 10),
                                  [0, 0, 0, 0])
    np.testing.assert_array_equal(async_rounds.fold_schedule(4, 1, 10),
                                  [1, 0, 0, 0])
    np.testing.assert_array_equal(async_rounds.fold_schedule(4, 3, 10),
                                  [1, 1, 1, 0])
    np.testing.assert_array_equal(async_rounds.fold_schedule(4, 4, 10),
                                  [1, 1, 1, 1])
    np.testing.assert_array_equal(async_rounds.fold_schedule(4, 5, 10),
                                  [2, 1, 1, 1])
    # round 0 cannot train on a pre-init model: clamp to 0
    np.testing.assert_array_equal(async_rounds.fold_schedule(4, 5, 0),
                                  [0, 0, 0, 0])
    np.testing.assert_array_equal(async_rounds.fold_schedule(4, 5, 1),
                                  [1, 1, 1, 1])


def test_staleness_weight_monotone_and_exact_at_zero():
    s = np.arange(5)
    w = np.asarray(async_rounds.staleness_weight(s, decay=0.5))
    assert w[0] == 1.0                      # exact — the parity bit
    assert np.all(np.diff(w) < 0)           # strictly decaying
    np.testing.assert_allclose(w, (1.0 + s) ** -0.5, rtol=1e-6)
    ones = np.asarray(async_rounds.staleness_weight(s, scheme="none"))
    np.testing.assert_array_equal(ones, np.ones(5))
    with pytest.raises(ValueError):
        async_rounds.staleness_weight(s, scheme="exp")


def test_config_validation():
    with pytest.raises(ValueError):
        FedConfig(async_lag=-1)
    with pytest.raises(ValueError):
        FedConfig(async_staleness="exp")
    with pytest.raises(ValueError):
        FedConfig(async_decay=-0.5)


# ---------------------------------------------------------------------------
# lag=0 bit-parity with the synchronous engine (the parity oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ["fedhen", "noside", "decouple"])
def test_lag0_bit_parity(algorithm):
    """The async engine at lag=0 IS the synchronous engine: identical
    server state bit-for-bit after multiple rounds, through the async
    code path (version stack, dynamic version select, float weights)."""
    sync = _make_trainer(algorithm)
    tr = _make_trainer(algorithm)
    eng = async_rounds.AsyncRoundEngine(tr, lag=0)
    for _ in range(2):
        m_sync = sync.run_round()
        m_async = eng.run_round()
    assert _max_abs_diff(sync.server.complex, tr.server.complex) == 0.0
    if algorithm == "decouple":
        assert _max_abs_diff(sync.server.simple_host,
                             tr.server.simple_host) == 0.0
    assert m_sync == m_async
    # byte accounting: every round publishes a fresh version at lag=0,
    # so the version-aware ledger reproduces the synchronous numbers
    assert tr.total_bytes_down == sync.total_bytes_down
    assert tr.total_bytes_up == sync.total_bytes_up


def test_lag0_bit_parity_int8_wire():
    """Parity holds through a quantized wire too: the version stack is
    encoded/decoded with the same bits as the sync broadcast_roundtrip."""
    sync = _make_trainer("fedhen", comm_dtype="int8")
    tr = _make_trainer("fedhen", comm_dtype="int8")
    eng = async_rounds.AsyncRoundEngine(tr, lag=0)
    for _ in range(2):
        sync.run_round()
        eng.run_round()
    assert _max_abs_diff(sync.server.complex, tr.server.complex) == 0.0


# ---------------------------------------------------------------------------
# Nonzero lag: engine wiring, staleness liveness, padding/NaN devices
# ---------------------------------------------------------------------------

def test_trainer_dispatches_to_async_engine():
    tr0 = _make_trainer("fedhen")
    assert tr0.async_engine is None
    tr = _make_trainer("fedhen", async_lag=2)
    assert tr.async_engine is not None
    assert tr.async_engine.lag == 2
    # k=3 per population at chunk 2 -> 2 chunks each, 4 folds/round,
    # lag=2 < F -> 2 versions (fresh + one round back)
    assert tr.async_engine.folds_per_round == 4
    assert tr.async_engine.n_versions == 2
    assert tr.async_engine.versions.shape == (2, tr.layout.n_flat)
    m = tr.run_round()
    assert np.isfinite(m["loss_complex"]) and np.isfinite(m["loss_simple"])
    assert tr.server.round == 1


@pytest.mark.parametrize("algorithm", ["fedhen", "decouple"])
def test_async_rounds_stay_on_reasonable_trajectory(algorithm):
    """Nonzero lag with zero-weight padding clients (chunk 2 over k=3):
    multiple rounds run finite, move the server, and count exactly the
    real clients as valid."""
    tr = _make_trainer(algorithm, async_lag=3)
    before = jax.tree.map(jnp.copy, tr.server.complex)
    for _ in range(3):
        m = tr.run_round()
        assert np.isfinite(m["loss_complex"])
        assert m["n_valid"] == tr.k_simple + tr.k_complex
    assert _max_abs_diff(before, tr.server.complex) > 0
    for leaf in jax.tree.leaves(tr.server.complex):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_staleness_weighting_is_live():
    """poly vs none weighting must actually change the trajectory at
    nonzero lag (the decay coefficient reaches the fold)."""
    a = _make_trainer("fedhen", async_lag=3, async_decay=0.5)
    b = _make_trainer("fedhen", async_lag=3, async_staleness="none")
    for _ in range(3):
        a.run_round()
        b.run_round()
    assert _max_abs_diff(a.server.complex, b.server.complex) > 0


class _NanAdapter:
    """Tiny real-training adapter whose loss is NaN-poisoned by NaN data:
    params drift toward each client's data mean, so a NaN shard produces
    a NaN-trained device the fold must exclude."""

    def init(self, key):
        return {"a": jnp.zeros((4,), jnp.float32),
                "b": jnp.zeros((4,), jnp.float32)}

    def subnet_mask(self, params):
        return {"a": jnp.asarray(True), "b": jnp.asarray(False)}

    @staticmethod
    def _loss(params, batch):
        x = batch["x"]                       # (B, 4)
        err_a = params["a"][None] - x
        err_b = params["b"][None] - 2.0 * x
        return jnp.mean(err_a ** 2) + jnp.mean(err_b ** 2)

    loss_simple = loss_complex = loss_side = _loss


def test_nan_device_excluded_under_lag():
    """A NaN-training device under nonzero lag carries weight 0 through
    the staleness-weighted fold: the server stays finite and still
    moves."""
    fed = FedConfig(n_devices=8, n_simple=4, participation=1.0,
                    local_epochs=1, lr=0.1, batch_size=4,
                    algorithm="fedhen", seed=0, cohort_chunk=1,
                    async_lag=2)
    rng = np.random.default_rng(0)
    shards = [{"x": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))}
              for _ in range(fed.n_devices)]
    shards[1]["x"] = shards[1]["x"].at[0, 0].set(jnp.nan)  # poisoned client
    tr = FederatedTrainer(_NanAdapter(), fed, shards)
    assert tr.async_engine is not None
    saw_exclusion = False
    for _ in range(4):
        m = tr.run_round()
        saw_exclusion |= m["n_valid"] < tr.k_simple + tr.k_complex
        for leaf in jax.tree.leaves(tr.server.complex):
            assert np.isfinite(np.asarray(leaf)).all()
    assert saw_exclusion  # the poisoned client was sampled and excluded
    assert _max_abs_diff(jax.tree.map(jnp.zeros_like, tr.server.complex),
                         tr.server.complex) > 0


def test_server_replacement_resets_version_stack():
    """Checkpoint restore replaces trainer.server wholesale AFTER the
    engine is built; the version stack must follow, or every chunk keeps
    training on the discarded pre-restore broadcast."""
    from repro.core import flatten
    from repro.core.federated import ServerState

    tr = _make_trainer("fedhen", async_lag=2)
    eng = tr.async_engine
    tr.run_round()
    tr.run_round()                          # the stack now carries history
    restored = ServerState(
        complex=jax.tree.map(lambda x: jnp.ones_like(x), tr.server.complex),
        round=7)
    tr.server = restored                    # what train.py --resume does
    args, (_, _, _, r) = eng._round_args()
    assert r == 7
    want = np.asarray(flatten.pack(eng.layout, restored.complex))
    for v in range(eng.n_versions):
        np.testing.assert_array_equal(np.asarray(args[0][v]), want)
    m = tr.run_round()                      # and rounds continue from it
    assert np.isfinite(m["loss_complex"])
    assert tr.server.round == 8


# ---------------------------------------------------------------------------
# Version-aware byte accounting
# ---------------------------------------------------------------------------

def test_version_cache_bills_once_per_version():
    cache = comm.VersionCache()
    assert cache.bill(7, 0, 100) == 100     # first fetch
    assert cache.bill(7, 0, 100) == 0       # cached
    assert cache.holds(7, 0) and not cache.holds(7, 1)
    assert cache.bill(7, 1, 100) == 100     # new version
    assert cache.bill(7, 0, 100) == 100     # old version evicted
    assert cache.bill(8, 0, 100) == 100     # per-client ledger


def test_stale_broadcast_reuse_saves_download_bytes():
    """With every client sampled every round (participation 1) and lag
    covering the first simple chunk, round >= 1 reuses the cached stale
    broadcast for that chunk — measured download drops below the
    synchronous constant by exactly that chunk's client downloads."""
    sync = _make_trainer("fedhen", participation=1.0)
    tr = _make_trainer("fedhen", participation=1.0, async_lag=1)
    eng = tr.async_engine
    tr.run_round()                           # round 0: cold cache
    assert tr.total_bytes_down == sync.bytes_down_per_round
    tr.run_round()                           # round 1: chunk 0 is stale
    expected_saving = eng.chunk_s * eng._per_simple
    assert eng.last_bytes_down == sync.bytes_down_per_round - expected_saving
    # uploads never shrink: every client uploads fresh params every round
    assert eng.last_bytes_up == sync.bytes_up_per_round


# ---------------------------------------------------------------------------
# Launch-side staleness seam (launch/steps.py)
# ---------------------------------------------------------------------------

def test_fed_round_step_staleness_weights():
    from repro.launch.steps import make_fed_round_step
    from repro.models import transformer as tfm
    from repro.models.common import NO_POLICY

    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab_size=64, pattern=(LayerSpec("attn"),),
                      exit_layer=1, compute_dtype="float32")
    k, batch, steps, seq = 4, 2, 2, 16
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    cohort = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), params)
    data = jax.random.randint(jax.random.PRNGKey(1),
                              (k, batch, steps, seq + 1), 0, 64)
    is_simple = jnp.array([True, True, False, False])
    step = make_fed_round_step(cfg, NO_POLICY, local_steps=steps,
                               cohort_chunk=2)
    ref_c, ref_loss = jax.jit(step)(cohort, data, is_simple)
    # all-zero staleness == no staleness argument, bit-for-bit
    zero_c, zero_loss = jax.jit(step)(cohort, data, is_simple, None,
                                      jnp.zeros((k,), jnp.int32))
    assert _max_abs_diff(ref_c, zero_c) == 0.0
    assert float(ref_loss) == float(zero_loss)
    # nonzero staleness reweights the fold (training is unchanged)
    stale_c, stale_loss = jax.jit(step)(cohort, data, is_simple, None,
                                        jnp.array([2, 0, 2, 0]))
    assert float(stale_loss) == float(ref_loss)
    assert _max_abs_diff(ref_c, stale_c) > 0
    for leaf in jax.tree.leaves(stale_c):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
