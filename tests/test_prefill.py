"""Prefill -> decode handoff: prefilling S tokens then decoding T more must
match the parallel forward over S+T tokens (per mixer family)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import transformer as tf

S, T, B = 12, 4, 2


def _check(cfg, tol=3e-3):
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    total = S + T
    shape = (B, total, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, total)
    tokens = jax.random.randint(jax.random.PRNGKey(1), shape, 0,
                                cfg.vocab_size)
    _, final_h, _ = tf.forward(params, cfg, tokens)
    ref = tf.logits_from_hidden(params, cfg, final_h, "final")

    logits_p, cache = tf.prefill(params, cfg, tokens[:, :S], cache_len=total)
    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(ref[:, :S], np.float32),
                               rtol=tol, atol=tol)
    outs = []
    for t in range(S, total):
        lg, cache = tf.decode_step(params, cache, cfg, tokens[:, t:t + 1],
                                   jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(ref[:, S:], np.float32),
                               rtol=tol, atol=tol)


def test_prefill_dense():
    _check(ModelConfig(n_layers=3, d_model=48, n_heads=4, n_kv_heads=2,
                       d_ff=96, vocab_size=61, pattern=(LayerSpec("attn"),),
                       exit_layer=1, compute_dtype="float32"))


def test_prefill_local_window():
    _check(ModelConfig(n_layers=2, d_model=48, n_heads=2, n_kv_heads=2,
                       d_ff=96, vocab_size=61, window=5,
                       pattern=(LayerSpec("local_attn"),),
                       exit_layer=1, compute_dtype="float32"))


def test_prefill_hybrid():
    _check(ModelConfig(n_layers=3, d_model=48, n_heads=2, n_kv_heads=1,
                       d_ff=96, vocab_size=61, window=5,
                       pattern=(LayerSpec("rglru"), LayerSpec("rglru"),
                                LayerSpec("local_attn")),
                       exit_layer=3, compute_dtype="float32"))


def test_prefill_xlstm():
    _check(ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                       d_ff=0, vocab_size=61, mlstm_chunk=4,
                       pattern=(LayerSpec("mlstm", "none"),
                                LayerSpec("slstm", "none")),
                       exit_layer=2, compute_dtype="float32"), tol=6e-3)
