"""chunk2d (SPMD flash) attention must match the reference semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import chunk2d_attention, chunked_causal_attention


@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_chunk2d_matches_reference(window, softcap):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    b, s, h, kh, dh = 2, 128, 6, 2, 16
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kh, dh))
    v = jax.random.normal(ks[2], (b, s, kh, dh))
    got = chunk2d_attention(q, k, v, window=window, softcap_val=softcap,
                            q_chunk=16, k_chunk=32)
    want = chunked_causal_attention(q, k, v, window=window,
                                    softcap_val=softcap, q_chunk=32)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_chunk2d_grads_match():
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 8))
    k = jax.random.normal(ks[1], (1, 64, 2, 8))
    v = jax.random.normal(ks[2], (1, 64, 2, 8))

    def f(impl):
        def loss(q, k, v):
            return jnp.sum(impl(q, k, v) ** 2)
        return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

    g1 = f(lambda q, k, v: chunk2d_attention(q, k, v, q_chunk=16,
                                             k_chunk=16))
    g2 = f(lambda q, k, v: chunked_causal_attention(q, k, v, q_chunk=16))
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
