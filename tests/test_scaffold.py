"""SCAFFOLD variance reduction end-to-end (the state-store tentpole's
first consumer): round-1 bit-equality with the plain protocol, a
closed-form option-II oracle, flat/tree + chunked/unchunked parity
across all three algorithms, NaN/pad-slot row hygiene, the async engine,
wire dtypes, comm billing, and checkpoint resume."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import restore_trainer, save_trainer
from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core import async_rounds, comm, flatten
from repro.core.adapters import LMAdapter
from repro.core.federated import (FederatedTrainer, local_step_count,
                                  make_client_trainer)
from repro.data.federated import iid_split
from repro.data.synthetic import synthetic_lm

TINY = ModelConfig(n_layers=4, d_model=32, n_heads=2, n_kv_heads=2,
                   d_ff=64, vocab_size=64, pattern=(LayerSpec("attn"),),
                   exit_layer=2, compute_dtype="float32")

ALGOS = ["fedhen", "noside", "decouple"]


def _make_trainer(algorithm="fedhen", *, n_devices=4, participation=1.0,
                  variance_reduction="scaffold", **fed_kw):
    fed = FedConfig(n_devices=n_devices, n_simple=n_devices // 2,
                    participation=participation, rounds=3, local_epochs=1,
                    lr=0.1, batch_size=4, algorithm=algorithm, seed=0,
                    variance_reduction=variance_reduction, **fed_kw)
    data = synthetic_lm(n_devices * 8, 16, TINY.vocab_size, seed=1)
    shards = iid_split(data, fed.n_devices, seed=2)
    return FederatedTrainer(LMAdapter(TINY), fed, shards)


def _max_abs_diff(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _server_close(a, b, tol=0.0):
    d = _max_abs_diff(a.server.complex, b.server.complex)
    assert d <= tol, d
    if a.fed.algorithm == "decouple":
        d = _max_abs_diff(a.server.simple_host, b.server.simple_host)
        assert d <= tol, d


# ---------------------------------------------------------------------------
# Zero-init contract: round 1 is bit-identical to variance_reduction="none"
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ALGOS)
def test_round1_bit_identical_to_none(algorithm):
    """c = c_i = 0 means the correction and every gradient are untouched:
    the first SCAFFOLD round must reproduce the plain protocol exactly
    (same trained models, same aggregate, bit for bit)."""
    plain = _make_trainer(algorithm, variance_reduction="none")
    scaf = _make_trainer(algorithm)
    m_plain = plain.run_round()
    m_scaf = scaf.run_round()
    _server_close(plain, scaf, tol=0.0)
    assert m_plain == m_scaf
    # ... and the control variates MOVED (the second round diverges)
    assert float(jnp.linalg.norm(scaf.cv_global)) > 0.0
    plain.run_round()
    scaf.run_round()
    assert _max_abs_diff(plain.server.complex, scaf.server.complex) > 0.0


# ---------------------------------------------------------------------------
# Closed-form option-II oracle (one client per population, K static)
# ---------------------------------------------------------------------------

def test_option_ii_oracle_single_client_populations():
    """With one simple + one complex client at full participation, the
    round's store rows must equal the hand-computed
    ``dc = (x - y)/(K*lr) - c`` (c = 0 in round 1): ``y`` is recomputed
    here by invoking the same client trainer with the same derived key,
    so the test pins the packing, masking, weighting AND the per-client
    RNG derivation."""
    tr = _make_trainer("fedhen", n_devices=2)
    fed, layout = tr.fed, tr.layout
    server0 = jax.tree.map(jnp.copy, tr.server.complex)
    plan = tr.sampler.plan(0)
    assert list(plan.simple_ids) == [0] and list(plan.complex_ids) == [1]

    tr.run_round()

    # replicate the round's broadcast + per-client training exactly
    key = jax.random.PRNGKey(fed.seed * 100003 + 0)
    rs, rc = jax.random.split(key)
    bc = comm.broadcast_roundtrip(tr.wire, layout, server0)
    x_flat = flatten.pack(layout, bc)
    adapter = tr.adapter
    shard = lambda i: jax.tree.map(lambda v: v[0], tr._gather([i]))

    train_s = make_client_trainer(adapter.loss_simple, fed)
    y_s, _ = train_s(bc, shard(0), jax.random.fold_in(rs, 0))
    train_c = make_client_trainer(adapter.loss_side, fed)
    y_c, _ = train_c(bc, shard(1), jax.random.fold_in(rc, 0))

    k_steps = local_step_count(tr._gather([0]), fed)
    inv = 1.0 / (k_steps * fed.lr)
    dc_s = jnp.where(tr.flat_mask,
                     (x_flat - flatten.pack(layout, y_s)) * inv, 0.0)
    dc_c = (x_flat - flatten.pack(layout, y_c)) * inv

    rows = tr.cv_store.to_array()
    assert float(jnp.max(jnp.abs(rows[0] - dc_s))) == 0.0
    assert float(jnp.max(jnp.abs(rows[1] - dc_c))) == 0.0
    # server update: c += (1/N) * sum_i dc_i (raw sum, never normalized
    # by cohort weights — dc_s is zero outside M so the masked fold's
    # w_out gating changes nothing elementwise)
    want = (dc_s + dc_c) / fed.n_devices
    np.testing.assert_allclose(np.asarray(tr.cv_global), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Engine parity: flat vs tree, chunked vs unchunked, all three algorithms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ALGOS)
def test_flat_vs_tree_engine_parity(algorithm):
    """The cv fold is a flat op on BOTH engines; after two rounds the
    server models and control variates must agree up to summation
    order."""
    flat = _make_trainer(algorithm, agg_engine="flat")
    tree = _make_trainer(algorithm, agg_engine="tree")
    for _ in range(2):
        flat.run_round()
        tree.run_round()
    _server_close(flat, tree, tol=2e-5)
    np.testing.assert_allclose(np.asarray(flat.cv_global),
                               np.asarray(tree.cv_global),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(flat.cv_store.to_array(),
                               tree.cv_store.to_array(),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("algorithm", ALGOS)
def test_chunked_parity(algorithm):
    """Streaming the cohort one client at a time must fold the same cv
    state as the single-chunk round (the rows ride the scan outputs)."""
    whole = _make_trainer(algorithm, cohort_chunk=0)
    chunked = _make_trainer(algorithm, cohort_chunk=1)
    for _ in range(2):
        whole.run_round()
        chunked.run_round()
    _server_close(whole, chunked, tol=2e-5)
    np.testing.assert_allclose(np.asarray(whole.cv_global),
                               np.asarray(chunked.cv_global),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(whole.cv_store.to_array(),
                               chunked.cv_store.to_array(),
                               rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# Row hygiene: NaN devices and uniform-sampling pad slots
# ---------------------------------------------------------------------------

class _NanAdapter:
    """Tiny real-training adapter (mirrors tests/test_async.py): params
    drift toward each client's data mean, so a NaN shard produces a
    NaN-trained device the fold — and the row scatter — must exclude."""

    def init(self, key):
        return {"a": jnp.zeros((4,), jnp.float32),
                "b": jnp.zeros((4,), jnp.float32)}

    def subnet_mask(self, params):
        return {"a": jnp.asarray(True), "b": jnp.asarray(False)}

    @staticmethod
    def _loss(params, batch):
        x = batch["x"]                       # (B, 4)
        err_a = params["a"][None] - x
        err_b = params["b"][None] - 2.0 * x
        return jnp.mean(err_a ** 2) + jnp.mean(err_b ** 2)

    loss_simple = loss_complex = loss_side = _loss


def test_nan_device_keeps_previous_row_and_finite_c():
    """A NaN device folds at weight 0 AND keeps its previous control
    variate: a NaN row must never persist in the store, and c stays
    finite."""
    fed = FedConfig(n_devices=4, n_simple=2, participation=1.0,
                    local_epochs=1, lr=0.1, batch_size=4,
                    algorithm="fedhen", seed=0,
                    variance_reduction="scaffold")
    rng = np.random.default_rng(0)
    shards = [{"x": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))}
              for _ in range(fed.n_devices)]
    shards[1]["x"] = shards[1]["x"].at[0, 0].set(jnp.nan)  # poisoned client
    tr = FederatedTrainer(_NanAdapter(), fed, shards)
    m = tr.run_round()
    assert m["n_valid"] == fed.n_devices - 1
    rows = tr.cv_store.to_array()
    assert np.isfinite(rows).all()
    np.testing.assert_array_equal(rows[1], 0.0)   # kept its (zero) row
    assert np.isfinite(np.asarray(tr.cv_global)).all()
    # the healthy clients' rows updated
    for i in (0, 2, 3):
        assert np.abs(rows[i]).max() > 0.0


def test_uniform_pad_slots_never_clobber_rows():
    """Uniform super-cohort mode: unfilled slots wrap real clients' ids —
    scattering them back would overwrite a row the wrapped client just
    wrote.  Only REAL slots may touch the store."""
    tr = _make_trainer("fedhen", n_devices=8, participation=0.25,
                       sample_uniform=True)
    # find a round whose plan actually has pad slots
    for r in range(20):
        plan = tr.sampler.plan(tr.server.round)
        if not plan.all_real:
            break
        tr.run_round()
    else:
        pytest.fail("no uniform round with pad slots in 20 draws")
    before = tr.cv_store.to_array().copy()
    tr.run_round()
    after = tr.cv_store.to_array()
    real = set(int(i) for i in plan.real_ids())
    changed = {i for i in range(tr.fed.n_devices)
               if np.abs(after[i] - before[i]).max() > 0.0}
    assert changed <= real, (changed, real)
    assert changed, "no real row updated"


# ---------------------------------------------------------------------------
# Async engine: lag=0 bit-parity, lag=1 liveness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ALGOS)
def test_async_lag0_bit_parity_with_scaffold(algorithm):
    """lag=0 through the async code path (version stack, float weights,
    shared scan) must reproduce the synchronous SCAFFOLD round bit for
    bit — server state, c, and every store row."""
    sync = _make_trainer(algorithm, n_devices=6, cohort_chunk=1)
    tr = _make_trainer(algorithm, n_devices=6, cohort_chunk=1)
    eng = async_rounds.AsyncRoundEngine(tr, lag=0)
    for _ in range(2):
        m_sync = sync.run_round()
        m_async = eng.run_round()
    _server_close(sync, tr, tol=0.0)
    assert m_sync == m_async
    assert _max_abs_diff([sync.cv_global], [tr.cv_global]) == 0.0
    np.testing.assert_array_equal(sync.cv_store.to_array(),
                                  tr.cv_store.to_array())
    assert sync.total_bytes == tr.total_bytes


def test_async_lag1_scaffold_runs_and_stays_finite():
    """Nonzero lag: stale chunks compute dc against the stale broadcast
    they actually trained on (x is the selected version).  The rounds
    must stay finite and move the control variates."""
    tr = _make_trainer("fedhen", n_devices=6, cohort_chunk=1, async_lag=1)
    assert tr.async_engine is not None
    for _ in range(3):
        m = tr.run_round()
        assert np.isfinite(m["loss_simple"]) and np.isfinite(
            m["loss_complex"])
    assert np.isfinite(np.asarray(tr.cv_global)).all()
    assert float(jnp.linalg.norm(tr.cv_global)) > 0.0
    assert np.isfinite(tr.cv_store.to_array()).all()
    assert tr.cv_store.scattered_bytes > 0


# ---------------------------------------------------------------------------
# Wire dtypes + comm billing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comm_dtype", ["bfloat16", "int8"])
def test_scaffold_through_nonidentity_wires(comm_dtype):
    """The cv exchange moves raw f32 alongside any wire: SCAFFOLD must
    compose with the bf16 and quantized paths and stay finite."""
    tr = _make_trainer("fedhen", comm_dtype=comm_dtype)
    for _ in range(2):
        m = tr.run_round()
        assert np.isfinite(m["loss_complex"])
    assert np.isfinite(np.asarray(tr.cv_global)).all()
    assert np.isfinite(tr.cv_store.to_array()).all()


def test_cv_exchange_billing():
    """SCAFFOLD bills the control-variate exchange at raw f32 of the
    trained element counts, both directions, on top of the wire."""
    plain = _make_trainer("fedhen", variance_reduction="none")
    scaf = _make_trainer("fedhen")
    n_m = int(np.sum(np.asarray(scaf.flat_mask)))
    extra_one_way = (scaf.k_simple * 4.0 * n_m
                     + scaf.k_complex * 4.0 * scaf.layout.n_params)
    assert scaf.bytes_per_round - plain.bytes_per_round == pytest.approx(
        2.0 * extra_one_way)
    scaf.run_round()
    assert scaf.total_bytes == pytest.approx(scaf.bytes_per_round)


# ---------------------------------------------------------------------------
# Checkpoint: the cv store rides the sidecar, resume is exact
# ---------------------------------------------------------------------------

def test_checkpoint_resume_reproduces_uninterrupted_run(tmp_path):
    path = str(tmp_path / "ckpt.npz")
    a = _make_trainer("fedhen")
    a.run_round()
    a.run_round()
    save_trainer(path, a)
    a.run_round()

    b = _make_trainer("fedhen")
    restore_trainer(path, b)
    assert b.server.round == 2
    b.run_round()
    _server_close(a, b, tol=0.0)
    np.testing.assert_array_equal(np.asarray(a.cv_global),
                                  np.asarray(b.cv_global))
    np.testing.assert_array_equal(a.cv_store.to_array(),
                                  b.cv_store.to_array())


def test_checkpoint_without_cv_sidecar_rejected(tmp_path):
    """Restoring a plain checkpoint into a SCAFFOLD trainer must fail
    loudly — silently resetting c/c_i would corrupt the correction."""
    path = str(tmp_path / "ckpt.npz")
    plain = _make_trainer("fedhen", variance_reduction="none")
    plain.run_round()
    save_trainer(path, plain)
    scaf = _make_trainer("fedhen")
    with pytest.raises(ValueError, match="no __cv_store__ sidecar"):
        restore_trainer(path, scaf)
