"""Quantized flat-buffer communication (core/comm.py): wire roundtrips,
per-slot error bounds, the dequantizing fold's parity with the f32 upload
path, and measured-vs-analytic byte accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, LayerSpec, ModelConfig
from repro.core import aggregate, comm, flatten
from repro.core.adapters import LMAdapter
from repro.core.federated import FederatedTrainer
from repro.data.federated import iid_split
from repro.data.synthetic import synthetic_lm


def _tree(seed=0, scale_b=100.0):
    """Leaves at very different magnitudes: per-slot scales must keep the
    error of each leaf proportional to ITS OWN magnitude."""
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(3, 5)).astype(np.float32)),
            "b": jnp.asarray((scale_b * rng.normal(size=(200,)))
                             .astype(np.float32)),
            "c": jnp.asarray(rng.normal(size=(2, 2)).astype(np.float32))}


# ---------------------------------------------------------------------------
# WireSpec validation
# ---------------------------------------------------------------------------

def test_wire_spec_validation():
    assert comm.WireSpec("float32").is_identity
    assert comm.WireSpec("int8").is_quantized
    assert not comm.WireSpec("bfloat16").is_identity
    with pytest.raises(ValueError):
        comm.WireSpec("float16")
    with pytest.raises(ValueError):
        comm.WireSpec("int8", quant_block=0)
    with pytest.raises(ValueError):
        comm.WireSpec("int8", quant_block=96)   # does not divide 128
    with pytest.raises(ValueError):
        comm.WireSpec("int8", quant_block=256)  # exceeds the alignment


def test_fedconfig_wire_validation():
    with pytest.raises(ValueError):
        FedConfig(comm_dtype="float16")
    with pytest.raises(ValueError):
        FedConfig(comm_dtype="int8", agg_engine="tree")
    with pytest.raises(ValueError):
        FedConfig(quant_block=96)
    FedConfig(comm_dtype="int8")        # flat engine default: fine
    FedConfig(comm_dtype="bfloat16", agg_engine="tree")  # bf16+tree: fine


# ---------------------------------------------------------------------------
# Quantize / dequantize
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bound_per_group():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 512)).astype(np.float32))
    q, scales = comm.quantize(x, 128)
    assert q.dtype == jnp.int8 and scales.shape == (4, 4)
    back = np.asarray(comm.dequantize(q, scales, 128))
    # error of each element <= half a quantization step of ITS group
    err = np.abs(back - np.asarray(x)).reshape(4, 4, 128)
    step = np.asarray(scales)[..., None]
    assert (err <= 0.5 * step + 1e-7).all()


def test_quantize_zero_group_is_exact_zero():
    x = jnp.zeros((256,))
    q, scales = comm.quantize(x, 128)
    np.testing.assert_array_equal(np.asarray(scales), 0.0)
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_array_equal(np.asarray(comm.dequantize(q, scales,
                                                             128)), 0.0)


def test_encode_decode_roundtrip_per_slot_bounds():
    """Int8 wire error of every slot is bounded by that slot's own group
    maxima — a 100x louder neighbouring leaf must not leak error in."""
    tree = _tree()
    layout = flatten.build_layout(tree, total_multiple=256)
    flat = flatten.pack(layout, tree)
    spec = comm.WireSpec("int8", 128)
    back = comm.decode(spec, comm.encode(spec, flat))
    flat_np, back_np = np.asarray(flat), np.asarray(back)
    for slot in layout.slots:
        seg = slice(slot.offset, slot.offset + slot.size)
        amax = np.abs(flat_np[seg]).max()
        err = np.abs(back_np[seg] - flat_np[seg]).max()
        assert err <= amax / 127.0 * 0.5 + 1e-7, (slot, err, amax)
    # alignment padding decodes to exactly zero
    live = np.zeros(layout.n_flat, bool)
    for slot in layout.slots:
        live[slot.offset:slot.offset + slot.size] = True
    np.testing.assert_array_equal(back_np[~live], 0.0)


@pytest.mark.parametrize("dtype,rtol", [("float32", 0.0),
                                        ("bfloat16", 1e-2)])
def test_encode_decode_float_wires(dtype, rtol):
    flat = flatten.pack(flatten.build_layout(_tree(), total_multiple=256),
                        _tree())
    spec = comm.WireSpec(dtype)
    back = comm.decode(spec, comm.encode(spec, flat))
    assert back.dtype == jnp.float32
    if rtol == 0.0:
        np.testing.assert_array_equal(np.asarray(back), np.asarray(flat))
    else:
        np.testing.assert_allclose(np.asarray(back), np.asarray(flat),
                                   rtol=rtol, atol=rtol)


def test_encode_handles_non_group_multiple_length():
    spec = comm.WireSpec("int8", 128)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(300,))
                    .astype(np.float32))
    buf = comm.encode(spec, x)
    assert buf.payload.shape == (300,) and buf.scales.shape == (3,)
    back = comm.decode(spec, buf)
    assert back.shape == (300,)
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(back - x))) <= amax / 127.0 * 0.5 + 1e-7


# ---------------------------------------------------------------------------
# Measured byte accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
@pytest.mark.parametrize("n", [128, 300, 4096])
def test_wire_bytes_measured_matches_analytic(dtype, n):
    spec = comm.WireSpec(dtype, 128)
    measured = comm.wire_bytes(spec, n)
    assert measured == comm.analytic_wire_bytes(spec, n)
    # and both match a concretely encoded buffer
    buf = comm.encode(spec, jnp.ones((n,)))
    assert comm.buffer_nbytes(buf) == measured


def test_int8_wire_bytes_beat_f32_by_3x():
    """The acceptance ratio at the accounting level: payload/4 + sidecar
    still >= 3x smaller (3.88x at quant_block=128)."""
    spec8 = comm.WireSpec("int8", 128)
    spec32 = comm.WireSpec("float32")
    for n in (2048, 165888):
        assert comm.wire_bytes(spec32, n) / comm.wire_bytes(spec8, n) >= 3.0


# ---------------------------------------------------------------------------
# Upload fold parity: wire vs f32 (all algorithms, NaN/zero-weight devices)
# ---------------------------------------------------------------------------

def _random_cohort(seed, z=8):
    rng = np.random.default_rng(seed)
    cohort = {"a": jnp.asarray(rng.normal(size=(z, 4, 3))
                               .astype(np.float32)),
              "b": jnp.asarray((50.0 * rng.normal(size=(z, 5)))
                               .astype(np.float32))}
    mask = {"a": jnp.asarray(True), "b": jnp.asarray(False)}
    is_simple = jnp.asarray(np.arange(z) < z // 2)
    valid = jnp.ones(z, bool)
    # a NaN device and a zero-weight padding device (both must be gated)
    cohort["a"] = cohort["a"].at[2].set(jnp.nan)
    valid = valid.at[2].set(False)
    valid = valid.at[z - 1].set(False)
    return cohort, mask, is_simple, valid


def _stream_wire(cohort, mask, is_simple, valid, algo, chunk, wire,
                 **fold_kw):
    z = jax.tree.leaves(cohort)[0].shape[0]
    template = jax.tree.map(lambda x: x[0], cohort)
    state = aggregate.streaming_init(template, algo)
    for lo in range(0, z, chunk):
        sl = slice(lo, min(lo + chunk, z))
        state = aggregate.streaming_fold(
            state, jax.tree.map(lambda x: x[sl], cohort),
            is_simple[sl], valid[sl], mask, algorithm=algo, wire=wire,
            **fold_kw)
    return aggregate.streaming_finalize(state, mask, template,
                                        algorithm=algo)


def _assert_tree_allclose(got, want, rtol, atol):
    if want is None:
        assert got is None
        return
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("algo", ["fedhen", "noside", "decouple"])
@pytest.mark.parametrize("chunk", [3, 8])
def test_int8_upload_fold_matches_f32_fold(algo, chunk):
    cohort, mask, is_simple, valid = _random_cohort(3)
    wire = comm.WireSpec("int8", 128)
    f32_c, f32_host = _stream_wire(cohort, mask, is_simple, valid, algo,
                                   chunk, None)
    q_c, q_host = _stream_wire(cohort, mask, is_simple, valid, algo,
                               chunk, wire)
    # int8 tolerance: |err| <= amax/254 per group; leaves here are O(50)
    _assert_tree_allclose(q_c, f32_c, rtol=2e-2, atol=0.3)
    _assert_tree_allclose(q_host, f32_host, rtol=2e-2, atol=0.3)
    for leaf in jax.tree.leaves(q_c):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("algo", ["fedhen", "decouple"])
def test_int8_fold_kernel_path_matches_cpu_path(algo):
    """The dequantizing kernel (interpret mode) and the per-leaf CPU ref
    produce the same accumulators: identical quantization grouping."""
    cohort, mask, is_simple, valid = _random_cohort(4)
    wire = comm.WireSpec("int8", 128)
    cpu_c, cpu_host = _stream_wire(cohort, mask, is_simple, valid, algo,
                                   3, wire)
    ker_c, ker_host = _stream_wire(cohort, mask, is_simple, valid, algo,
                                   3, wire, force_pallas_interpret=True)
    _assert_tree_allclose(ker_c, cpu_c, rtol=1e-5, atol=1e-6)
    _assert_tree_allclose(ker_host, cpu_host, rtol=1e-5, atol=1e-6)


def test_bf16_wire_fold_rides_stream_dtype():
    cohort, mask, is_simple, valid = _random_cohort(5)
    wire = comm.WireSpec("bfloat16")
    got_c, _ = _stream_wire(cohort, mask, is_simple, valid, "fedhen", 4,
                            wire)
    want_c, _ = _stream_wire(cohort, mask, is_simple, valid, "fedhen", 4,
                             None, stream_dtype=jnp.bfloat16)
    _assert_tree_allclose(got_c, want_c, rtol=1e-6, atol=1e-7)


def test_int8_wire_rejects_tree_engine():
    with pytest.raises(ValueError):
        aggregate.make_engine("tree", algorithm="fedhen", mask={},
                              wire=comm.WireSpec("int8"))


# ---------------------------------------------------------------------------
# Broadcast roundtrip
# ---------------------------------------------------------------------------

def test_decode_tree_rejects_mismatched_template():
    tree = _tree()
    layout = flatten.build_layout(tree, total_multiple=256)
    spec = comm.WireSpec("float32")
    buf = comm.encode_tree(spec, layout, tree)
    out = comm.decode_tree(spec, layout, buf, template=tree)
    for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    with pytest.raises(ValueError):
        comm.decode_tree(spec, layout, buf, template={"x": tree["a"]})


def test_broadcast_roundtrip_identity_for_f32():
    tree = _tree()
    layout = flatten.build_layout(tree, total_multiple=256)
    out = comm.broadcast_roundtrip(comm.WireSpec("float32"), layout, tree)
    assert out is tree        # no ops traced at all


def test_broadcast_roundtrip_int8_bounds():
    tree = _tree()
    layout = flatten.build_layout(tree, total_multiple=256)
    out = comm.broadcast_roundtrip(comm.WireSpec("int8", 128), layout, tree)
    for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert got.dtype == want.dtype
        amax = float(jnp.max(jnp.abs(want)))
        assert float(jnp.max(jnp.abs(got - want))) <= amax / 127.0


# ---------------------------------------------------------------------------
# Trainer integration: wire rounds + measured accounting
# ---------------------------------------------------------------------------

TINY = ModelConfig(n_layers=4, d_model=32, n_heads=2, n_kv_heads=2,
                   d_ff=64, vocab_size=64, pattern=(LayerSpec("attn"),),
                   exit_layer=2, compute_dtype="float32")


def _make_trainer(algorithm="fedhen", **fed_kw):
    fed_kw.setdefault("cohort_chunk", 2)
    fed = FedConfig(n_devices=8, n_simple=4, participation=0.5, rounds=3,
                    local_epochs=1, lr=0.1, batch_size=4,
                    algorithm=algorithm, seed=0, **fed_kw)
    data = synthetic_lm(32, 16, TINY.vocab_size, seed=1)
    shards = iid_split(data, fed.n_devices, seed=2)
    return FederatedTrainer(LMAdapter(TINY), fed, shards)


def test_trainer_measured_equals_analytic_for_f32_wire():
    """The f32 wire bills exactly the paper's analytic accounting (true
    element counts x 4 bytes, down+up) — padding is never billed."""
    tr = _make_trainer()
    assert tr.bytes_per_round == tr.analytic_bytes_per_round()
    assert tr.bytes_down_per_round == tr.bytes_up_per_round
    assert tr.bytes_per_round == (tr.bytes_down_per_round
                                  + tr.bytes_up_per_round)


def test_trainer_measured_bytes_monotone_and_gated():
    f32 = _make_trainer()
    bf16 = _make_trainer(comm_dtype="bfloat16")
    int8 = _make_trainer(comm_dtype="int8")
    assert int8.bytes_per_round < bf16.bytes_per_round < f32.bytes_per_round
    assert bf16.bytes_per_round == f32.bytes_per_round / 2
    assert f32.bytes_per_round / int8.bytes_per_round >= 3.0


@pytest.mark.parametrize("algorithm", ["fedhen", "decouple"])
def test_int8_wire_round_stays_near_f32_round(algorithm):
    """One full round through the quantized broadcast + dequantizing
    upload fold lands close to the f32 round and stays finite."""
    ref = _make_trainer(algorithm)
    tr = _make_trainer(algorithm, comm_dtype="int8")
    m_ref = ref.run_round()
    m = tr.run_round()
    assert np.isfinite(m["loss_complex"])
    assert m["n_valid"] == m_ref["n_valid"]
    for a, b in zip(jax.tree.leaves(tr.server.complex),
                    jax.tree.leaves(ref.server.complex)):
        delta = float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
        assert delta < 0.05, delta


def test_total_bytes_accumulate_per_direction():
    tr = _make_trainer(comm_dtype="int8")
    tr.run_round()
    tr.run_round()
    assert tr.total_bytes_down == 2 * tr.bytes_down_per_round
    assert tr.total_bytes_up == 2 * tr.bytes_up_per_round
    assert tr.total_bytes == tr.total_bytes_down + tr.total_bytes_up
    test = {"tokens": jnp.asarray(synthetic_lm(8, 16, TINY.vocab_size,
                                               seed=9)["tokens"])}
    ev = tr.evaluate(test)
    assert ev["mbytes"] == pytest.approx(ev["mbytes_down"]
                                         + ev["mbytes_up"])


# ---------------------------------------------------------------------------
# Wire v2: validation, stochastic rounding, top-k codec, upload accounting
# ---------------------------------------------------------------------------

def test_wire_spec_v2_validation():
    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="topk_frac must be in"):
            comm.WireSpec("float32", topk_frac=bad)
    with pytest.raises(ValueError, match="stochastic rounding requires"):
        comm.WireSpec("float32", stochastic=True)
    with pytest.raises(ValueError, match="error_feedback requires"):
        comm.WireSpec("float32", error_feedback=True)
    # lossy paths make all three legal
    comm.WireSpec("int8", stochastic=True, error_feedback=True)
    comm.WireSpec("bfloat16", stochastic=True)
    comm.WireSpec("float32", topk_frac=0.5, error_feedback=True)


def test_uses_deltas_gate():
    """uses_deltas is THE switch that moves uploads off the pre-existing
    traced program — every pre-v2 config must keep it False."""
    for dtype in ("float32", "bfloat16", "int8"):
        assert not comm.WireSpec(dtype).uses_deltas
    assert comm.WireSpec("float32", topk_frac=0.25).uses_deltas
    assert comm.WireSpec("int8", stochastic=True).uses_deltas
    assert comm.WireSpec("int8", error_feedback=True).uses_deltas


def test_fedconfig_delta_mode_requires_flat_engine():
    with pytest.raises(ValueError, match="require.*agg_engine='flat'"):
        FedConfig(topk_frac=0.5, agg_engine="tree")
    with pytest.raises(ValueError, match="require.*agg_engine='flat'"):
        FedConfig(comm_dtype="bfloat16", stochastic_rounding=True,
                  agg_engine="tree")
    FedConfig(topk_frac=0.5)            # flat default: fine


def test_stochastic_encode_is_seeded_and_reproducible():
    spec = comm.WireSpec("int8", 128, stochastic=True)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(256,))
                    .astype(np.float32))
    k1, k2 = jax.random.PRNGKey(1), jax.random.PRNGKey(2)
    a = comm.encode(spec, x, key=k1)
    b = comm.encode(spec, x, key=k1)
    c = comm.encode(spec, x, key=k2)
    np.testing.assert_array_equal(np.asarray(a.payload),
                                  np.asarray(b.payload))
    assert not np.array_equal(np.asarray(a.payload), np.asarray(c.payload))


def test_encode_ignores_key_on_deterministic_spec():
    """The broadcast path may thread a key by accident — a non-stochastic
    spec must stay bit-identical to the keyless encode."""
    spec = comm.WireSpec("int8", 128)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(256,))
                    .astype(np.float32))
    a = comm.encode(spec, x)
    b = comm.encode(spec, x, key=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(a.payload),
                                  np.asarray(b.payload))


def test_topk_count_lane_aligned():
    spec = comm.WireSpec("int8", 128, topk_frac=0.1)
    for n in (128, 1000, 4096, 165888):
        k = comm.topk_count(spec, n)
        assert k % 128 == 0 and k >= n * 0.1
    assert comm.topk_count(comm.WireSpec("int8"), 300) == 300  # dense
    # tiny populations still ship at least one lane
    assert comm.topk_count(spec, 64) == 128


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_sparse_encode_keeps_the_k_largest(dtype):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(1024,)).astype(np.float32))
    spec = comm.WireSpec(dtype, 128, topk_frac=0.25)
    k = comm.topk_count(spec, 1024)
    buf = comm.sparse_encode(spec, x, k)
    assert buf.indices.dtype == jnp.int32
    idx = np.asarray(buf.indices)
    assert (np.diff(idx) > 0).all()          # sorted, distinct
    want = set(np.argsort(-np.abs(np.asarray(x)))[:k].tolist())
    assert set(idx.tolist()) == want
    # decoded values sit within the dense wire's error of the kept entries
    vals = np.asarray(comm.sparse_decode_values(spec, buf))
    kept = np.asarray(x)[idx]
    tol = {"float32": 0.0, "bfloat16": 0.05,
           "int8": np.abs(kept).max() / 127.0}[dtype]
    assert np.abs(vals - kept).max() <= tol + 1e-7


def test_sparse_decode_scatters_only_kept_positions():
    x = jnp.asarray(np.random.default_rng(8).normal(size=(512,))
                    .astype(np.float32))
    spec = comm.WireSpec("float32", topk_frac=0.25)
    k = comm.topk_count(spec, 512)
    buf = comm.sparse_encode(spec, x, k)
    dense = np.asarray(comm.sparse_decode(spec, buf, 512))
    idx = np.asarray(buf.indices)
    np.testing.assert_array_equal(dense[idx], np.asarray(x)[idx])
    dropped = np.setdiff1d(np.arange(512), idx)
    np.testing.assert_array_equal(dense[dropped], 0.0)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
@pytest.mark.parametrize("frac", [1.0, 0.5, 1 / 16])
def test_wire_bytes_up_measured_matches_analytic(dtype, frac):
    spec = comm.WireSpec(dtype, 128, topk_frac=frac)
    for n in (2048, 165888):
        measured = comm.wire_bytes_up(spec, n)
        assert measured == comm.analytic_wire_bytes_up(spec, n)
        if frac == 1.0:
            assert measured == comm.wire_bytes(spec, n)
        else:
            # and both match a concretely encoded sparse buffer
            k = comm.topk_count(spec, n)
            buf = comm.sparse_encode(spec, jnp.ones((n,)), k)
            assert comm.sparse_buffer_nbytes(buf) == measured


def test_int8_topk_upload_beats_f32_by_10x():
    """The tentpole's upload-direction acceptance ratio at the accounting
    level: int8 payload + scales + int32 indices at topk_frac=1/16."""
    spec = comm.WireSpec("int8", 128, topk_frac=1 / 16,
                         stochastic=True, error_feedback=True)
    f32 = comm.WireSpec("float32")
    for n in (16384, 165888):
        ratio = comm.wire_bytes_up(f32, n) / comm.wire_bytes_up(spec, n)
        assert ratio >= 10.0, ratio


# ---------------------------------------------------------------------------
# Scatter-fold kernel: interpret-mode parity with the CPU reference
# ---------------------------------------------------------------------------

def _scatter_case(seed, n=512, z=4, k=128, quant_block=64):
    rng = np.random.default_rng(seed)
    acc = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    idx = np.stack([np.sort(rng.choice(n, size=k, replace=False))
                    for _ in range(z)]).astype(np.int32)
    vals = jnp.asarray(rng.normal(size=(z, k)).astype(np.float32))
    scales = jnp.asarray(rng.uniform(0.5, 2.0, size=(z, k // quant_block))
                         .astype(np.float32))
    mask = jnp.asarray(rng.random(n) < 0.5)
    w_m = jnp.asarray(rng.uniform(0, 1, size=(z,)).astype(np.float32))
    w_r = jnp.asarray(rng.uniform(0, 1, size=(z,)).astype(np.float32))
    return acc, vals, scales, jnp.asarray(idx), mask, w_m, w_r


@pytest.mark.parametrize("with_scales", [True, False])
def test_scatter_fold_kernel_matches_ref(with_scales):
    from repro.kernels.masked_agg import ops as agg_ops
    acc, vals, scales, idx, mask, w_m, w_r = _scatter_case(11)
    sc = scales if with_scales else None
    ref = agg_ops.masked_scatter_acc_ref(acc, vals, sc, idx, mask,
                                         w_m, w_r, quant_block=64)
    ker = agg_ops.masked_scatter_acc_pallas(acc, vals, sc, idx, mask,
                                            w_m, w_r, quant_block=64,
                                            block_n=256, interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_scatter_fold_gates_nan_rows():
    """A NaN row at weight 0 (both masks) must leave the accumulator
    untouched — the kernel gates BEFORE the multiply."""
    from repro.kernels.masked_agg import ops as agg_ops
    acc, vals, scales, idx, mask, w_m, w_r = _scatter_case(12)
    vals = vals.at[1].set(jnp.nan)
    w_m = w_m.at[1].set(0.0)
    w_r = w_r.at[1].set(0.0)
    for fn, kw in ((agg_ops.masked_scatter_acc_ref, {}),
                   (agg_ops.masked_scatter_acc_pallas,
                    {"block_n": 256, "interpret": True})):
        out = fn(acc, vals, scales, idx, mask, w_m, w_r,
                 quant_block=64, **kw)
        assert np.isfinite(np.asarray(out)).all()


def test_scatter_fold_dequantizes_like_reference():
    """scales fold as a per-group multiply of the values — pin against
    an explicit dense dequantize + scatter + weighted sum."""
    from repro.kernels.masked_agg import ops as agg_ops
    acc, vals, scales, idx, mask, w_m, w_r = _scatter_case(13)
    got = agg_ops.masked_scatter_acc_ref(acc, vals, scales, idx, mask,
                                         w_m, w_r, quant_block=64)
    want = np.asarray(acc).copy()
    for z in range(vals.shape[0]):
        deq = np.asarray(vals[z]).reshape(-1, 64) \
            * np.asarray(scales[z])[:, None]
        deq = deq.reshape(-1)
        w_at = np.where(np.asarray(mask)[np.asarray(idx[z])],
                        float(w_m[z]), float(w_r[z]))
        np.add.at(want, np.asarray(idx[z]), deq * w_at)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Trainer integration: upload-direction accounting under the v2 wire
# ---------------------------------------------------------------------------

def test_trainer_bills_sparse_uploads_separately():
    dense = _make_trainer(comm_dtype="int8")
    sparse = _make_trainer(comm_dtype="int8", topk_frac=1 / 16,
                           stochastic_rounding=True, error_feedback=True)
    assert sparse.bytes_down_per_round == dense.bytes_down_per_round
    assert sparse.bytes_up_per_round < dense.bytes_up_per_round
    f32 = _make_trainer()
    assert f32.bytes_up_per_round / sparse.bytes_up_per_round >= 10.0


def test_auto_chunk_budgets_int8_sidecar():
    """cohort_chunk="auto" under the int8 wire must budget the scale
    sidecar: the int8 stream copy is cheaper than f32, so the resolved
    chunk can only grow — and stream_bytes includes the sidecar."""
    layout = flatten.build_layout(LMAdapter(TINY).init(
        jax.random.PRNGKey(0)), total_multiple=2048)
    b8 = layout.stream_bytes(jnp.int8, quant_block=128)
    assert b8 == layout.n_flat + layout.n_flat // 128 * 4
    f32 = _make_trainer(cohort_chunk="auto", agg_memory_budget_mb=1.0)
    int8 = _make_trainer(cohort_chunk="auto", agg_memory_budget_mb=1.0,
                         comm_dtype="int8")
    assert int8.cohort_chunk >= f32.cohort_chunk
