"""Intra-repo markdown link checker (plain Python, no deps) — CI docs gate.

Walks every ``*.md`` under the repo root, extracts inline links and
images (``[text](target)`` / ``![alt](target)``), and fails on:

* a relative link whose target file/directory does not exist;
* a ``#fragment`` (same-file or cross-file into another ``.md``) that
  matches no heading's GitHub-style anchor slug.

External schemes (``http://``, ``https://``, ``mailto:``) are *not*
fetched — this gate is about the repo's own docs never rotting against
its own tree.  Links inside fenced code blocks are ignored (they are
examples, not navigation).

Usage: ``python tools/check_md_links.py [root]`` (default: repo root,
inferred from this file's location).  Exits 1 with a per-link report on
any broken link.
"""

from __future__ import annotations

import pathlib
import re
import sys

SKIP_DIRS = {".git", ".github", "__pycache__", ".pytest_cache"}

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
FENCE_RE = re.compile(r"^(```|~~~)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def heading_anchors(md_path: pathlib.Path) -> set:
    """GitHub-style anchor slugs of every heading in a markdown file."""
    anchors = set()
    counts = {}
    in_fence = False
    for line in md_path.read_text(encoding="utf-8").splitlines():
        if FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        text = m.group(1).strip()
        # strip inline code/links/emphasis markers, then slugify
        text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)
        text = re.sub(r"[`*_]", "", text)
        slug = re.sub(r"[^\w\- ]", "", text.lower()).strip()
        slug = re.sub(r"\s", "-", slug)
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def iter_links(md_path: pathlib.Path):
    """(line_number, target) for every inline link outside code fences."""
    in_fence = False
    for i, line in enumerate(md_path.read_text(encoding="utf-8")
                             .splitlines(), 1):
        if FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            yield i, m.group(1)


def check_file(md_path: pathlib.Path, root: pathlib.Path) -> list:
    errors = []
    for lineno, target in iter_links(md_path):
        if target.startswith(EXTERNAL):
            continue
        path_part, _, fragment = target.partition("#")
        if not path_part:                       # same-file #anchor
            dest = md_path
        else:
            dest = (md_path.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{md_path.relative_to(root)}:{lineno}: "
                              f"broken link -> {target}")
                continue
        if fragment and dest.suffix == ".md" and dest.is_file():
            if fragment.lower() not in heading_anchors(dest):
                errors.append(f"{md_path.relative_to(root)}:{lineno}: "
                              f"missing anchor -> {target}")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = pathlib.Path(argv[0]).resolve() if argv else \
        pathlib.Path(__file__).resolve().parent.parent
    md_files = sorted(
        p for p in root.rglob("*.md")
        if not any(part in SKIP_DIRS for part in p.parts))
    errors = []
    for md in md_files:
        errors.extend(check_file(md, root))
    if errors:
        print(f"BROKEN MARKDOWN LINKS ({len(errors)}):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"docs ok: {len(md_files)} markdown files, all intra-repo "
          f"links resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
