"""Render a telemetry JSONL run log as a human-readable summary.

Thin CLI wrapper over :mod:`repro.obs.report` (the importable, tested
logic).  Typical use, after a run with ``--telemetry --telemetry-out``:

    PYTHONPATH=src python tools/obs_report.py run.jsonl
    PYTHONPATH=src python tools/obs_report.py run.jsonl --target 0.15
    PYTHONPATH=src python tools/obs_report.py --compare a.jsonl b.jsonl

``--target`` reports rounds-to-target on ``--metric`` (default
``loss_complex``) — the headline FedHeN comparison number.  ``--compare``
diffs two run logs (B relative to A): per-phase wall clock, bytes/round,
rounds-to-target — the A/B view a SCAFFOLD-vs-FedAvg or wire-format
experiment reads.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(
    0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.obs.report import compare_paths, report_path  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Render (or diff) telemetry JSONL run logs")
    ap.add_argument("jsonl", nargs="?", default=None,
                    help="run log written by --telemetry-out")
    ap.add_argument("--compare", nargs=2, metavar=("A", "B"), default=None,
                    help="diff two run logs instead (B relative to A)")
    ap.add_argument("--target", type=float, default=None,
                    help="rounds-to-target threshold on --metric")
    ap.add_argument("--metric", default="loss_complex",
                    help="eval metric for --target (default: loss_complex)")
    args = ap.parse_args(argv)
    if args.compare is not None:
        if args.jsonl is not None:
            ap.error("pass either a single run log or --compare A B, "
                     "not both")
        print(compare_paths(args.compare[0], args.compare[1],
                            target=args.target,
                            target_metric=args.metric))
        return 0
    if args.jsonl is None:
        ap.error("a run log is required (or --compare A B)")
    print(report_path(args.jsonl, target=args.target,
                      target_metric=args.metric))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
