"""Render a telemetry JSONL run log as a human-readable summary.

Thin CLI wrapper over :mod:`repro.obs.report` (the importable, tested
logic).  Typical use, after a run with ``--telemetry --telemetry-out``:

    PYTHONPATH=src python tools/obs_report.py run.jsonl
    PYTHONPATH=src python tools/obs_report.py run.jsonl --target 0.15

``--target`` reports rounds-to-target on ``--metric`` (default
``loss_complex``) — the headline FedHeN comparison number.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(
    0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.obs.report import report_path  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Render a telemetry JSONL run log")
    ap.add_argument("jsonl", help="run log written by --telemetry-out")
    ap.add_argument("--target", type=float, default=None,
                    help="rounds-to-target threshold on --metric")
    ap.add_argument("--metric", default="loss_complex",
                    help="eval metric for --target (default: loss_complex)")
    args = ap.parse_args(argv)
    print(report_path(args.jsonl, target=args.target,
                      target_metric=args.metric))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
