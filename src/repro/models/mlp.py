"""Dense GLU MLP and Mixture-of-Experts layers.

MoE uses capacity-based top-k routing with a *per-sequence* routing group:
each batch element routes its own tokens into an ``(E, C, D)`` buffer via a
one-hot-free gather.  This keeps the dispatch local to the ``data`` mesh
shards (batch-aligned gather), so under pjit the only cross-shard collective
the layer needs is the expert-output combine (an all-reduce over ``model``
when experts or expert-ffn dims are model-sharded) — the classic
expert/tensor-parallel hybrid.  Dropped tokens (over capacity) fall into a
garbage slot and are zero-combined, as in Switch/GShard.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import common
from repro.models.common import Policy, NO_POLICY


# ---------------------------------------------------------------------------
# Dense GLU MLP (gate, up, down)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.jnp_param_dtype()
    kg, ku, kd = jax.random.split(key, 3)
    p = {
        "up": common.dense_init(ku, (d, f), dt),
        "down": common.dense_init(kd, (f, d), dt, fan_in=f),
    }
    if cfg.mlp_glu:
        p["gate"] = common.dense_init(kg, (d, f), dt)
    return p


def apply_mlp(p: dict, x: jax.Array, policy: Policy = NO_POLICY) -> jax.Array:
    u = jnp.einsum("...d,df->...f", x, p["up"].astype(x.dtype))
    if "gate" in p:
        g = jnp.einsum("...d,df->...f", x, p["gate"].astype(x.dtype))
        h = jax.nn.gelu(g) * u
    else:
        h = jax.nn.gelu(u)
    h = policy.constrain(h, ("batch", "seq", "ffn"))
    return jnp.einsum("...f,fd->...d", h, p["down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    de = m.d_expert or cfg.d_ff
    dt = cfg.jnp_param_dtype()
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    # router always spans the REAL experts; only the weight tensors pad
    e = max(m.pad_to, m.n_experts) if m.pad_to else m.n_experts
    p = {
        "router": common.dense_init(kr, (d, m.n_experts), jnp.float32),
        "experts": {
            "gate": common.dense_init(kg, (e, d, de), dt, fan_in=d),
            "up": common.dense_init(ku, (e, d, de), dt, fan_in=d),
            "down": common.dense_init(kd, (e, de, d), dt, fan_in=de),
        },
    }
    if m.n_shared:
        sub = jax.random.split(ks, m.n_shared)
        p["shared"] = [init_mlp(sub[i], cfg, d_ff=de) for i in range(m.n_shared)]
    return p


def _capacity(moe: MoEConfig, tokens_per_group: int) -> int:
    c = int(moe.top_k * tokens_per_group * moe.capacity_factor / moe.n_experts)
    return max(min(c, tokens_per_group), 1)


def route_topk(router_logits: jax.Array, moe: MoEConfig,
               capacity: int, e_pad: int = 0
               ) -> Tuple[jax.Array, jax.Array, jax.Array, dict]:
    """Top-k routing with per-group capacity.

    router_logits: (B, S, E).  Returns
      slot_idx  (B, E, C) int32 token index per expert slot (S = garbage),
      slot_gate (B, E, C) f32 combine weight per slot (0 for empty),
      token_expert (B, S, K) chosen expert per token (diagnostics),
      aux: router z-loss and load-balance loss terms.
    """
    b, s, e = router_logits.shape
    logits = router_logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    e_out = max(e_pad, e)

    topk_prob, topk_idx = jax.lax.top_k(probs, moe.top_k)       # (B, S, K)
    # normalize the combine weights over the selected experts
    topk_prob = topk_prob / jnp.maximum(
        jnp.sum(topk_prob, axis=-1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert's queue
    onehot = jax.nn.one_hot(topk_idx, e, dtype=jnp.int32)       # (B, S, K, E)
    flat = onehot.reshape(b, s * moe.top_k, e)
    rank = jnp.cumsum(flat, axis=1) - flat                      # (B, S*K, E)
    rank = jnp.sum(rank * flat, axis=-1).reshape(b, s, moe.top_k)
    within = rank < capacity

    # scatter token indices into (B, E, C) slots
    tok_ids = jnp.broadcast_to(jnp.arange(s)[None, :, None], (b, s, moe.top_k))
    # buffers sized to the (possibly padded) expert axis; pad experts can
    # never appear in topk_idx so their slots stay at the garbage index
    slot_idx = jnp.full((b, e_out, capacity), s, dtype=jnp.int32)
    slot_gate = jnp.zeros((b, e_out, capacity), dtype=jnp.float32)

    flat_e = topk_idx.reshape(b, -1)
    flat_r = rank.reshape(b, -1)
    flat_t = tok_ids.reshape(b, -1)
    flat_g = jnp.where(within, topk_prob, 0.0).reshape(b, -1)
    flat_keep = within.reshape(b, -1)
    # out-of-capacity entries scatter to a dummy slot via clamped rank? No:
    # drop them by redirecting to expert-slot (e-1, capacity-1)? Cleaner: use
    # mode="drop" — JAX scatters with out-of-bound indices are dropped.
    flat_r = jnp.where(flat_keep, flat_r, capacity)             # OOB -> dropped

    def scatter_one(si, sg, te, tr, tt, tg):
        idx = jnp.stack([te, tr], axis=-1)                      # (S*K, 2)
        dnums = jax.lax.ScatterDimensionNumbers(
            update_window_dims=(), inserted_window_dims=(0, 1),
            scatter_dims_to_operand_dims=(0, 1))
        si = jax.lax.scatter(si, idx, tt, dnums,
                             mode=jax.lax.GatherScatterMode.FILL_OR_DROP)
        sg = jax.lax.scatter(sg, idx, tg, dnums,
                             mode=jax.lax.GatherScatterMode.FILL_OR_DROP)
        return si, sg

    slot_idx, slot_gate = jax.vmap(scatter_one)(
        slot_idx, slot_gate, flat_e, flat_r, flat_t, flat_g)

    # aux losses (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                           # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topk_idx[..., 0], e), axis=1) / s, axis=0)
    load_balance = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"load_balance": load_balance * moe.load_balance_loss,
           "router_z": z_loss * moe.router_z_loss}
    return slot_idx, slot_gate, topk_idx, aux


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig,
              policy: Policy = NO_POLICY) -> Tuple[jax.Array, dict]:
    """x: (B, S, D) -> (out, aux_losses)."""
    m = cfg.moe
    b, s, d = x.shape
    capacity = _capacity(m, s)
    e_pad = max(m.pad_to, m.n_experts) if m.pad_to else m.n_experts

    router_logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                               p["router"])
    slot_idx, slot_gate, _, aux = route_topk(router_logits, m, capacity,
                                             e_pad=e_pad)

    # dispatch: gather tokens into (B, E, C, D); garbage index S reads zeros
    xp = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    dispatched = jnp.take_along_axis(
        xp[:, None, :, :],                                      # (B, 1, S+1, D)
        slot_idx[..., None].clip(0, s),                         # (B, E, C, 1)
        axis=2)                                                 # (B, E, C, D)
    dispatched = policy.constrain(dispatched, ("batch", "experts", None, None))

    w = p["experts"]
    g = jnp.einsum("becd,edf->becf", dispatched, w["gate"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", dispatched, w["up"].astype(x.dtype))
    h = jax.nn.gelu(g) * u
    h = policy.constrain(h, ("batch", "experts", None, "expert_ffn"))
    y = jnp.einsum("becf,efd->becd", h, w["down"].astype(x.dtype))

    # combine: scatter-add back to token positions, weighted by gate
    y = y * slot_gate[..., None].astype(y.dtype)
    flat_y = y.reshape(b, e_pad * capacity if m.pad_to else
                       m.n_experts * capacity, d)
    flat_i = slot_idx.reshape(b, -1)

    def combine_one(buf, idx, vals):
        return buf.at[idx].add(vals, mode="drop")

    out = jax.vmap(combine_one)(jnp.zeros((b, s, d), y.dtype), flat_i, flat_y)
    out = policy.constrain(out, ("batch", "seq", None))

    for shared in p.get("shared", []):
        out = out + apply_mlp(shared, x, policy)
    return out, aux
