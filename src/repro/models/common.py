"""Shared building blocks: norms, embeddings, init helpers, sharding hooks.

Parameters are plain nested dicts of ``jnp`` arrays.  Every ``init_*``
function takes an explicit PRNG key; every ``apply_*`` function is pure.

Sharding is threaded through a :class:`Policy` object: model code annotates
activations with *logical axis names* and the policy (installed by
``launch/sharding.py``) resolves them to ``with_sharding_constraint`` under a
mesh, or to the identity on a single device (smoke tests).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Sharding policy hook
# ---------------------------------------------------------------------------

class Policy:
    """No-op default policy (single device).  See launch/sharding.py."""

    def constrain(self, x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
        return x


NO_POLICY = Policy()


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    """Truncated-normal fan-in init (LeCun-ish), matching common LLM practice."""
    if fan_in is None:
        fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = 1.0 / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm (gemma-style: weight is a residual around 1)
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}


def apply_rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    out = x * (1.0 + p["scale"].astype(jnp.float32))
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# GroupNorm (paper footnote 1: replaces BatchNorm in all ResNets)
# ---------------------------------------------------------------------------

def init_groupnorm(channels: int, dtype) -> dict:
    return {"scale": jnp.ones((channels,), dtype),
            "bias": jnp.zeros((channels,), dtype)}


def apply_groupnorm(p: dict, x: jax.Array, groups: int = 8,
                    eps: float = 1e-5) -> jax.Array:
    """x: (B, H, W, C) channels-last."""
    b, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    dtype = x.dtype
    xf = x.astype(jnp.float32).reshape(b, h, w, g, c // g)
    mean = jnp.mean(xf, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xf, axis=(1, 2, 4), keepdims=True)
    xf = (xf - mean) * jax.lax.rsqrt(var + eps)
    xf = xf.reshape(b, h, w, c)
    return (xf * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Softcap (gemma-2)
# ---------------------------------------------------------------------------

def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                       # (head_dim // 2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, N, Dh); positions: (B, S) or (S,) int32."""
    b, s, n, dh = x.shape
    freqs = rope_frequencies(dh, theta)                    # (dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Token embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype) -> dict:
    return {"table": embed_init(key, (vocab, d_model), dtype)}


def apply_embedding(p: dict, tokens: jax.Array, *, scale: bool = True) -> jax.Array:
    h = jnp.take(p["table"], tokens, axis=0)
    if scale:
        h = h * jnp.asarray(jnp.sqrt(p["table"].shape[-1]), h.dtype)
    return h


def apply_unembedding(p: dict, h: jax.Array) -> jax.Array:
    return jnp.einsum("...d,vd->...v", h, p["table"])


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy_sum(logits: jax.Array, labels: jax.Array
                              ) -> jax.Array:
    """Sum (not mean) of per-position NLL; sharding-friendly (see below)."""
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - m).astype(jnp.float32)
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) \
        + m[..., 0].astype(jnp.float32)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1).astype(jnp.float32)
    return jnp.sum(logz - gold)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean CE over (optionally masked) positions.  logits: (..., V).

    Written to stay efficient when the vocab axis is model-sharded: the
    gold logit is picked with a one-hot contraction (local + all-reduce)
    rather than take_along_axis (which would all-gather the full logits),
    and reductions accumulate in f32 while logits stay in their compute
    dtype.
    """
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - m).astype(jnp.float32)
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) \
        + m[..., 0].astype(jnp.float32)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1).astype(jnp.float32)
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
