"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM (scalar).

mLSTM
-----
Matrix-memory cell with exponential input gate and sigmoid forget gate,
stabilized by the running max ``m``:

    m_t = max(logsig(f~_t) + m_{t-1}, i~_t)
    f'  = exp(logsig(f~_t) + m_{t-1} - m_t);  i' = exp(i~_t - m_t)
    C_t = f' C_{t-1} + i' v_t k_t^T;          n_t = f' n_{t-1} + i' k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, exp(-m_t))

Two equivalent implementations:
* ``mlstm_recurrent`` — step-by-step ``lax.scan`` (decode path + test oracle)
* ``mlstm_chunked`` — chunkwise-parallel form (train/prefill path): intra-chunk
  terms are an attention-like (L x L) product on the MXU; inter-chunk state is
  carried by a scan over chunks.  This is the TPU-native adaptation of the
  paper's fused CUDA kernel.

sLSTM
-----
Scalar-memory cell with per-head block-diagonal recurrence — inherently
sequential (the paper's point); implemented as ``lax.scan`` over time.

Block layout follows the xLSTM-1.3B residual stacking: mLSTM blocks are
pre-norm -> up-proj (x2) -> conv+swish -> mLSTM -> groupnorm -> gated -> down;
sLSTM blocks are pre-norm -> sLSTM -> groupnorm -> gated FFN (factor 4/3).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import Policy, NO_POLICY


# ===========================================================================
# mLSTM
# ===========================================================================

def _mlstm_dims(cfg: ModelConfig):
    d_inner = int(cfg.d_model * cfg.mlstm_proj_factor)
    nh = cfg.n_heads
    return d_inner, nh, d_inner // nh


def init_mlstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, nh, dh = _mlstm_dims(cfg)
    dt = cfg.jnp_param_dtype()
    ks = jax.random.split(key, 8)
    return {
        "w_up": common.dense_init(ks[0], (d, di), dt),
        "w_gate": common.dense_init(ks[1], (d, di), dt),
        "conv": common.dense_init(ks[2], (4, di), dt, fan_in=4),
        # block-diagonal (per-head) q/k/v projections, as in xLSTM
        "wq": common.dense_init(ks[3], (nh, dh, dh), dt, fan_in=dh),
        "wk": common.dense_init(ks[4], (nh, dh, dh), dt, fan_in=dh),
        "wv": common.dense_init(ks[5], (nh, dh, dh), dt, fan_in=dh),
        "w_if": common.dense_init(ks[6], (di, 2 * nh), jnp.float32, fan_in=di),
        "b_if": jnp.concatenate([jnp.zeros((nh,)),          # input gate bias
                                 jnp.full((nh,), 3.0)]),     # forget bias +3
        "norm": common.init_rmsnorm(dh, dt),
        "w_down": common.dense_init(ks[7], (di, d), dt, fan_in=di),
    }


def _mlstm_qkv_gates(p: dict, h_in: jax.Array, cfg: ModelConfig,
                     conv_window: Optional[jax.Array] = None):
    """Shared pre-computation. h_in (B, S, D)."""
    di, nh, dh = _mlstm_dims(cfg)
    x = jnp.einsum("bsd,de->bse", h_in, p["w_up"].astype(h_in.dtype))
    z = jnp.einsum("bsd,de->bse", h_in, p["w_gate"].astype(h_in.dtype))
    # causal depthwise conv + swish (xLSTM uses conv before q/k only; we
    # follow the reference and feed the conv'd activation to q, k and gates,
    # raw x to v)
    w = p["conv"].astype(x.dtype)
    tw = w.shape[0]
    if conv_window is None:
        pad = jnp.zeros((x.shape[0], tw - 1, x.shape[-1]), x.dtype)
    else:
        pad = conv_window.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    xc = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(tw))
    xc = jax.nn.swish(xc)

    b, s, _ = x.shape
    xch = xc.reshape(b, s, nh, dh)
    xh = x.reshape(b, s, nh, dh)
    q = jnp.einsum("bshd,hde->bshe", xch, p["wq"].astype(x.dtype))
    k = jnp.einsum("bshd,hde->bshe", xch, p["wk"].astype(x.dtype))
    v = jnp.einsum("bshd,hde->bshe", xh, p["wv"].astype(x.dtype))
    k = k / jnp.asarray(dh ** 0.5, k.dtype)
    gates = jnp.einsum("bse,eg->bsg", xc.astype(jnp.float32), p["w_if"]) + p["b_if"]
    i_raw, f_raw = gates[..., :nh], gates[..., nh:]          # (B, S, NH)
    log_f = jax.nn.log_sigmoid(f_raw)
    return x, z, q, k, v, i_raw, log_f


def _mlstm_out(p: dict, h_cell: jax.Array, z: jax.Array, cfg: ModelConfig):
    """h_cell: (B, S, NH, DH) -> (B, S, D)."""
    b, s, nh, dh = h_cell.shape
    h_cell = common.apply_rmsnorm(p["norm"], h_cell, cfg.norm_eps)
    h = h_cell.reshape(b, s, nh * dh) * jax.nn.swish(z)
    return jnp.einsum("bse,ed->bsd", h, p["w_down"].astype(h.dtype))


# -- recurrent oracle / decode ------------------------------------------------

def mlstm_cell_step(q, k, v, i_raw, log_f, state):
    """One step.  q/k/v: (B, NH, DH); i_raw/log_f: (B, NH).

    state: dict(C (B,NH,DH,DH), n (B,NH,DH), m (B,NH)) all f32.
    Returns (h (B,NH,DH) f32, new state).
    """
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    m_new = jnp.maximum(log_f + state["m"], i_raw)
    f_p = jnp.exp(log_f + state["m"] - m_new)[..., None]
    i_p = jnp.exp(i_raw - m_new)[..., None]
    C = f_p[..., None] * state["C"] + i_p[..., None] * (vf[..., :, None] *
                                                        kf[..., None, :])
    n = f_p * state["n"] + i_p * kf
    num = jnp.einsum("bhij,bhj->bhi", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qf)),
                      jnp.exp(-m_new))[..., None]
    return num / den, {"C": C, "n": n, "m": m_new}


def init_mlstm_state(cfg: ModelConfig, batch: int) -> dict:
    di, nh, dh = _mlstm_dims(cfg)
    return {"C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, nh, dh), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


def mlstm_recurrent(q, k, v, i_raw, log_f, state=None):
    """Oracle: scan mlstm_cell_step over S.  q/k/v: (B, S, NH, DH)."""
    b, s, nh, dh = q.shape
    if state is None:
        state = {"C": jnp.zeros((b, nh, dh, dh), jnp.float32),
                 "n": jnp.zeros((b, nh, dh), jnp.float32),
                 "m": jnp.full((b, nh), -1e30, jnp.float32)}

    def body(st, xs):
        h, st = mlstm_cell_step(*xs, st)
        return st, h

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), i_raw.transpose(1, 0, 2),
          log_f.transpose(1, 0, 2))
    state, hs = jax.lax.scan(body, state, xs)
    return hs.transpose(1, 0, 2, 3), state


# -- chunkwise parallel form --------------------------------------------------

def mlstm_chunked(q, k, v, i_raw, log_f, chunk: int = 64, state=None):
    """Chunkwise-parallel mLSTM.  q/k/v: (B, S, NH, DH) -> (B, S, NH, DH) f32.

    Equivalent to ``mlstm_recurrent`` (validated in tests); intra-chunk work
    is an (L x L) masked attention-like product, inter-chunk state is carried
    by a scan.
    """
    b, s, nh, dh = q.shape
    if s % chunk:
        raise ValueError(f"seq {s} % chunk {chunk} != 0")
    nc = s // chunk

    def rs(t):  # (B, S, NH, X) -> (NC, B, NH, L, X); keep storage dtype —
        # f32 casts happen per chunk inside the (checkpointed) body so the
        # full-sequence tensors are never materialized in f32
        return t.reshape(b, nc, chunk, nh, -1).transpose(1, 0, 3, 2, 4)

    qc, kc, vc = rs(q), rs(k), rs(v)
    ic = i_raw.reshape(b, nc, chunk, nh).transpose(1, 0, 3, 2)   # (NC,B,NH,L)
    fc = log_f.reshape(b, nc, chunk, nh).transpose(1, 0, 3, 2)

    if state is None:
        state = {"C": jnp.zeros((b, nh, dh, dh), jnp.float32),
                 "n": jnp.zeros((b, nh, dh), jnp.float32),
                 "m": jnp.full((b, nh), -1e30, jnp.float32)}

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    @jax.checkpoint   # recompute chunk intermediates in backward
    def body(st, xs):
        qb, kb, vb, ib, fb = xs                      # (B,NH,L,DH) / (B,NH,L)
        qb = qb.astype(jnp.float32)
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
        bcum = jnp.cumsum(fb, axis=-1)               # inclusive logF cumsum
        btot = bcum[..., -1:]
        # intra-chunk log weights D[j,t] = bcum_j - bcum_t + i_t  (t <= j)
        dmat = bcum[..., :, None] - bcum[..., None, :] + ib[..., None, :]
        dmat = jnp.where(causal, dmat, -jnp.inf)
        m_intra = jnp.max(dmat, axis=-1)             # (B,NH,L)
        m_inter = st["m"][..., None] + bcum          # (B,NH,L)
        m_j = jnp.maximum(m_inter, m_intra)
        # inter contribution
        w_inter = jnp.exp(m_inter - m_j)             # (B,NH,L)
        # h_i = sum_j C[i, j] q_j : contract q against C's key index (axis -1)
        h_inter = jnp.einsum("bhle,bhde->bhld", qb, st["C"]) * w_inter[..., None]
        n_inter = st["n"][..., None, :] * w_inter[..., None]
        # intra contribution
        wmat = jnp.exp(dmat - m_j[..., None])        # (B,NH,L,L)
        scores = jnp.einsum("bhld,bhtd->bhlt", qb, kb) * wmat
        h_intra = jnp.einsum("bhlt,bhtd->bhld", scores, vb)
        n_intra = jnp.einsum("bhlt,bhtd->bhld", wmat, kb)
        n_j = n_inter + n_intra
        den = jnp.maximum(jnp.abs(jnp.einsum("bhld,bhld->bhl", n_j, qb)),
                          jnp.exp(-m_j))
        h = (h_inter + h_intra) / den[..., None]
        # chunk-end state update
        m_endi = jnp.max(btot - bcum + ib, axis=-1)  # (B,NH)
        m_end = jnp.maximum(st["m"] + btot[..., 0], m_endi)
        w_old = jnp.exp(st["m"] + btot[..., 0] - m_end)
        w_new = jnp.exp(btot - bcum + ib - m_end[..., None])  # (B,NH,L)
        C = (st["C"] * w_old[..., None, None]
             + jnp.einsum("bhl,bhld,bhle->bhde", w_new, vb, kb))
        n = st["n"] * w_old[..., None] + jnp.einsum("bhl,bhld->bhd", w_new, kb)
        return {"C": C, "n": n, "m": m_end}, h

    state, hs = jax.lax.scan(body, state, (qc, kc, vc, ic, fc))
    # hs: (NC, B, NH, L, DH) -> (B, S, NH, DH)
    return hs.transpose(1, 0, 3, 2, 4).reshape(b, s, nh, dh), state


def apply_mlstm(p: dict, h_in: jax.Array, cfg: ModelConfig,
                policy: Policy = NO_POLICY, return_state: bool = False):
    """Train/prefill. (B, S, D) -> (B, S, D)."""
    x, z, q, k, v, i_raw, log_f = _mlstm_qkv_gates(p, h_in, cfg)
    q = policy.constrain(q, ("batch", "seq", None, "mlstm_dh"))
    k = policy.constrain(k, ("batch", "seq", None, "mlstm_dh"))
    v = policy.constrain(v, ("batch", "seq", None, "mlstm_dh"))
    h, state = mlstm_chunked(q, k, v, i_raw, log_f, chunk=cfg.mlstm_chunk)
    out = _mlstm_out(p, h.astype(h_in.dtype), z, cfg)
    if return_state:
        state = dict(state)
        state["conv"] = x[:, -3:].astype(cfg.jnp_compute_dtype())
        return out, state
    return out


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> dict:
    di, _, _ = _mlstm_dims(cfg)
    st = init_mlstm_state(cfg, batch)
    st["conv"] = jnp.zeros((batch, 3, di), cfg.jnp_compute_dtype())
    return st


def apply_mlstm_decode(p: dict, h_in: jax.Array, cache: dict,
                       cfg: ModelConfig,
                       policy: Policy = NO_POLICY) -> Tuple[jax.Array, dict]:
    conv_win = cache["conv"]
    x, z, q, k, v, i_raw, log_f = _mlstm_qkv_gates(p, h_in, cfg,
                                                   conv_window=conv_win)
    state = {k_: cache[k_] for k_ in ("C", "n", "m")}
    h, state = mlstm_cell_step(q[:, 0], k[:, 0], v[:, 0],
                               i_raw[:, 0], log_f[:, 0], state)
    out = _mlstm_out(p, h[:, None].astype(h_in.dtype), z, cfg)
    new_cache = dict(state)
    new_cache["conv"] = jnp.concatenate(
        [conv_win, x.astype(conv_win.dtype)], axis=1)[:, 1:]
    return out, new_cache


# ===========================================================================
# sLSTM
# ===========================================================================

def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    dff = int(d * cfg.slstm_ff_factor)
    dt = cfg.jnp_param_dtype()
    ks = jax.random.split(key, 4)
    return {
        # input projections for i, f, z, o stacked: (D, 4D)
        "w_x": common.dense_init(ks[0], (d, 4 * d), dt),
        # block-diagonal recurrent weights per gate: (4, NH, DH, DH)
        "r": common.dense_init(ks[1], (4, nh, dh, dh), jnp.float32, fan_in=dh),
        "b": jnp.concatenate([jnp.zeros((2 * d,)),
                              jnp.zeros((d,)),
                              jnp.zeros((d,))]).reshape(4, d).astype(jnp.float32)
             .at[1].set(1.0),                       # forget bias +1
        "norm": common.init_rmsnorm(dh, dt),
        "ff_gate": common.dense_init(ks[2], (d, dff), dt),
        "ff_down": common.dense_init(ks[3], (dff, d), dt, fan_in=dff),
    }


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z,
            "m": jnp.zeros((batch, nh, dh), jnp.float32)}


def slstm_cell_step(xg: jax.Array, r: jax.Array, state: dict):
    """Reference single-step sLSTM (kept as an oracle for the custom-VJP
    block path below).  xg: (B, 4, NH, DH) preactivations, bias included."""
    rec = jnp.einsum("bhj,ghij->gbhi", state["h"], r)      # (4, B, NH, DH)
    i_t = xg[:, 0] + rec[0]
    f_t = xg[:, 1] + rec[1]
    z_t = xg[:, 2] + rec[2]
    o_t = xg[:, 3] + rec[3]
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + state["m"], i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(log_f + state["m"] - m_new)
    c = f_p * state["c"] + i_p * jnp.tanh(z_t)
    n = f_p * state["n"] + i_p
    h = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


def _slstm_step_pure(xg_t: jax.Array, rec_t: jax.Array, state: dict) -> dict:
    """One sLSTM step with the recurrent contribution precomputed.
    xg_t: (B, 4, NH, DH); rec_t: (4, B, NH, DH)."""
    i_t = xg_t[:, 0] + rec_t[0]
    f_t = xg_t[:, 1] + rec_t[1]
    z_t = xg_t[:, 2] + rec_t[2]
    o_t = xg_t[:, 3] + rec_t[3]
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + state["m"], i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(log_f + state["m"] - m_new)
    c = f_p * state["c"] + i_p * jnp.tanh(z_t)
    n = f_p * state["n"] + i_p
    h = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


@jax.custom_vjp
def slstm_block(xg_b: jax.Array, r: jax.Array, state: dict):
    """A block of sLSTM steps.  xg_b: (B, T, 4, NH, DH).

    Custom VJP: under SPMD, autodiff of a per-step scan accumulates the
    recurrent-weight cotangent into a replicated carry, which pins one
    16 MB all-reduce INSIDE the time loop (measured 384 GiB/step at
    S=4096).  The hand-rolled backward instead emits *batch-sharded*
    per-step cotangents (drec, h_prev) as scan outputs — no communication —
    and contracts dr with ONE einsum per block: a single all-reduce per
    block, ~T x fewer collectives for identical math (validated against
    autodiff in tests/test_slstm_vjp.py).
    """
    def step(st, xg_t):
        rec = jnp.einsum("bhj,ghij->gbhi", st["h"], r)
        new = _slstm_step_pure(xg_t, rec, st)
        return new, new["h"]

    stT, hs = jax.lax.scan(step, state, xg_b.transpose(1, 0, 2, 3, 4))
    return hs.transpose(1, 0, 2, 3), stT


def _slstm_block_fwd(xg_b, r, state):
    out = slstm_block(xg_b, r, state)
    return out, (xg_b, r, state)


def _slstm_block_bwd(res, cot):
    xg_b, r, state0 = res
    dhs, dstT = cot                      # (B, T, NH, DH), state cotangent
    xg_t_first = xg_b.transpose(1, 0, 2, 3, 4)   # (T, B, 4, NH, DH)

    # 1) forward replay, stacking prev-states and rec (batch-sharded ys)
    def fstep(st, xg_t):
        rec = jnp.einsum("bhj,ghij->gbhi", st["h"], r)
        new = _slstm_step_pure(xg_t, rec, st)
        return new, (st, rec)

    _, (prev_states, recs) = jax.lax.scan(fstep, state0, xg_t_first)

    # 2) reverse sweep: vjp of the pure step; drec/dxg leave as sharded ys
    def bstep(dst, xs):
        xg_t, rec_t, prev_st, dh_t = xs
        _, vjp = jax.vjp(_slstm_step_pure, xg_t, rec_t, prev_st)
        dnew = dict(dst)
        dnew["h"] = dst["h"] + dh_t
        dxg, drec, dprev = vjp(dnew)
        dprev = dict(dprev)
        dprev["h"] = dprev["h"] + jnp.einsum("gbhi,ghij->bhj", drec, r)
        return dprev, (dxg, drec)

    dhs_t = dhs.transpose(1, 0, 2, 3)
    dst0, (dxgs, drecs) = jax.lax.scan(
        bstep, dict(dstT), (xg_t_first, recs, prev_states, dhs_t),
        reverse=True)

    # 3) ONE weight-grad contraction per block (single partial -> one AR)
    dr = jnp.einsum("tgbhi,tbhj->ghij", drecs, prev_states["h"])
    return dxgs.transpose(1, 0, 2, 3, 4), dr, dst0


slstm_block.defvjp(_slstm_block_fwd, _slstm_block_bwd)


def _slstm_core(p: dict, h_in: jax.Array, cfg: ModelConfig, state: dict,
                block: int = 128):
    """Sequential sLSTM over time, scanned in blocks of custom-VJP
    ``slstm_block`` (see its docstring for the collective analysis)."""
    b, s, d = h_in.shape
    nh = cfg.n_heads
    dh = d // nh
    xg = jnp.einsum("bsd,dg->bsg", h_in.astype(jnp.float32),
                    p["w_x"].astype(jnp.float32))
    xg = xg.reshape(b, s, 4, d) + p["b"][None, None]
    xg = xg.reshape(b, s, 4, nh, dh)
    r = p["r"]

    block = min(block, s)
    if s % block:
        block = 1
    nb = s // block

    def body(st, xb):                    # xb: (B, block, 4, NH, DH)
        hs, st = slstm_block(xb, r, st)
        return st, hs

    xb = xg.reshape(b, nb, block, 4, nh, dh).transpose(1, 0, 2, 3, 4, 5)
    state, hs = jax.lax.scan(body, state, xb)
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, dh)
    return hs, state                                       # (B, S, NH, DH)


def _slstm_out(p: dict, hs: jax.Array, cfg: ModelConfig):
    b, s, nh, dh = hs.shape
    hs = common.apply_rmsnorm(p["norm"], hs.astype(jnp.bfloat16), cfg.norm_eps)
    h = hs.reshape(b, s, nh * dh)
    g = jnp.einsum("bsd,df->bsf", h, p["ff_gate"].astype(h.dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.gelu(g), p["ff_down"].astype(h.dtype))


def apply_slstm(p: dict, h_in: jax.Array, cfg: ModelConfig,
                policy: Policy = NO_POLICY, return_state: bool = False):
    state = init_slstm_state(cfg, h_in.shape[0])
    hs, state = _slstm_core(p, h_in, cfg, state)
    out = _slstm_out(p, hs, cfg).astype(h_in.dtype)
    if return_state:
        return out, state
    return out


def init_slstm_cache(cfg: ModelConfig, batch: int) -> dict:
    return init_slstm_state(cfg, batch)


def apply_slstm_decode(p: dict, h_in: jax.Array, cache: dict,
                       cfg: ModelConfig,
                       policy: Policy = NO_POLICY) -> Tuple[jax.Array, dict]:
    hs, state = _slstm_core(p, h_in, cfg, cache)
    out = _slstm_out(p, hs, cfg).astype(h_in.dtype)
    return out, state
