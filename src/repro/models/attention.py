"""GQA attention: global/sliding-window, RoPE, softcap, KV caches, decode.

Three execution regimes, all sharing the same parameters:

* ``train/prefill`` — chunked causal attention.  Queries are processed in
  chunks of ``q_chunk`` via ``lax.scan`` so the score matrix is
  O(chunk x keys) rather than O(S^2) memory.  Local layers slice only the
  ``chunk + window`` keys they can see, so their FLOPs are O(S * window).
* ``decode`` — one query token against a KV cache.  Local layers keep a
  ring-buffer cache of size ``window`` (RoPE is applied at write time, so
  ring rotation is harmless); global layers keep the full ``S`` cache.
* ``pallas`` — the sliding-window flash kernel in ``repro/kernels`` is the
  TPU target; this module is also its reference semantics.

Shapes: hidden (B, S, D); q (B, S, H, Dh); k/v (B, S, Kh, Dh).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import Policy, NO_POLICY

NEG_INF = -2.0 ** 30  # large-but-finite: keeps fully-masked rows NaN-free


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> dict:
    d, h, kh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.resolved_head_dim
    dt = cfg.jnp_param_dtype()
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": common.dense_init(kq, (d, h, dh), dt, fan_in=d),
        "wk": common.dense_init(kk, (d, kh, dh), dt, fan_in=d),
        "wv": common.dense_init(kv, (d, kh, dh), dt, fan_in=d),
        "wo": common.dense_init(ko, (h, dh, d), dt, fan_in=h * dh),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = common.init_rmsnorm(dh, dt)
        p["k_norm"] = common.init_rmsnorm(dh, dt)
    return p


# ---------------------------------------------------------------------------
# Core masked attention over an explicit key block
# ---------------------------------------------------------------------------

def _attend(q, k, v, mask, softcap_val: float):
    """q: (B, Sq, Kh, G, Dh); k/v: (B, Sk, Kh, Dh); mask: (B|1, Sq, Sk)."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = common.softcap(logits, softcap_val)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out


def _split_gqa(q, n_kv: int):
    b, s, h, dh = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, dh)


def _merge_gqa(o):
    b, s, kh, g, dh = o.shape
    return o.reshape(b, s, kh * g, dh)


# ---------------------------------------------------------------------------
# Chunked causal attention (train / prefill)
# ---------------------------------------------------------------------------

def chunked_causal_attention(q, k, v, *, window: int = 0,
                             softcap_val: float = 0.0,
                             q_chunk: int = 512) -> jax.Array:
    """Causal (optionally sliding-window) attention without an S^2 buffer.

    q: (B, S, H, Dh); k, v: (B, S, Kh, Dh).  ``window`` == 0 means global
    causal.  A query at position i sees keys j with j <= i and, when
    windowed, i - j < window.
    """
    b, s, h, dh = q.shape
    kh = k.shape[2]
    qg = _split_gqa(q, kh)

    if s <= q_chunk:
        pos = jnp.arange(s)
        mask = pos[None, :, None] >= pos[None, None, :]
        if window:
            mask &= (pos[None, :, None] - pos[None, None, :]) < window
        return _merge_gqa(_attend(qg, k, v, mask, softcap_val))

    if s % q_chunk:
        raise ValueError(f"seq {s} not divisible by q_chunk {q_chunk}")
    n_chunks = s // q_chunk

    if window and window + q_chunk < s:
        # Local: each chunk sees a static slice of window + chunk keys.
        span = window + q_chunk
        pad = window
        kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        qc = qg.reshape(b, n_chunks, q_chunk, kh, -1, dh)

        @jax.checkpoint  # flash-style: recompute chunk attention in backward
        def body(c, q_blk):
            start = c * q_chunk                      # in padded coords
            kb = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
            q_pos = start + pad + jnp.arange(q_chunk)    # padded coords
            k_pos = start + jnp.arange(span)
            delta = q_pos[:, None] - k_pos[None, :]
            mask = (delta >= 0) & (delta < window) & (k_pos[None, :] >= pad)
            out = _attend(q_blk, kb, vb, mask[None], softcap_val)
            return c + 1, out

        _, outs = jax.lax.scan(body, 0, qc.transpose(1, 0, 2, 3, 4, 5))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, kh, -1, dh)
        return _merge_gqa(out)

    # Global causal: chunked queries against all keys.
    qc = qg.reshape(b, n_chunks, q_chunk, kh, -1, dh)
    k_pos = jnp.arange(s)

    @jax.checkpoint  # flash-style: recompute chunk attention in backward
    def body(c, q_blk):
        q_pos = c * q_chunk + jnp.arange(q_chunk)
        mask = q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        out = _attend(q_blk, k, v, mask[None], softcap_val)
        return c + 1, out

    _, outs = jax.lax.scan(body, 0, qc.transpose(1, 0, 2, 3, 4, 5))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, kh, -1, dh)
    return _merge_gqa(out)


def chunk2d_attention(q, k, v, *, window: int = 0, softcap_val: float = 0.0,
                      q_chunk: int = 512, k_chunk: int = 2048,
                      policy: Policy = NO_POLICY) -> jax.Array:
    """Sequence-parallel flash attention (XLA level).

    q is reshaped to (B, NC, Lq, H, Dh) and the CHUNK axis is sharded over
    `model` (logical name "seq_chunks"), so the quadratic score work spreads
    over data x model; k/v are consumed whole (the policy leaves them
    batch-sharded only -> one all-gather each).  An online-softmax scan over
    k-blocks bounds the live score tile, exactly like the Pallas kernel in
    repro/kernels/flash_attention — this is its pjit/SPMD twin for meshes
    where heads cannot shard (llava 56H; H1 in EXPERIMENTS.md §Perf).
    """
    b, s, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    if s % q_chunk or s % k_chunk:
        return chunked_causal_attention(q, k, v, window=window,
                                        softcap_val=softcap_val,
                                        q_chunk=min(q_chunk, s))
    nc = s // q_chunk
    nk = s // k_chunk
    qc = q.reshape(b, nc, q_chunk, kh, g, dh)
    qc = policy.constrain(qc, ("batch", "seq_chunks", None, None, None, None))
    scale = dh ** -0.5

    def body(carry, kc):
        m_prev, l_prev, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, kc * k_chunk, k_chunk, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, kc * k_chunk, k_chunk, axis=1)
        logits = jnp.einsum("bnqkgd,bskd->bnqkgs", qc, kb,
                            preferred_element_type=jnp.float32) * scale
        logits = common.softcap(logits, softcap_val)
        q_pos = (jnp.arange(nc)[:, None] * q_chunk
                 + jnp.arange(q_chunk)[None, :])          # (NC, Lq)
        k_pos = kc * k_chunk + jnp.arange(k_chunk)        # (Lk,)
        delta = q_pos[..., None] - k_pos[None, None, :]
        mask = delta >= 0
        if window:
            mask &= delta < window
        logits = jnp.where(mask[None, :, :, None, None, :], logits, NEG_INF)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        # p joins v's storage dtype (standard flash practice) so XLA
        # all-gathers v in bf16, not f32 — accumulation stays f32
        acc = (acc * alpha[..., None]
               + jnp.einsum("bnqkgs,bskd->bnqkgd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32))
        return (m_new, l_new, acc), None

    shape5 = (b, nc, q_chunk, kh, g)
    init = (jnp.full(shape5, NEG_INF, jnp.float32),
            jnp.zeros(shape5, jnp.float32),
            jnp.zeros(shape5 + (dh,), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(nk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.astype(q.dtype).reshape(b, s, kh, g, dh)
    return _merge_gqa(out)


# ---------------------------------------------------------------------------
# Full layer application
# ---------------------------------------------------------------------------

def _project_qkv(p, h_in, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", h_in, p["wq"].astype(h_in.dtype))
    k = jnp.einsum("bsd,dhk->bshk", h_in, p["wk"].astype(h_in.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h_in, p["wv"].astype(h_in.dtype))
    if cfg.use_qk_norm:
        q = common.apply_rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = common.apply_rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def apply_attention(p: dict, h_in: jax.Array, cfg: ModelConfig, *,
                    window: int = 0, policy: Policy = NO_POLICY,
                    positions: Optional[jax.Array] = None,
                    q_chunk: int = 512, return_kv: bool = False):
    """Train/prefill path.  h_in: (B, S, D) -> (B, S, D).

    ``return_kv=True`` additionally returns the (RoPE'd) K/V tensors so the
    caller can build a decode cache (prefill -> decode handoff)."""
    b, s, _ = h_in.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    h_in = policy.constrain(h_in, ("batch", "seq", None))
    q, k, v = _project_qkv(p, h_in, cfg, positions)
    if getattr(policy, "seq2d", False):
        # 2D token sharding: q-chunks sharded over `model`; k/v consumed
        # whole (batch-sharded) — the SPMD twin of the flash kernel.
        # Constrain k/v seq-sharded FIRST so the projection dot computes
        # locally and only the small k/v get gathered — otherwise SPMD
        # replicates the (much larger) hidden-state input instead.
        q = policy.constrain(q, ("batch", "seq", None, None))
        k = policy.constrain(k, ("batch", "seq", None, None))
        v = policy.constrain(v, ("batch", "seq", None, None))
        k = policy.constrain(k, ("batch", None, None, None))
        v = policy.constrain(v, ("batch", None, None, None))
        out = chunk2d_attention(q, k, v, window=window,
                                softcap_val=cfg.attn_logit_softcap,
                                q_chunk=q_chunk, policy=policy)
    else:
        q = policy.constrain(q, ("batch", "seq", "heads", "head_dim"))
        k = policy.constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
        v = policy.constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
        out = chunked_causal_attention(q, k, v, window=window,
                                       softcap_val=cfg.attn_logit_softcap,
                                       q_chunk=q_chunk)
    out = policy.constrain(out, ("batch", "seq", "heads", None))
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))
    if return_kv:
        return out, k, v
    return out


def kv_to_cache(k: jax.Array, v: jax.Array, cfg: ModelConfig, *,
                window: int = 0, cache_len: Optional[int] = None) -> dict:
    """Arrange prefill K/V (B, S, Kh, Dh) into a decode cache.

    Windowed layers get a ring buffer laid out so that position p sits at
    slot p % size — exactly what ``apply_attention_decode`` expects when it
    continues from pos = S.  Global layers get a dense cache of
    ``cache_len`` (>= S) slots.
    """
    b, s, kh, dh = k.shape
    dt = cfg.jnp_compute_dtype()
    if window:
        size = min(window, cache_len or s)
        start = max(s - size, 0)
        slots = (start + jnp.arange(min(size, s))) % size
        ck = jnp.zeros((b, size, kh, dh), dt).at[:, slots].set(
            k[:, start:].astype(dt))
        cv = jnp.zeros((b, size, kh, dh), dt).at[:, slots].set(
            v[:, start:].astype(dt))
        return {"k": ck, "v": cv}
    size = cache_len or s
    ck = jnp.zeros((b, size, kh, dh), dt).at[:, :s].set(k.astype(dt))
    cv = jnp.zeros((b, size, kh, dh), dt).at[:, :s].set(v.astype(dt))
    return {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
                  window: int = 0) -> dict:
    """window > 0 -> ring buffer of that size; else dense cache of seq_len."""
    size = min(window, seq_len) if window else seq_len
    dt = cfg.jnp_compute_dtype()
    shape = (batch, size, cfg.n_kv_heads, cfg.resolved_head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def apply_attention_decode(p: dict, h_in: jax.Array, cache: dict,
                           pos: jax.Array, cfg: ModelConfig, *,
                           window: int = 0,
                           policy: Policy = NO_POLICY):
    """One-token decode.  h_in: (B, 1, D); pos: scalar int32 (current index).

    Returns (out (B, 1, D), new_cache).
    """
    b = h_in.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(p, h_in, cfg, positions)

    size = cache["k"].shape[1]
    slot = pos % size if window else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    new_cache = {"k": k, "v": v}

    k = policy.constrain(k, ("batch", "kv_seq", "kv_heads", "head_dim"))
    v = policy.constrain(v, ("batch", "kv_seq", "kv_heads", "head_dim"))

    idx = jnp.arange(size)
    if window:
        # slot j holds logical position: the largest p' <= pos with p' % size == j
        logical = pos - ((pos - idx) % size)
        valid = (logical >= 0) & (logical <= pos) & (pos - logical < window)
    else:
        valid = idx <= pos
    mask = jnp.broadcast_to(valid[None, None, :], (1, 1, size))

    qg = _split_gqa(q, cfg.n_kv_heads)
    out = _attend(qg, k, v, mask, cfg.attn_logit_softcap)
    out = _merge_gqa(out)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))
    return out, new_cache
