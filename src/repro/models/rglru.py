"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block layout (as in RecurrentGemma):

    h -> W_in -> causal depthwise conv1d(width 4) -> RG-LRU -> * gelu(W_gate h) -> W_out

RG-LRU recurrence (diagonal, per-channel):

    r_t = sigmoid(w_r * x_t + b_r)              recurrence gate
    i_t = sigmoid(w_i * x_t + b_i)              input gate
    log a_t = -c * softplus(lam) * r_t          c = 8
    y_t = a_t * y_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The sequence dimension is parallelized with ``jax.lax.associative_scan``
(first-order linear recurrence composition) for train/prefill; decode is the
single-step recurrence carrying ``(y, conv window)`` state.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import Policy, NO_POLICY

_C = 8.0


def init_rglru(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dr = cfg.resolved_d_rnn
    tw = cfg.lru_temporal_width
    dt = cfg.jnp_param_dtype()
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    # lam init so that a^c spans ~(0.9, 0.999) as in the Griffin paper
    u = jax.random.uniform(k5, (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))   # softplus^-1(-log(u)/c)
    return {
        "w_in": common.dense_init(k1, (d, dr), dt),
        "w_gate": common.dense_init(k2, (d, dr), dt),
        "w_out": common.dense_init(k3, (dr, d), dt, fan_in=dr),
        "conv": (common.dense_init(k4, (tw, dr), dt, fan_in=tw)),
        "w_r": jnp.zeros((dr,), jnp.float32),
        "b_r": jnp.zeros((dr,), jnp.float32),
        "w_i": jnp.zeros((dr,), jnp.float32),
        "b_i": jnp.zeros((dr,), jnp.float32),
        "lam": lam.astype(jnp.float32),
    }


def _gates(p: dict, x: jax.Array):
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(p["w_r"] * xf + p["b_r"])
    i = jax.nn.sigmoid(p["w_i"] * xf + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return a, b


def lru_scan(p: dict, x: jax.Array,
             y0: Optional[jax.Array] = None) -> jax.Array:
    """Linear recurrence over (B, S, Dr) via associative scan."""
    a, b = _gates(p, x)
    if y0 is not None:
        # fold the initial state into the first step: y_1 = a_1 y_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * y0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, y = jax.lax.associative_scan(combine, (a, b), axis=1)
    return y.astype(x.dtype)


def _causal_conv(p: dict, x: jax.Array,
                 window: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv, width tw.  window: (B, tw-1, Dr) history."""
    w = p["conv"].astype(x.dtype)                  # (tw, Dr)
    tw = w.shape[0]
    if window is None:
        pad = jnp.zeros((x.shape[0], tw - 1, x.shape[-1]), x.dtype)
    else:
        pad = window.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)         # (B, S + tw - 1, Dr)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(tw))
    return out


def apply_rglru(p: dict, h_in: jax.Array, cfg: ModelConfig,
                policy: Policy = NO_POLICY, return_state: bool = False):
    """Train/prefill path. h_in: (B, S, D) -> (B, S, D).

    ``return_state=True`` also returns the decode cache (final recurrent
    state + conv history) for prefill -> decode handoff."""
    x = jnp.einsum("bsd,dr->bsr", h_in, p["w_in"].astype(h_in.dtype))
    x = policy.constrain(x, ("batch", "seq", "rnn"))
    g = jnp.einsum("bsd,dr->bsr", h_in, p["w_gate"].astype(h_in.dtype))
    xc = _causal_conv(p, x)
    y = lru_scan(p, xc)
    out = y * jax.nn.gelu(g)
    out = policy.constrain(out, ("batch", "seq", "rnn"))
    out = jnp.einsum("bsr,rd->bsd", out, p["w_out"].astype(out.dtype))
    if return_state:
        tw = cfg.lru_temporal_width
        state = {"y": y[:, -1].astype(jnp.float32),
                 "conv": x[:, -(tw - 1):].astype(cfg.jnp_compute_dtype())}
        return out, state
    return out


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_rglru_cache(cfg: ModelConfig, batch: int) -> dict:
    dr = cfg.resolved_d_rnn
    tw = cfg.lru_temporal_width
    dt = cfg.jnp_compute_dtype()
    return {"y": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, tw - 1, dr), dt)}


def apply_rglru_decode(p: dict, h_in: jax.Array, cache: dict,
                       cfg: ModelConfig,
                       policy: Policy = NO_POLICY) -> Tuple[jax.Array, dict]:
    """One step. h_in: (B, 1, D) -> ((B, 1, D), new cache)."""
    x = jnp.einsum("bsd,dr->bsr", h_in, p["w_in"].astype(h_in.dtype))
    g = jnp.einsum("bsd,dr->bsr", h_in, p["w_gate"].astype(h_in.dtype))
    new_window = jnp.concatenate([cache["conv"], x.astype(cache["conv"].dtype)],
                                 axis=1)[:, 1:]
    xc = _causal_conv(p, x, window=cache["conv"])  # (B, 1, Dr)
    a, b = _gates(p, xc[:, 0])
    y = a * cache["y"] + b                          # (B, Dr) f32
    out = y[:, None].astype(h_in.dtype) * jax.nn.gelu(g)
    out = jnp.einsum("bsr,rd->bsd", out, p["w_out"].astype(out.dtype))
    return out, {"y": y, "conv": new_window}
