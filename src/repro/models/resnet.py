"""PreActResNet18 with GroupNorm — the paper's experimental model (§3).

* Complex architecture: PreActResNet18 (He et al. 2016), 4 stages x 2
  pre-activation basic blocks, channels (64, 128, 256, 512), ~11.1M params.
  BatchNorm is replaced by GroupNorm everywhere (paper footnote 1).
* Simple architecture: the first 2 stages, followed by a *mix pooling* layer
  (Lee et al. 2016 — learned convex combination of avg and max pooling, as
  used by Kaya et al. 2019) and a linear classifier; ~0.7M params.

Per FedHeN Assumption 2.1 the complex parameter vector *contains* the simple
one: the mix-pool/exit head lives inside the complex params (it is exercised
by the side objective) and the index set M selects
``stem + stage1 + stage2 + exit head``.

Layout: channels-last (B, H, W, C); convs via ``lax.conv_general_dilated``.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common

Params = Dict[str, Any]

STAGE_CHANNELS = (64, 128, 256, 512)
BLOCKS_PER_STAGE = 2
SIMPLE_STAGES = 2          # paper: first 2 residual stages


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    std = (2.0 / fan_in) ** 0.5
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * std


def conv2d(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


# ---------------------------------------------------------------------------
# Pre-activation basic block
# ---------------------------------------------------------------------------

def init_block(key, cin, cout, stride) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "gn1": common.init_groupnorm(cin, jnp.float32),
        "conv1": _conv_init(k1, 3, 3, cin, cout),
        "gn2": common.init_groupnorm(cout, jnp.float32),
        "conv2": _conv_init(k2, 3, 3, cout, cout),
    }
    if stride != 1 or cin != cout:
        p["shortcut"] = _conv_init(k3, 1, 1, cin, cout)
    return p


def apply_block(p: Params, x, stride):
    h = jax.nn.relu(common.apply_groupnorm(p["gn1"], x))
    shortcut = conv2d(h, p["shortcut"], stride) if "shortcut" in p else x
    h = conv2d(h, p["conv1"], stride)
    h = jax.nn.relu(common.apply_groupnorm(p["gn2"], h))
    h = conv2d(h, p["conv2"], 1)
    return h + shortcut


# ---------------------------------------------------------------------------
# Mix pooling head (Lee et al. 2016): alpha * avg + (1 - alpha) * max
# ---------------------------------------------------------------------------

def init_mixpool_head(key, channels, n_classes) -> Params:
    return {
        "alpha": jnp.zeros((), jnp.float32),      # sigmoid(0) = 0.5 mix
        "w": common.dense_init(key, (channels, n_classes), jnp.float32),
        "b": jnp.zeros((n_classes,), jnp.float32),
    }


def apply_mixpool_head(p: Params, x) -> jax.Array:
    a = jax.nn.sigmoid(p["alpha"])
    avg = jnp.mean(x, axis=(1, 2))
    mx = jnp.max(x, axis=(1, 2))
    pooled = a * avg + (1.0 - a) * mx
    return pooled @ p["w"] + p["b"]


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_params(key, n_classes: int = 10) -> Params:
    keys = jax.random.split(key, 16)
    params: Params = {"stem": _conv_init(keys[0], 3, 3, 3, 64)}
    ki = 1
    cin = 64
    for s, cout in enumerate(STAGE_CHANNELS):
        blocks = []
        for b in range(BLOCKS_PER_STAGE):
            stride = 2 if (s > 0 and b == 0) else 1
            blocks.append(init_block(keys[ki], cin, cout, stride))
            ki += 1
            cin = cout
        params[f"stage{s + 1}"] = blocks
    params["final_gn"] = common.init_groupnorm(512, jnp.float32)
    params["head"] = {
        "w": common.dense_init(keys[ki], (512, n_classes), jnp.float32),
        "b": jnp.zeros((n_classes,), jnp.float32),
    }
    # FedHeN simple/exit head: mix pooling + linear on stage-2 output
    params["exit_head"] = init_mixpool_head(
        keys[ki + 1], STAGE_CHANNELS[SIMPLE_STAGES - 1], n_classes)
    return params


def _run_stages(params: Params, x, n_stages: int):
    h = conv2d(x, params["stem"], 1)
    for s in range(n_stages):
        for b, blk in enumerate(params[f"stage{s + 1}"]):
            stride = 2 if (s > 0 and b == 0) else 1
            h = apply_block(blk, h, stride)
    return h


def forward(params: Params, images) -> Tuple[jax.Array, jax.Array]:
    """images: (B, 32, 32, 3).  Returns (exit_logits, final_logits).

    One pass: the simple sub-network is a prefix, so the side objective's
    logits come from the stage-2 activation for free.
    """
    h = conv2d(images, params["stem"], 1)
    for s in range(len(STAGE_CHANNELS)):
        for b, blk in enumerate(params[f"stage{s + 1}"]):
            stride = 2 if (s > 0 and b == 0) else 1
            h = apply_block(blk, h, stride)
        if s + 1 == SIMPLE_STAGES:
            exit_logits = apply_mixpool_head(params["exit_head"], h)
    h = jax.nn.relu(common.apply_groupnorm(params["final_gn"], h))
    final_logits = jnp.mean(h, axis=(1, 2)) @ params["head"]["w"] \
        + params["head"]["b"]
    return exit_logits, final_logits


def forward_simple(params: Params, images) -> jax.Array:
    """Simple-architecture forward (works on extracted simple params too)."""
    h = _run_stages(params, images, SIMPLE_STAGES)
    return apply_mixpool_head(params["exit_head"], h)


def subnet_mask(params: Params) -> Params:
    """FedHeN index set M: stem + stage1 + stage2 + exit head."""
    def mark(path_has_simple):
        return path_has_simple

    mask = jax.tree.map(lambda _: False, params)
    for key in ("stem", "stage1", "stage2", "exit_head"):
        mask[key] = jax.tree.map(lambda _: True, params[key])
    return mask


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
