"""Decoder-stack assembly for the architecture zoo.

The layer stack is organised as ``n_periods`` repetitions of the config's
``pattern`` (compiled as ``lax.scan`` over stacked parameters, one stack per
pattern position) plus ``n_remainder`` unrolled tail layers.  The FedHeN
simple sub-network is the depth prefix ``blocks[:exit_layer]`` — the scan is
split at ``exit_period`` so the complex forward yields the exit activation
for the side objective in the same pass (one forward, two heads).

Parameter tree:

    {"embed":   {"table": (V, D)} | {"tables": (n_codebooks, V, D)},
     "frontend_proj": {"w": (d_in, D)}?,            # VLM / audio stub projector
     "periods": (p0, p1, ... p_{period-1})          # leaves (n_periods, ...)
     "rem":     (layer trees ...),                  # unrolled tail
     "exit_norm":  rmsnorm,                         # FedHeN early-exit head
     "final_norm": rmsnorm}

Caches mirror the same periods/rem structure.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, MLP_DENSE, MLP_MOE,
                                MLSTM, RGLRU, SLSTM, LayerSpec, ModelConfig)
from repro.models import attention, common, mlp, rglru, xlstm
from repro.models.common import NO_POLICY, Policy

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def init_block(key, spec: LayerSpec, cfg: ModelConfig) -> Params:
    km, kf = jax.random.split(key)
    dt = cfg.jnp_param_dtype()
    p: Params = {"pre_norm": common.init_rmsnorm(cfg.d_model, dt)}
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        p["mixer"] = attention.init_attention(km, cfg)
    elif spec.mixer == RGLRU:
        p["mixer"] = rglru.init_rglru(km, cfg)
    elif spec.mixer == MLSTM:
        p["mixer"] = xlstm.init_mlstm(km, cfg)
    elif spec.mixer == SLSTM:
        p["mixer"] = xlstm.init_slstm(km, cfg)
    if spec.mlp == MLP_DENSE:
        p["mlp_norm"] = common.init_rmsnorm(cfg.d_model, dt)
        p["mlp"] = mlp.init_mlp(kf, cfg)
    elif spec.mlp == MLP_MOE:
        p["mlp_norm"] = common.init_rmsnorm(cfg.d_model, dt)
        p["mlp"] = mlp.init_moe(kf, cfg)
    return p


def _zero_aux() -> Dict[str, jax.Array]:
    return {"load_balance": jnp.zeros((), jnp.float32),
            "router_z": jnp.zeros((), jnp.float32)}


def apply_block(p: Params, spec: LayerSpec, h: jax.Array, cfg: ModelConfig,
                policy: Policy, *, window_override: Optional[int] = None
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence (train/prefill) block application."""
    aux = _zero_aux()
    x = common.apply_rmsnorm(p["pre_norm"], h, cfg.norm_eps)
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        window = cfg.window if spec.mixer == ATTN_LOCAL else 0
        if window_override is not None:
            window = window_override
        m = attention.apply_attention(p["mixer"], x, cfg, window=window,
                                      policy=policy)
    elif spec.mixer == RGLRU:
        m = rglru.apply_rglru(p["mixer"], x, cfg, policy)
    elif spec.mixer == MLSTM:
        m = xlstm.apply_mlstm(p["mixer"], x, cfg, policy)
    elif spec.mixer == SLSTM:
        m = xlstm.apply_slstm(p["mixer"], x, cfg, policy)
    h = h + m
    if "mlp" in p:
        x = common.apply_rmsnorm(p["mlp_norm"], h, cfg.norm_eps)
        if spec.mlp == MLP_MOE:
            y, aux = mlp.apply_moe(p["mlp"], x, cfg, policy)
        else:
            y = mlp.apply_mlp(p["mlp"], x, policy)
        h = h + y
    h = policy.constrain(h, ("batch", "seq", None))
    return h, aux


# -- decode variant ---------------------------------------------------------

def init_block_cache(spec: LayerSpec, cfg: ModelConfig, batch: int,
                     seq_len: int, *, window_override: Optional[int] = None
                     ) -> Params:
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        window = cfg.window if spec.mixer == ATTN_LOCAL else 0
        if window_override is not None:
            window = window_override
        return attention.init_kv_cache(cfg, batch, seq_len, window=window)
    if spec.mixer == RGLRU:
        return rglru.init_rglru_cache(cfg, batch)
    if spec.mixer == MLSTM:
        return xlstm.init_mlstm_cache(cfg, batch)
    if spec.mixer == SLSTM:
        return xlstm.init_slstm_cache(cfg, batch)
    raise ValueError(spec.mixer)


def apply_block_decode(p: Params, spec: LayerSpec, h: jax.Array, cache: Params,
                       pos: jax.Array, cfg: ModelConfig, policy: Policy, *,
                       window_override: Optional[int] = None):
    aux = _zero_aux()
    x = common.apply_rmsnorm(p["pre_norm"], h, cfg.norm_eps)
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        window = cfg.window if spec.mixer == ATTN_LOCAL else 0
        if window_override is not None:
            window = window_override
        m, cache = attention.apply_attention_decode(
            p["mixer"], x, cache, pos, cfg, window=window, policy=policy)
    elif spec.mixer == RGLRU:
        m, cache = rglru.apply_rglru_decode(p["mixer"], x, cache, cfg, policy)
    elif spec.mixer == MLSTM:
        m, cache = xlstm.apply_mlstm_decode(p["mixer"], x, cache, cfg, policy)
    elif spec.mixer == SLSTM:
        m, cache = xlstm.apply_slstm_decode(p["mixer"], x, cache, cfg, policy)
    h = h + m
    if "mlp" in p:
        x = common.apply_rmsnorm(p["mlp_norm"], h, cfg.norm_eps)
        if spec.mlp == MLP_MOE:
            # decode: route across the batch (one group) so active-expert
            # FLOPs scale with top_k, not n_experts
            b, s, d = x.shape
            y, aux = mlp.apply_moe(p["mlp"], x.reshape(1, b * s, d), cfg, policy)
            y = y.reshape(b, s, d)
        else:
            y = mlp.apply_mlp(p["mlp"], x, policy)
        h = h + y
    return h, cache, aux


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    dt = cfg.jnp_param_dtype()
    params: Params = {}

    if cfg.n_codebooks > 1:
        tables = jax.vmap(
            lambda k: common.embed_init(k, (cfg.vocab_size, cfg.d_model), dt)
        )(jax.random.split(keys[0], cfg.n_codebooks))
        params["embed"] = {"tables": tables}
    else:
        params["embed"] = common.init_embedding(keys[0], cfg.vocab_size,
                                                cfg.d_model, dt)
    if cfg.frontend is not None:
        params["frontend_proj"] = {
            "w": common.dense_init(keys[1], (cfg.frontend.d_in, cfg.d_model),
                                   dt)}

    # periodic stacks: one stacked tree per pattern position
    period_params = []
    for pos, spec in enumerate(cfg.pattern):
        pkeys = jax.random.split(jax.random.fold_in(keys[2], pos),
                                 cfg.n_periods)
        stacked = jax.vmap(lambda k, s=spec: init_block(k, s, cfg))(pkeys)
        period_params.append(stacked)
    params["periods"] = tuple(period_params)

    rem = []
    for i in range(cfg.n_remainder):
        spec = cfg.pattern[i % cfg.period]
        rem.append(init_block(jax.random.fold_in(keys[3], i), spec, cfg))
    params["rem"] = tuple(rem)

    params["exit_norm"] = common.init_rmsnorm(cfg.d_model, dt)
    params["final_norm"] = common.init_rmsnorm(cfg.d_model, dt)
    if not cfg.tie_embeddings:
        params["unembed"] = {
            "w": common.dense_init(keys[4], (cfg.d_model, cfg.vocab_size), dt)}
    return params


# -- embedding --------------------------------------------------------------

def embed_inputs(params: Params, cfg: ModelConfig, tokens: jax.Array,
                 extra_embeds: Optional[jax.Array] = None,
                 policy: Policy = NO_POLICY) -> jax.Array:
    """tokens: (B, S) or (B, S, n_codebooks).  extra_embeds: (B, N, d_in)
    precomputed frontend embeddings (VLM patches / audio conditioning),
    prepended to the sequence after projection."""
    cd = cfg.jnp_compute_dtype()
    if cfg.n_codebooks > 1:
        tabs = params["embed"]["tables"]                  # (NC, V, D)
        parts = [jnp.take(tabs[c], tokens[..., c], axis=0)
                 for c in range(cfg.n_codebooks)]
        h = sum(parts) * jnp.asarray(cfg.d_model ** 0.5, tabs.dtype)
    else:
        h = common.apply_embedding(params["embed"], tokens)
    h = h.astype(cd)
    if extra_embeds is not None:
        proj = jnp.einsum("bnd,dk->bnk",
                          extra_embeds.astype(cd),
                          params["frontend_proj"]["w"].astype(cd))
        h = jnp.concatenate([proj, h], axis=1)
    return policy.constrain(h, ("batch", "seq", None))


def logits_from_hidden(params: Params, cfg: ModelConfig, h: jax.Array,
                       head: str, policy: Policy = NO_POLICY) -> jax.Array:
    """head: 'final' or 'exit' (FedHeN early-exit head, shared unembedding)."""
    norm = params["final_norm"] if head == "final" else params["exit_norm"]
    h = common.apply_rmsnorm(norm, h, cfg.norm_eps)
    if cfg.n_codebooks > 1:
        tabs = params["embed"]["tables"].astype(h.dtype)   # (NC, V, D)
        logits = jnp.einsum("bsd,cvd->bscv", h, tabs)
    elif cfg.tie_embeddings:
        logits = common.apply_unembedding(
            {"table": params["embed"]["table"].astype(h.dtype)}, h)
    else:
        logits = jnp.einsum("bsd,dv->bsv", h,
                            params["unembed"]["w"].astype(h.dtype))
    logits = common.softcap(logits, cfg.final_logit_softcap)
    return policy.constrain(logits, ("batch", "seq", "vocab"))


# -- forward (train / prefill) -----------------------------------------------

def _merge_aux(a, b):
    return {k: a[k] + b[k] for k in a}


def _tree_slice(tree, start, stop):
    return jax.tree.map(lambda x: x[start:stop], tree)


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            extra_embeds: Optional[jax.Array] = None,
            policy: Policy = NO_POLICY, remat: bool = False,
            window_override: Optional[int] = None
            ) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """Returns (exit_hidden, final_hidden, aux_losses).

    ``exit_hidden`` is the activation after ``resolved_exit_layer`` blocks —
    the FedHeN simple sub-network's output stream.  One scan over all
    periods; the exit activation is captured in the carry with a select at
    the exit boundary (gradients from the exit head route through it), which
    keeps the layer stack a single while loop in HLO.
    """
    h = embed_inputs(params, cfg, tokens, extra_embeds, policy)
    kp = cfg.exit_period

    def period_body(carry, xs):
        h, exit_h, aux, idx = carry
        period_slice = xs
        for pos, spec in enumerate(cfg.pattern):
            h, a = apply_block(period_slice[pos], spec, h, cfg, policy,
                               window_override=window_override)
            aux = _merge_aux(aux, a)
        exit_h = jnp.where(idx == kp - 1, h, exit_h)
        return (h, exit_h, aux, idx + 1), None

    body = jax.checkpoint(period_body) if remat else period_body
    (h, exit_h, aux, _), _ = jax.lax.scan(
        body, (h, h, _zero_aux(), jnp.zeros((), jnp.int32)),
        params["periods"])
    for i, p_rem in enumerate(params["rem"]):
        spec = cfg.pattern[i % cfg.period]
        h, a = apply_block(p_rem, spec, h, cfg, policy,
                           window_override=window_override)
        aux = _merge_aux(aux, a)
    return exit_h, h, aux


def forward_simple(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
                   extra_embeds: Optional[jax.Array] = None,
                   policy: Policy = NO_POLICY, remat: bool = False
                   ) -> jax.Array:
    """Forward of the *simple* architecture only (prefix blocks + exit head).

    ``params`` may be either full complex params or an extracted simple tree
    (see core/masking.py) — only the prefix stacks are touched.
    """
    h = embed_inputs(params, cfg, tokens, extra_embeds, policy)

    def period_body(carry, period_slice):
        h, aux = carry
        for pos, spec in enumerate(cfg.pattern):
            h, a = apply_block(period_slice[pos], spec, h, cfg, policy)
            aux = _merge_aux(aux, a)
        return (h, aux), None

    body = jax.checkpoint(period_body) if remat else period_body
    kp = cfg.exit_period
    pre = tuple(_tree_slice(t, 0, kp) for t in params["periods"])
    (h, _), _ = jax.lax.scan(body, (h, _zero_aux()), pre)
    return h


# -- prefill (build cache + logits in one parallel pass) ---------------------

def apply_block_prefill(p: Params, spec: LayerSpec, h: jax.Array,
                        cfg: ModelConfig, policy: Policy, *,
                        window_override: Optional[int] = None,
                        cache_len: Optional[int] = None):
    aux = _zero_aux()
    x = common.apply_rmsnorm(p["pre_norm"], h, cfg.norm_eps)
    x = policy.constrain(x, ("batch", "seq", None))
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        window = cfg.window if spec.mixer == ATTN_LOCAL else 0
        if window_override is not None:
            window = window_override
        m, k, v = attention.apply_attention(p["mixer"], x, cfg, window=window,
                                            policy=policy, return_kv=True)
        cache = attention.kv_to_cache(k, v, cfg, window=window,
                                      cache_len=cache_len)
    elif spec.mixer == RGLRU:
        m, cache = rglru.apply_rglru(p["mixer"], x, cfg, policy,
                                     return_state=True)
    elif spec.mixer == MLSTM:
        m, cache = xlstm.apply_mlstm(p["mixer"], x, cfg, policy,
                                     return_state=True)
    elif spec.mixer == SLSTM:
        m, cache = xlstm.apply_slstm(p["mixer"], x, cfg, policy,
                                     return_state=True)
    h = h + m
    if "mlp" in p:
        x = common.apply_rmsnorm(p["mlp_norm"], h, cfg.norm_eps)
        if spec.mlp == MLP_MOE:
            y, aux = mlp.apply_moe(p["mlp"], x, cfg, policy)
        else:
            y = mlp.apply_mlp(p["mlp"], x, policy)
        h = h + y
    h = policy.constrain(h, ("batch", "seq", None))
    return h, cache, aux


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            extra_embeds: Optional[jax.Array] = None,
            policy: Policy = NO_POLICY,
            window_override: Optional[int] = None,
            cache_len: Optional[int] = None):
    """Parallel prefill: returns (logits, cache) — the prefill -> decode
    handoff.  ``cache_len`` sizes the dense caches (>= prompt length) to
    leave room for decoded tokens."""
    h = embed_inputs(params, cfg, tokens, extra_embeds, policy)

    def period_body(h, period_slice):
        caches = []
        for pos, spec in enumerate(cfg.pattern):
            h, c, _ = apply_block_prefill(
                period_slice[pos], spec, h, cfg, policy,
                window_override=window_override, cache_len=cache_len)
            caches.append(c)
        return h, tuple(caches)

    h, period_caches = jax.lax.scan(period_body, h, params["periods"])
    rem_caches = []
    for i, p_rem in enumerate(params["rem"]):
        spec = cfg.pattern[i % cfg.period]
        h, c, _ = apply_block_prefill(p_rem, spec, h, cfg, policy,
                                      window_override=window_override,
                                      cache_len=cache_len)
        rem_caches.append(c)
    cache = {"periods": period_caches, "rem": tuple(rem_caches)}
    logits = logits_from_hidden(params, cfg, h, "final", policy)
    return logits, cache


# -- decode -------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
               window_override: Optional[int] = None) -> Params:
    cache: Params = {"periods": [], "rem": []}
    for pos, spec in enumerate(cfg.pattern):
        one = init_block_cache(spec, cfg, batch, seq_len,
                               window_override=window_override)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape)
            if cfg.n_periods else x[None][:0], one)
        cache["periods"].append(stacked)
    cache["periods"] = tuple(cache["periods"])
    for i in range(cfg.n_remainder):
        spec = cfg.pattern[i % cfg.period]
        cache["rem"].append(init_block_cache(spec, cfg, batch, seq_len,
                                             window_override=window_override))
    cache["rem"] = tuple(cache["rem"])
    return cache


def decode_step(params: Params, cache: Params, cfg: ModelConfig,
                tokens: jax.Array, pos: jax.Array, *,
                policy: Policy = NO_POLICY,
                window_override: Optional[int] = None,
                with_exit_head: bool = False):
    """One decode step.  tokens: (B, 1) or (B, 1, n_codebooks); pos: scalar.

    Returns (logits, new_cache[, exit_logits]).
    """
    h = embed_inputs(params, cfg, tokens, None, policy)
    kp = cfg.exit_period

    def period_body(carry, period_slice):
        h, pcaches, exit_h, idx = carry
        new_caches = list(pcaches)
        for pos_i, spec in enumerate(cfg.pattern):
            c_i = jax.tree.map(
                lambda x: jax.lax.dynamic_index_in_dim(x, idx, 0,
                                                       keepdims=False),
                pcaches[pos_i])
            h, c, _ = apply_block_decode(period_slice[pos_i], spec, h,
                                         c_i, pos, cfg, policy,
                                         window_override=window_override)
            # write back in place (while-loop carry -> no cache copy)
            new_caches[pos_i] = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), idx, 0),
                pcaches[pos_i], c)
            pcaches = tuple(new_caches)
        exit_h = jnp.where(idx == kp - 1, h, exit_h)
        return (h, pcaches, exit_h, idx + 1), None

    (h, new_periods, exit_h, _), _ = jax.lax.scan(
        period_body,
        (h, cache["periods"], h, jnp.zeros((), jnp.int32)),
        params["periods"])

    new_rem = []
    for i, p_rem in enumerate(params["rem"]):
        spec = cfg.pattern[i % cfg.period]
        h, c, _ = apply_block_decode(p_rem, spec, h, cache["rem"][i], pos,
                                     cfg, policy,
                                     window_override=window_override)
        new_rem.append(c)

    new_cache = {"periods": new_periods, "rem": tuple(new_rem)}

    logits = logits_from_hidden(params, cfg, h, "final", policy)
    if with_exit_head:
        exit_logits = logits_from_hidden(params, cfg, exit_h, "exit", policy)
        return logits, new_cache, exit_logits
    return logits, new_cache
