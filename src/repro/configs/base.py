"""Configuration dataclasses for the FedHeN framework.

Every model in the zoo is described by a :class:`ModelConfig`.  The layer
stack is expressed as a repeating *pattern period* (e.g. gemma-2's
``[local_attn, global_attn]`` alternation or recurrentgemma's
``[rglru, rglru, local_attn]``), which lets the runtime compile the stack as
``lax.scan`` over full periods with the remainder layers unrolled — faithful
interleaving with compact HLO.

FedHeN (the paper's technique) is configured via ``exit_layer``: the simple
architecture is the depth-prefix ``blocks[:exit_layer]`` plus an early-exit
head (own final norm, shared unembedding).  ``exit_layer`` must sit on a
period boundary so the prefix is expressible as a scan over whole periods.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple, Union

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer kinds
# ---------------------------------------------------------------------------

ATTN_GLOBAL = "attn"          # full causal attention
ATTN_LOCAL = "local_attn"     # sliding-window causal attention
RGLRU = "rglru"               # Griffin/RecurrentGemma real-gated LRU block
MLSTM = "mlstm"               # xLSTM matrix-memory block (chunked parallel)
SLSTM = "slstm"               # xLSTM scalar-memory block (sequential scan)

MIXER_KINDS = (ATTN_GLOBAL, ATTN_LOCAL, RGLRU, MLSTM, SLSTM)

MLP_DENSE = "dense"
MLP_MOE = "moe"
MLP_NONE = "none"             # block has no separate MLP (xLSTM style)


@dataclass(frozen=True)
class LayerSpec:
    """One position in the repeating layer pattern."""

    mixer: str = ATTN_GLOBAL
    mlp: str = MLP_DENSE

    def __post_init__(self):
        if self.mixer not in MIXER_KINDS:
            raise ValueError(f"unknown mixer kind {self.mixer!r}")
        if self.mlp not in (MLP_DENSE, MLP_MOE, MLP_NONE):
            raise ValueError(f"unknown mlp kind {self.mlp!r}")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int            # routed experts
    top_k: int
    n_shared: int = 0         # always-on shared experts
    d_expert: int = 0         # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2
    # pad the expert axis to this size (0 = off): dead experts never get
    # routed tokens, but make E divisible by the model axis so the combine
    # stays local + one small all-reduce (EXPERIMENTS.md §Perf H4)
    pad_to: int = 0


@dataclass(frozen=True)
class StubFrontend:
    """Modality frontend stub (the sanctioned carve-out).

    The dry-run's ``input_specs`` provides precomputed embeddings of shape
    ``(batch, n_tokens, d_in)``; the backbone owns only the projector.
    """

    kind: str                 # "vision" | "audio_conditioning"
    n_tokens: int             # tokens the frontend contributes to the sequence
    d_in: int                 # embedding dim produced by the (stubbed) encoder


@dataclass(frozen=True)
class ModelConfig:
    # -- identity ----------------------------------------------------------
    name: str = "model"
    arch_type: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""          # citation for the config numbers

    # -- dimensions --------------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0         # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # -- layer pattern -----------------------------------------------------
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    window: int = 4096        # sliding window for local attention layers
    rope_theta: float = 10000.0
    attn_logit_softcap: float = 0.0   # gemma-2 style; 0 disables
    final_logit_softcap: float = 0.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    use_qk_norm: bool = False
    d_rnn: int = 0            # RG-LRU width (0 -> d_model)
    lru_temporal_width: int = 4

    # -- MoE / modality ----------------------------------------------------
    moe: Optional[MoEConfig] = None
    mlp_glu: bool = True      # gated (3-matrix) vs plain (2-matrix) MLP
    n_codebooks: int = 1      # musicgen: parallel EnCodec codebooks
    frontend: Optional[StubFrontend] = None

    # -- xLSTM -------------------------------------------------------------
    mlstm_proj_factor: float = 2.0
    slstm_ff_factor: float = 4.0 / 3.0
    mlstm_chunk: int = 64

    # -- FedHeN ------------------------------------------------------------
    exit_layer: int = 0       # K: simple subnet = blocks[:K]; 0 -> n_layers//2
                              # (rounded down to a period boundary)

    # -- numerics ----------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # -- sharding hints (resolved by launch/sharding.py) --------------------
    attn_shard: str = "auto"    # auto | heads | uneven_heads | replicate
    shard_experts_2d: bool = False  # also shard expert d_ff over data (ZeRO-ish)

    # -- long-context variant ------------------------------------------------
    longctx_window: int = 8192  # window used when forcing the sliding-window
                                # variant for long_500k on full-attention archs

    # ------------------------------------------------------------------

    def __post_init__(self):
        if self.n_heads % self.n_kv_heads != 0:
            raise ValueError("n_heads must be divisible by n_kv_heads")

    # Derived quantities -------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def resolved_d_rnn(self) -> int:
        return self.d_rnn if self.d_rnn else self.d_model

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def n_remainder(self) -> int:
        return self.n_layers % self.period

    @property
    def resolved_exit_layer(self) -> int:
        """FedHeN K, rounded down to a period boundary (>= one period)."""
        k = self.exit_layer if self.exit_layer else self.n_layers // 2
        k = (k // self.period) * self.period
        return max(k, self.period)

    @property
    def exit_period(self) -> int:
        return self.resolved_exit_layer // self.period

    def layer_spec(self, idx: int) -> LayerSpec:
        return self.pattern[idx % self.period]

    def jnp_param_dtype(self):
        return jnp.dtype(self.param_dtype)

    def jnp_compute_dtype(self):
        return jnp.dtype(self.compute_dtype)

    # Parameter counting (used by comm accounting + roofline) -------------

    def param_count(self) -> int:
        """Analytical parameter count of the complex model."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d * self.n_codebooks          # embeddings
        if not self.tie_embeddings:
            total += v * d * self.n_codebooks
        if self.frontend is not None:
            total += self.frontend.d_in * d       # projector
        for i in range(self.n_layers):
            total += self._layer_params(self.layer_spec(i))
        total += d                                 # final norm
        total += d                                 # exit norm (FedHeN head)
        return total

    def _layer_params(self, spec: LayerSpec) -> int:
        d = self.d_model
        hd = self.resolved_head_dim
        n = 0
        if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
            n += d * self.n_heads * hd             # Wq
            n += 2 * d * self.n_kv_heads * hd      # Wk, Wv
            n += self.n_heads * hd * d             # Wo
        elif spec.mixer == RGLRU:
            dr = self.resolved_d_rnn
            n += 2 * d * dr + dr * d               # in/gate/out proj
            n += dr * self.lru_temporal_width      # temporal conv
            n += 3 * dr                            # a, input-gate, rec-gate diag
        elif spec.mixer == MLSTM:
            di = int(self.d_model * self.mlstm_proj_factor)
            n += 2 * d * di                        # up + gate proj
            n += 3 * di * (di // self.n_heads)     # block-diag q, k, v
            n += di * 2 * self.n_heads             # i, f gate projections
            n += di * d                            # down proj
        elif spec.mixer == SLSTM:
            nh, dh = self.n_heads, d // self.n_heads
            n += 4 * d * d                         # i, f, z, o input projections
            n += 4 * nh * dh * dh                  # recurrent (block-diag)
            dff = int(d * self.slstm_ff_factor)
            n += 2 * d * dff                       # post FFN
        n += 2 * d                                 # pre norms (mixer + mlp)
        mats = 3 if self.mlp_glu else 2            # (gate,) up, down
        if spec.mlp == MLP_DENSE:
            n += mats * d * self.d_ff
        elif spec.mlp == MLP_MOE:
            m = self.moe
            de = m.d_expert or self.d_ff
            n += d * m.n_experts                   # router
            n += mats * d * de * (m.n_experts + m.n_shared)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        de = m.d_expert or self.d_ff
        total = self.param_count()
        n_moe_layers = sum(
            1 for i in range(self.n_layers)
            if self.layer_spec(i).mlp == MLP_MOE
        )
        mats = 3 if self.mlp_glu else 2
        inactive = n_moe_layers * mats * self.d_model * de * (m.n_experts -
                                                              m.top_k)
        return total - inactive

    def simple_param_count(self) -> int:
        """Analytical parameter count of the FedHeN simple subnet."""
        d, v = self.d_model, self.vocab_size
        total = v * d * self.n_codebooks
        if self.frontend is not None:
            total += self.frontend.d_in * d
        for i in range(self.resolved_exit_layer):
            total += self._layer_params(self.layer_spec(i))
        total += d                                 # exit norm
        return total

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Federated experiment config (paper §3 + Appendix A)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FedConfig:
    """Hyper-parameters of the FedHeN experimental protocol."""

    n_devices: int = 100           # total federated clients
    n_simple: int = 50             # first 50 simple, rest complex (paper)
    participation: float = 0.10    # 10% active per round
    # Cohort sampling mode (core/sampling.py).  False (default): stratified
    # per-population draws of max(round(participation * pop), 1) clients —
    # the expectation of the paper's protocol, with every slot real (the
    # pre-existing behavior, bit-parity-tested).  True: the paper's EXACT
    # uniform sampling — one draw of ceil(participation * n_devices)
    # clients over the whole population, routed into static per-arch slot
    # blocks whose unfilled slots fold at weight 0 through the validity
    # path (shapes stay static; loss/bytes use realized counts).
    sample_uniform: bool = False
    rounds: int = 1000             # T
    local_epochs: int = 5          # E
    lr: float = 0.1                # eta
    clip_norm: float = 10.0        # gradient clipping (Appendix A)
    batch_size: int = 50
    dirichlet_alpha: float = 0.3   # non-IID split concentration
    iid: bool = True
    algorithm: str = "fedhen"      # fedhen | noside | decouple
    seed: int = 0
    skip_nan_devices: bool = True  # Appendix A: drop NaN devices for the round
    # beyond-paper: FedProx-style proximal term mu/2 ||w - w_server||^2 on
    # client objectives (Li et al. 2020, the paper's related-work family);
    # composes with any of the three algorithms.  0 = off (paper setting).
    prox_mu: float = 0.0
    # Streaming cohort engine: train the round's cohort in chunks of this
    # many clients (per population), folding each chunk into running masked
    # aggregation sums — device memory becomes O(cohort_chunk) instead of
    # O(k).  0 = whole population in one chunk.  "auto" derives the chunk
    # from the flat layout's per-client byte footprint vs
    # ``agg_memory_budget_mb`` (core/flatten.auto_cohort_chunk).  Populations
    # whose size the chunk does not divide are padded with zero-validity
    # clients, so the aggregate is unchanged (see core/federated.py).
    cohort_chunk: Union[int, str] = 0
    # Aggregation engine: "flat" packs each trained chunk into one
    # contiguous (Z, n_flat) buffer (core/flatten.py) and folds it with a
    # single in-place-accumulating masked_agg launch; "tree" is the
    # per-leaf PR 2 engine (parity reference, one launch per leaf).
    agg_engine: str = "flat"
    # masked_agg kernel lane-tile width (multiple of 128) — the ROADMAP
    # block-size sweep knob; the flat layout's total length is rounded up
    # to it so the fold needs no call-time padding.
    agg_block_n: int = 2048
    # dtype trained chunks stream through the fold in ("bfloat16" halves
    # the fold's HBM read traffic; accumulation is always f32).
    agg_stream_dtype: str = "float32"
    # memory budget targeted by cohort_chunk="auto" (per-client packed
    # footprint x multiplier x chunk <= this).
    agg_memory_budget_mb: float = 512.0
    # Wire dtype of the communication path (core/comm.py): the server
    # broadcast is decoded from this format on clients, and client uploads
    # are folded through it ("int8" via the dequantizing masked_agg
    # variant — ~3.9x smaller payloads than f32 incl. the scale sidecar).
    # "float32" is the identity wire (paper accounting, no transform).
    comm_dtype: str = "float32"
    # int8 wire scale-group size: one f32 scale per this many elements.
    # Must divide the flat layout's lane alignment (128) so scale groups
    # never cross a LeafSlot boundary.
    quant_block: int = 128
    # Wire v2 upload-path knobs (core/comm.py).  topk_frac < 1 uploads
    # only the k = ceil(frac * n) largest-|delta| entries as index+value
    # payloads (k rounded up to the 128-lane multiple); stochastic
    # rounding makes the lossy encode unbiased (seeded per client+round);
    # error_feedback keeps a per-client residual row
    # (core/state_store.py) accumulating the compression error so it is
    # re-uploaded next participation.  Any of the three switches the
    # upload from full params to deltas vs the trained-on broadcast; all
    # defaults leave the pre-existing wire bit-identical.
    topk_frac: float = 1.0
    stochastic_rounding: bool = False
    error_feedback: bool = False
    # Asynchronous round engine (core/async_rounds.py): bounded staleness
    # lag measured in chunk folds.  Chunk ``i`` of a round trains on the
    # server params published at fold ``i - async_lag`` of the global fold
    # stream — the first ``async_lag`` chunks of every round overlap the
    # previous round's server fold and therefore train on a stale,
    # version-tagged broadcast.  0 = fully synchronous (today's engine,
    # bit-for-bit).
    async_lag: int = 0
    # Staleness weighting scheme for stale uploads: "poly" applies the
    # FedAsync polynomial decay 1/(1+s)^async_decay (s = staleness in
    # rounds) to the client's validity weight before the masked fold;
    # "none" folds stale uploads at full weight.
    async_staleness: str = "poly"
    # Exponent a of the polynomial staleness decay 1/(1+s)^a.
    async_decay: float = 0.5
    # Variance reduction over the per-client flat state store
    # (core/state_store.py): "scaffold" maintains a global control variate
    # c and per-client c_i (Karimireddy et al. 2020, option II) packed
    # through the same FlatLayout as params, corrected into every local
    # SGD step and folded as a second flat accumulator through the masked
    # aggregation launch.  "none" = paper protocol, bit-identical rounds.
    variance_reduction: str = "none"
    # Backing store for the (N_clients, n_flat) per-client vectors:
    # "device" (jnp array), "host" (numpy), "mmap" (np.memmap tempfile for
    # population-scale N), or "auto" (pick by footprint).
    state_store_backend: str = "auto"

    def __post_init__(self):
        self.validate()

    def validate(self) -> None:
        """Single entry point for every config-rejection rule.

        Called from ``__post_init__`` (construction-time failure),
        ``FederatedTrainer.__init__`` and ``launch/train.py`` — so a
        config built by ``dataclasses.replace`` or deserialization hits
        the same wall as one built by the CLI.  Raises ``ValueError``
        with a distinct message per rule (one test each in
        tests/test_config.py).
        """
        # call-time import: the config leaf module must not pull repro.core
        # (aggregate/comm) at import — both import jax-heavy machinery and
        # comm itself imports this module
        from repro.core.aggregate import ALGORITHMS
        if self.algorithm not in ALGORITHMS:
            raise ValueError(f"unknown algorithm {self.algorithm!r} "
                             f"(expected one of {ALGORITHMS})")
        if self.agg_engine not in ("flat", "tree"):
            raise ValueError(f"unknown agg_engine {self.agg_engine!r}")
        if self.agg_block_n <= 0 or self.agg_block_n % 128:
            raise ValueError("agg_block_n must be a positive multiple of 128")
        if self.agg_stream_dtype not in ("float32", "bfloat16"):
            raise ValueError(f"agg_stream_dtype must be float32 or "
                             f"bfloat16, got {self.agg_stream_dtype!r}")
        if isinstance(self.cohort_chunk, str) and self.cohort_chunk != "auto":
            raise ValueError(f"cohort_chunk must be an int or 'auto', got "
                             f"{self.cohort_chunk!r}")
        # wire validation lives with the wire (one source of truth for the
        # dtype set, quant_block | lane-alignment rule and the v2 knob
        # rules: topk_frac range, stochastic-on-f32, EF-on-lossless)
        from repro.core.comm import WireSpec
        spec = WireSpec(self.comm_dtype, self.quant_block,
                        topk_frac=self.topk_frac,
                        stochastic=self.stochastic_rounding,
                        error_feedback=self.error_feedback)
        if self.comm_dtype == "int8" and self.agg_engine != "flat":
            raise ValueError("comm_dtype=int8 requires agg_engine='flat' "
                             "(the dequantizing fold is a flat-buffer op)")
        if spec.uses_deltas and self.agg_engine != "flat":
            raise ValueError("compressed uploads (topk_frac < 1, "
                             "stochastic_rounding or error_feedback) require "
                             "agg_engine='flat' (the delta fold is a "
                             "flat-buffer op)")
        if self.async_lag < 0:
            raise ValueError("async_lag must be >= 0 (folds of broadcast "
                             f"staleness), got {self.async_lag}")
        if self.async_staleness not in ("poly", "none"):
            raise ValueError(f"async_staleness must be 'poly' or 'none', "
                             f"got {self.async_staleness!r}")
        if self.async_decay < 0:
            raise ValueError(f"async_decay must be >= 0, "
                             f"got {self.async_decay}")
        if self.variance_reduction not in ("none", "scaffold"):
            raise ValueError(f"variance_reduction must be 'none' or "
                             f"'scaffold', got {self.variance_reduction!r}")
        if self.state_store_backend not in ("auto", "device", "host", "mmap"):
            raise ValueError(f"state_store_backend must be one of "
                             f"auto/device/host/mmap, "
                             f"got {self.state_store_backend!r}")
        if self.variance_reduction == "scaffold" and self.lr <= 0:
            raise ValueError("variance_reduction='scaffold' requires lr > 0 "
                             "(control-variate deltas divide by K*lr)")
