"""RecurrentGemma-2B [hybrid] — RG-LRU + local attention, 1:2 attn:recurrent
[arXiv:2402.19427].  26L, d_model 2560, 10 heads (MQA kv=1), d_ff 7680,
vocab 256000.  Griffin pattern period: (RG-LRU, RG-LRU, local attention),
window 2048.  head_dim 256.  Natively sub-quadratic -> runs long_500k as-is.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    source="arXiv:2402.19427",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    pattern=(LayerSpec("rglru"), LayerSpec("rglru"), LayerSpec("local_attn")),
    window=2048,
    d_rnn=2560,
    param_dtype="bfloat16",
    attn_shard="replicate",   # 10 heads / kv=1 do not divide the model axis
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=256, d_rnn=128, vocab_size=512, window=16, exit_layer=3,
        param_dtype="float32", compute_dtype="float32")
