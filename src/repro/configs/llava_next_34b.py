"""LLaVA-NeXT 34B-class [vlm] — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf].  Backbone: 60L, d_model 7168,
56 heads (GQA kv=8), d_ff 20480, vocab 64000.

The vision tower is the sanctioned STUB: ``input_specs`` provides
precomputed patch embeddings (anyres 4 tiles + base = 5 x 576 = 2880 tokens,
d_in 1152 SigLIP-class); the backbone owns only the 2-layer-equivalent
projector (single linear here) and consumes them prepended to the text."""

from repro.configs.base import LayerSpec, ModelConfig, StubFrontend

CONFIG = ModelConfig(
    name="llava-next-34b",
    arch_type="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20_480,
    vocab_size=64_000,
    pattern=(LayerSpec("attn"),),
    frontend=StubFrontend(kind="vision", n_tokens=2880, d_in=1152),
    param_dtype="bfloat16",
    # 56 q-heads / 8 kv-heads don't divide the 16-way model axis (and pjit
    # input shardings cannot pad), so shard head_dim (128/16=8) instead —
    # scores need an all-reduce over the contracted dim; hillclimb target.
    attn_shard="head_dim",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, exit_layer=1,
        frontend=StubFrontend(kind="vision", n_tokens=8, d_in=48),
        param_dtype="float32", compute_dtype="float32")
