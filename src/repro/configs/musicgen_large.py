"""MusicGen-Large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].  48L, d_model 2048, 32 heads (MHA kv=32), d_ff 8192,
vocab 2048 per codebook; 4 parallel codebooks (delay pattern handled by the
data pipeline), token embeddings summed, one output head per codebook.

The text-conditioning encoder (T5) and the EnCodec codec are the sanctioned
STUBS: ``input_specs`` provides precomputed conditioning embeddings
(64 tokens, d_in 1024) prepended to the sequence, and EnCodec tokens
directly."""

from repro.configs.base import LayerSpec, ModelConfig, StubFrontend

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    source="arXiv:2306.05284",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    n_codebooks=4,
    mlp_glu=False,            # vanilla transformer FFN (Audiocraft)
    pattern=(LayerSpec("attn"),),
    frontend=StubFrontend(kind="audio_conditioning", n_tokens=64, d_in=1024),
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=64, exit_layer=1, n_codebooks=2,
        frontend=StubFrontend(kind="audio_conditioning", n_tokens=4, d_in=32),
        param_dtype="float32", compute_dtype="float32")
