"""xLSTM-1.3B [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].
48L, d_model 2048, 4 heads, no separate FFN (d_ff=0; blocks carry their own
projections).  xLSTM[7:1] ratio -> period (7x mLSTM, 1x sLSTM) x 6.
Constant-size state -> runs long_500k natively."""

from repro.configs.base import LayerSpec, ModelConfig

_M = LayerSpec("mlstm", "none")
_S = LayerSpec("slstm", "none")

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    source="arXiv:2405.04517",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    pattern=(_M, _M, _M, _M, _M, _M, _M, _S),
    mlstm_proj_factor=2.0,
    slstm_ff_factor=4.0 / 3.0,
    # 1024 (not 64): the (B, NH, DH, DH) chunk-boundary states are saved for
    # the backward pass, so fewer/larger chunks cut train memory ~16x at the
    # cost of a larger intra-chunk quadratic term — the same trade the
    # paper's fused CUDA kernels make.
    mlstm_chunk=1024,
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, vocab_size=512,
        pattern=(_M, _S), mlstm_chunk=8, exit_layer=2,
        param_dtype="float32", compute_dtype="float32")
