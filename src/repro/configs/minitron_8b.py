"""Minitron-8B [dense] — width/depth-pruned Nemotron-4 [arXiv:2407.14679].
32L, d_model 4096, 32 heads (GQA kv=8), d_ff 16384, vocab 256000."""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    arch_type="dense",
    source="arXiv:2407.14679",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=256_000,
    pattern=(LayerSpec("attn"),),
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, exit_layer=1,
        param_dtype="float32", compute_dtype="float32")
