"""Gemma-3 4B [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt].  34L, d_model 2560, 8 heads (GQA kv=4),
d_ff 10240, vocab 262144, local window 1024.
Pattern period (5x local, 1x global) x 5 + 4 local remainder layers."""

from repro.configs.base import LayerSpec, ModelConfig

_L = LayerSpec("local_attn")
_G = LayerSpec("attn")

CONFIG = ModelConfig(
    name="gemma3-4b",
    arch_type="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10_240,
    vocab_size=262_144,
    pattern=(_L, _L, _L, _L, _L, _G),
    window=1024,
    rope_theta=1_000_000.0,
    use_qk_norm=True,
    param_dtype="bfloat16",
    attn_shard="replicate",   # 8 heads < model axis (16)
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, window=16, exit_layer=2,
        pattern=(_L, _G),
        param_dtype="float32", compute_dtype="float32")
