"""StarCoder2-15B [dense] — GQA + RoPE [arXiv:2402.19173].
40L, d_model 6144, 48 heads (GQA kv=4), d_ff 24576, vocab 49152.
Pure full attention: long_500k runs the sliding-window variant
(longctx_window) and is flagged as such in the dry-run record."""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    arch_type="dense",
    source="arXiv:2402.19173",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24_576,
    vocab_size=49_152,
    pattern=(LayerSpec("attn"),),
    rope_theta=1_000_000.0,
    mlp_glu=False,            # StarCoder2 uses a plain (2-matrix) MLP
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=192, n_heads=6, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512, exit_layer=1,
        param_dtype="float32", compute_dtype="float32")
