"""Kimi K2 [moe] — trillion-parameter MoE (paper-table entry)
[arXiv:2501.kimi2].  61L, d_model 7168, 64 heads (GQA kv=8), per-expert
d_ff 2048, vocab 163840; 384 routed experts top-8 + 1 shared.

Deviation note: the real K2 keeps its first block dense; we model all 61
blocks as MoE (uniform period -> scan) — total params 1.03e12, active ~32B,
matching the 1T/A32B budget.  Trained with SGD (the paper's optimizer),
which is what keeps optimizer state at zero for the 1T dry-run; the
single-pod train_4k memory analysis documents that this config needs the
2-pod mesh for training (see EXPERIMENTS.md §Dry-run)."""

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    source="arXiv:2501.kimi2",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab_size=163_840,
    pattern=(LayerSpec("attn", "moe"),),
    moe=MoEConfig(n_experts=384, top_k=8, n_shared=1, d_expert=2048),
    param_dtype="bfloat16",
    shard_experts_2d=True,    # experts over model AND expert-ffn over data
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=512, exit_layer=1,
        moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_expert=128),
        shard_experts_2d=False,
        param_dtype="float32", compute_dtype="float32")
