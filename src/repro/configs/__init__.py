"""Architecture registry: the 10 assigned architectures + the paper's own
PreActResNet18/CIFAR setting, with ``input_specs`` ShapeDtypeStruct
stand-ins for the dry-run."""

from __future__ import annotations

import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (FedConfig, InputShape, ModelConfig,
                                INPUT_SHAPES, TRAIN_4K, PREFILL_32K,
                                DECODE_32K, LONG_500K)

_MODULES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "starcoder2-15b": "starcoder2_15b",
    "gemma2-2b": "gemma2_2b",
    "xlstm-1.3b": "xlstm_1_3b",
    "llava-next-34b": "llava_next_34b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "gemma3-4b": "gemma3_4b",
    "musicgen-large": "musicgen_large",
    "minitron-8b": "minitron_8b",
}

ARCH_NAMES = tuple(_MODULES)

# Archs whose paper config is natively sub-quadratic (bounded state / local
# window): run long_500k as configured.  The rest use the sliding-window
# longctx variant (cfg.longctx_window), flagged in the dry-run record.
NATIVE_LONGCTX = ("recurrentgemma-2b", "xlstm-1.3b", "gemma2-2b", "gemma3-4b")


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_reduced(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.reduced()


def needs_longctx_variant(cfg: ModelConfig, shape: InputShape) -> bool:
    return (shape.name == "long_500k"
            and cfg.name not in NATIVE_LONGCTX)


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins (no allocation) for every input a
# step function takes, per (arch x input shape).
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape,
                batch_override: Optional[int] = None
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    b = batch_override or shape.global_batch
    s = shape.seq_len
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    tok_shape = (b, s + 1) if shape.kind == "train" else (b, s)
    if shape.kind == "decode":
        tok_shape = (b, 1)
    if cfg.n_codebooks > 1:
        tok_shape = tok_shape + (cfg.n_codebooks,)
    specs["tokens"] = jax.ShapeDtypeStruct(tok_shape, jnp.int32)

    if cfg.frontend is not None and shape.kind != "decode":
        # frontend embeddings occupy the head of the sequence; the token part
        # shrinks so total length stays seq_len (handled by the step fns)
        specs["extra_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend.n_tokens, cfg.frontend.d_in),
            jnp.dtype(cfg.compute_dtype))
        t = specs["tokens"].shape
        specs["tokens"] = jax.ShapeDtypeStruct(
            (b, t[1] - cfg.frontend.n_tokens) + t[2:], jnp.int32)
    return specs
