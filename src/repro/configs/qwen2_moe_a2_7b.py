"""Qwen1.5-MoE-A2.7B [moe] — 4 shared + 60 routed experts, top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B].  24L, d_model 2048, 16 heads (kv=16),
per-expert d_ff 1408, vocab 151936."""

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151_936,
    pattern=(LayerSpec("attn", "moe"),),
    moe=MoEConfig(n_experts=60, top_k=4, n_shared=4, d_expert=1408),
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, exit_layer=1,
        moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_expert=128),
        param_dtype="float32", compute_dtype="float32")
