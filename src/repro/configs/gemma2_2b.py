"""Gemma-2 2B [dense] — alternating local/global attention with logit
softcapping [arXiv:2408.00118].  26L, d_model 2304, 8 heads (GQA kv=4),
d_ff 9216, vocab 256000, window 4096, attn softcap 50, final softcap 30."""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    arch_type="dense",
    source="arXiv:2408.00118",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    pattern=(LayerSpec("local_attn"), LayerSpec("attn")),
    window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    param_dtype="bfloat16",
    attn_shard="replicate",   # 8 heads < model axis (16)
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, window=16, exit_layer=2,
        param_dtype="float32", compute_dtype="float32")
