"""Federated dataset splits (paper §3 'FL dataset').

* IID: random equal partition over clients.
* Non-IID: Dirichlet prior over label proportions per client
  (Yurochkin et al. 2019), concentration alpha.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

Batch = Dict[str, np.ndarray]


def iid_split(data: Batch, n_clients: int, seed: int = 0) -> List[Batch]:
    n = len(next(iter(data.values())))
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    per = n // n_clients
    return [
        {k: v[perm[i * per:(i + 1) * per]] for k, v in data.items()}
        for i in range(n_clients)]


def dirichlet_split(data: Batch, n_clients: int, alpha: float,
                    seed: int = 0, label_key: str = "labels") -> List[Batch]:
    """Label-Dirichlet non-IID split; every client gets an equal-size shard
    (sampling without replacement within classes, topping up IID if a class
    runs dry) so client datasets stay shape-static for vmapped training."""
    labels = np.asarray(data[label_key])
    n = len(labels)
    per = n // n_clients
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    pools = {c: list(rng.permutation(np.where(labels == c)[0]))
             for c in classes}

    shards = []
    for i in range(n_clients):
        props = rng.dirichlet(np.full(len(classes), alpha))
        # largest-remainder rounding: hand the floor-rounding shortfall to
        # the classes with the largest fractional parts (dumping it all on
        # the last class would bias its realized marginal high)
        ideal = props * per
        counts = np.floor(ideal).astype(int)
        short = per - counts.sum()
        if short:
            order = np.argsort(-(ideal - counts))
            counts[order[:short]] += 1
        take: List[int] = []
        for c, k in zip(classes, counts):
            pool = pools[c]
            got = pool[:k]
            pools[c] = pool[k:]
            take.extend(got)
        # top up from any remaining indices if classes ran dry — in a
        # fresh random class order each pass, so the top-up surplus does
        # not systematically favor the low class ids
        while len(take) < per:
            for c in rng.permutation(classes):
                if pools[c]:
                    take.append(pools[c].pop())
                    if len(take) == per:
                        break
        idx = np.asarray(take[:per])
        shards.append({k: v[idx] for k, v in data.items()})
    return shards


def label_distribution(shards: List[Batch], n_classes: int,
                       label_key: str = "labels") -> np.ndarray:
    out = np.zeros((len(shards), n_classes))
    for i, s in enumerate(shards):
        lab, cnt = np.unique(s[label_key], return_counts=True)
        out[i, lab] = cnt
    # an empty shard has no distribution: keep its row zero, not NaN
    totals = out.sum(1, keepdims=True)
    return out / np.maximum(totals, 1.0)
