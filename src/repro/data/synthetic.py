"""Synthetic datasets (deterministic, offline-friendly).

* ``synthetic_cifar`` — class-conditional images: each class has a smooth
  random prototype; samples are prototype + structured noise.  Learnable by
  both the simple and complex ResNets, separable enough that federated
  convergence ordering (the paper's claim) is measurable in tens of rounds.
* ``synthetic_lm`` — first-order Markov token streams with a class-dependent
  transition matrix; learnable by small decoder LMs.
* ``synthetic_frontend_embeds`` — stand-ins for the stubbed modality
  frontends (VLM patches / audio conditioning).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_cifar(n: int, n_classes: int, seed: int = 0,
                    image_size: int = 32) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    # smooth prototypes: low-frequency random fields per class
    base = rng.normal(size=(n_classes, 8, 8, 3)).astype(np.float32)
    protos = np.stack([
        np.kron(base[c], np.ones((image_size // 8, image_size // 8, 1)))
        for c in range(n_classes)])
    labels = rng.integers(0, n_classes, size=n).astype(np.int32)
    noise = rng.normal(scale=0.6, size=(n, image_size, image_size, 3))
    images = protos[labels] + noise.astype(np.float32)
    return {"images": images.astype(np.float32), "labels": labels}


def synthetic_lm(n_seqs: int, seq_len: int, vocab: int,
                 seed: int = 0, n_codebooks: int = 1,
                 chain_seed: int = 1234) -> Dict[str, np.ndarray]:
    """``seed`` controls the sampled streams; ``chain_seed`` controls the
    transition structure — train/test splits must share the latter."""
    rng = np.random.default_rng(chain_seed)
    # peaked Markov chain: one dominant successor (p~0.75) + a runner-up,
    # so argmax accuracy is learnable (optimum ~0.75) and convergence
    # ordering between algorithms is measurable in tens of rounds
    probs = np.full((vocab, vocab), 0.1 / vocab, np.float32)
    succ = rng.permutation(vocab)
    succ2 = rng.permutation(vocab)
    for v in range(vocab):
        probs[v, succ[v]] += 0.75
        probs[v, succ2[v]] += 0.15
    probs /= probs.sum(1, keepdims=True)
    cdf = np.cumsum(probs, axis=1)

    def sample_stream(k):
        r = np.random.default_rng(seed * 7919 + k)
        out = np.empty(seq_len + 1, np.int32)
        out[0] = r.integers(vocab)
        u = r.random(seq_len)
        for t in range(seq_len):
            out[t + 1] = np.searchsorted(cdf[out[t]], u[t])
        return out

    tokens = np.stack([sample_stream(i) for i in range(n_seqs)])
    if n_codebooks > 1:
        shifted = [np.roll(tokens, c, axis=1) for c in range(n_codebooks)]
        tokens = np.stack(shifted, axis=-1)
    # labels for dirichlet splitting: dominant token bucket
    labels = (tokens.reshape(n_seqs, -1)[:, 0] % 10).astype(np.int32)
    return {"tokens": tokens, "labels": labels}


def synthetic_frontend_embeds(n: int, n_tokens: int, d_in: int,
                              seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.normal(scale=0.5, size=(n, n_tokens, d_in)).astype(np.float32)
