"""Pure-jnp oracle for the masked cohort aggregation (FedHeN Alg. 1).

Contract (per flattened parameter leaf):

    out[n] = sum_z x[z, n] * (mask[n] ? w_m[z] : w_rest[z])

which implements server lines 18 + 22 in one pass: inside the index set M
the cohort is averaged with ``w_m`` (all active devices, 1/|Z|), outside M
with ``w_rest`` (complex devices only, 1/|Z_c|).  Weights of NaN-skipped
devices are zero; inputs of zero-weight devices are gated before the
multiply so non-finite values cannot poison the sum.
"""

from __future__ import annotations

import jax.numpy as jnp


def masked_agg_ref(x: jnp.ndarray, mask: jnp.ndarray, w_m: jnp.ndarray,
                   w_rest: jnp.ndarray) -> jnp.ndarray:
    """x: (Z, N); mask: (N,) bool; w_m/w_rest: (Z,) f32 -> (N,) in x.dtype."""
    xf = x.astype(jnp.float32)
    w = jnp.where(mask[None, :], w_m[:, None], w_rest[:, None])
    xf = jnp.where(w > 0, xf, 0.0)
    return jnp.sum(xf * w, axis=0).astype(x.dtype)


def masked_agg_acc_ref(acc: jnp.ndarray, x: jnp.ndarray, mask: jnp.ndarray,
                       w_m: jnp.ndarray, w_rest: jnp.ndarray) -> jnp.ndarray:
    """Accumulating form: acc (N,) f32 + masked sum of x (Z, N) -> f32.

    x may be bf16 (streaming dtype); the sum and the accumulator stay f32
    — this is the oracle for ``masked_agg_acc_pallas``.

    The cohort axis is accumulated row by row (Z is static and small —
    the chunk size), mirroring how the kernel streams tiles: every term is
    an elementwise ``(N,)`` chain XLA fuses outright, so the CPU path never
    materializes a ``(Z, N)`` product the way a one-shot ``reduce`` over a
    packed buffer would — and slice-of-concatenate simplification deletes
    the packed buffer itself."""
    out = acc
    for z in range(x.shape[0]):
        wz = jnp.where(mask, w_m[z], w_rest[z]).astype(jnp.float32)
        xz = jnp.where(wz > 0, x[z].astype(jnp.float32), 0.0)
        out = out + xz * wz
    return out


def masked_agg_acc_deq_ref(acc: jnp.ndarray, q: jnp.ndarray,
                           scales: jnp.ndarray, mask: jnp.ndarray,
                           w_m: jnp.ndarray, w_rest: jnp.ndarray, *,
                           quant_block: int) -> jnp.ndarray:
    """Dequantizing accumulating fold (oracle for
    ``masked_agg_acc_deq_pallas``): acc (N,) f32 + masked sum of int8
    payload q (Z, N) x per-group f32 scales (Z, N/quant_block) -> f32.

    Row-streamed like ``masked_agg_acc_ref``: each client's payload is
    dequantized inside its own fused elementwise chain (int8 -> f32 cast,
    per-group scale broadcast, gate, FMA), so no f32 copy of the whole
    quantized chunk ever materializes — the CPU mirror of the kernel's
    tile-local dequant.  A non-finite scale row (NaN device) is killed by
    the weight gate before the multiply, same as the f32 paths.
    """
    z, n = q.shape
    out = acc
    for row in range(z):
        s = jnp.repeat(scales[row], quant_block, total_repeat_length=n)
        xz = q[row].astype(jnp.float32) * s
        wz = jnp.where(mask, w_m[row], w_rest[row]).astype(jnp.float32)
        xz = jnp.where(wz > 0, xz, 0.0)
        out = out + xz * wz
    return out


def masked_scatter_acc_ref(acc: jnp.ndarray, values: jnp.ndarray,
                           scales, indices: jnp.ndarray,
                           mask: jnp.ndarray, w_m: jnp.ndarray,
                           w_rest: jnp.ndarray, *,
                           quant_block: int) -> jnp.ndarray:
    """Sparse scatter-fold (oracle for ``masked_scatter_acc_pallas``):
    acc (N,) f32 += each client's compacted payload values (Z, k) x
    per-group scales (Z, k/quant_block) scattered at flat positions
    indices (Z, k) int32.

    Row-streamed like the dense accumulating refs: one XLA scatter-add
    per client over the compacted ``(k,)`` values — the dense ``(Z, N)``
    f32 cohort copy never materializes.  The weight at each target
    position is ``mask[idx] ? w_m[z] : w_rest[z]``; zero weights gate
    the value before the add (NaN-device contract), and a row whose
    weights are both zero is dropped entirely.  ``scales=None`` skips
    the dequant (bf16/f32 payloads)."""
    z, k = values.shape
    out = acc
    for row in range(z):
        v = values[row].astype(jnp.float32)
        if scales is not None:
            v = v * jnp.repeat(scales[row], quant_block,
                               total_repeat_length=k)
        v = jnp.where((w_m[row] > 0) | (w_rest[row] > 0), v, 0.0)
        w_at = jnp.where(jnp.take(mask, indices[row]), w_m[row],
                         w_rest[row]).astype(jnp.float32)
        v = jnp.where(w_at > 0, v, 0.0) * w_at
        out = out.at[indices[row]].add(v)
    return out
