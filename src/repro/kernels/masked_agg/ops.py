"""Masked cohort aggregation over parameter pytrees + backend dispatch.

The server hot path: ``core.aggregate.streaming_fold`` owns the flat
engine's dispatch — on the kernel path it packs each chunk into one
contiguous ``(Z, n_flat)`` buffer and calls ``masked_agg_acc_pallas``
(re-exported here) with *raw* unnormalized weights, accumulating into one
flat f32 running sum divided once per round: one launch per fold, updated
in place via ``input_output_aliases``; on CPU it folds per leaf directly
into the flat accumulator's slices.  Under an int8 wire
(``FedConfig.comm_dtype``) the fold instead calls
``masked_agg_acc_deq_pallas`` — the dequantizing accumulate that consumes
the wire payload + per-group scales directly (``masked_agg_acc_deq_ref``
is its CPU/oracle form).  ``masked_agg_tree`` below keeps the PR 2
per-leaf path (one launch per leaf) as the parity engine.

Backend selection (``use_pallas``): the Pallas kernel targets TPU; on CPU
(this container) the XLA reference path runs instead — set
``force_pallas_interpret=True`` to exercise the kernel body in interpret
mode (tests do), or ``REPRO_MASKED_AGG=ref|pallas`` to override the
automatic choice.
"""

from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.masked_agg.kernel import (masked_agg_acc_deq_pallas,
                                             masked_agg_acc_pallas,
                                             masked_agg_pallas,
                                             masked_scatter_acc_pallas)
from repro.kernels.masked_agg.ref import (masked_agg_acc_deq_ref,
                                          masked_agg_acc_ref,
                                          masked_agg_ref,
                                          masked_scatter_acc_ref)

Tree = Any


def use_pallas() -> bool:
    """True when the Pallas kernel (not the XLA reference) should run."""
    override = os.environ.get("REPRO_MASKED_AGG", "")
    if override in ("ref", "pallas"):
        return override == "pallas"
    return jax.default_backend() == "tpu"


def masked_agg_leaf(x: jax.Array, mask: jax.Array, w_m: jax.Array,
                    w_rest: jax.Array, *, block_n: int = 2048,
                    force_pallas_interpret: bool = False) -> jax.Array:
    """One stacked leaf: x (Z, ...) + broadcastable mask -> aggregated (…)."""
    z = x.shape[0]
    body = x.reshape(z, -1)
    # mask is broadcastable against one cohort member's shape (x.shape[1:])
    mask_flat = jnp.broadcast_to(jnp.asarray(mask),
                                 x.shape[1:]).reshape(-1)
    if force_pallas_interpret:
        out = masked_agg_pallas(body, mask_flat, w_m, w_rest,
                                block_n=block_n, interpret=True)
    elif use_pallas():
        out = masked_agg_pallas(body, mask_flat, w_m, w_rest,
                                block_n=block_n)
    else:
        out = masked_agg_ref(body, mask_flat, w_m, w_rest)
    return out.reshape(x.shape[1:])


def masked_agg_tree(cohort: Tree, mask_tree: Tree, w_m: jax.Array,
                    w_rest: jax.Array, **kw) -> Tree:
    """Apply the aggregation across a stacked cohort pytree (per leaf).

    Weights are RAW per-client coefficients (a weighted *sum*, not a
    mean): the streaming server step passes unnormalized validity weights
    per chunk and divides by the running totals once per round.  Callers
    wanting a mean must normalize w_m/w_rest themselves."""
    return jax.tree.map(
        lambda x, m: masked_agg_leaf(x, m, w_m, w_rest, **kw),
        cohort, mask_tree)
