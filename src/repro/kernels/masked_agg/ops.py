"""Jit'd wrapper: masked cohort aggregation over parameter pytrees.

This is the server hot path: ``core.aggregate.streaming_fold`` calls
``masked_agg_tree`` once per cohort chunk with *raw* (unnormalized) weights,
accumulating partial sums that are divided once per round — so each client
model leaf streams through the kernel exactly once regardless of chunking.

Backend selection: the Pallas kernel targets TPU; on CPU (this container)
the XLA reference path runs instead — set ``force_pallas_interpret=True``
to exercise the kernel body in interpret mode (tests do), or
``REPRO_MASKED_AGG=ref|pallas`` to override the automatic choice.
"""

from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.masked_agg.kernel import masked_agg_pallas
from repro.kernels.masked_agg.ref import masked_agg_ref

Tree = Any


def _use_pallas() -> bool:
    override = os.environ.get("REPRO_MASKED_AGG", "")
    if override in ("ref", "pallas"):
        return override == "pallas"
    return jax.default_backend() == "tpu"


def masked_agg_leaf(x: jax.Array, mask: jax.Array, w_m: jax.Array,
                    w_rest: jax.Array, *,
                    force_pallas_interpret: bool = False) -> jax.Array:
    """One stacked leaf: x (Z, ...) + broadcastable mask -> aggregated (…)."""
    z = x.shape[0]
    body = x.reshape(z, -1)
    # mask is broadcastable against one cohort member's shape (x.shape[1:])
    mask_flat = jnp.broadcast_to(jnp.asarray(mask),
                                 x.shape[1:]).reshape(-1)
    if force_pallas_interpret:
        out = masked_agg_pallas(body, mask_flat, w_m, w_rest, interpret=True)
    elif _use_pallas():
        out = masked_agg_pallas(body, mask_flat, w_m, w_rest)
    else:
        out = masked_agg_ref(body, mask_flat, w_m, w_rest)
    return out.reshape(x.shape[1:])


def masked_agg_tree(cohort: Tree, mask_tree: Tree, w_m: jax.Array,
                    w_rest: jax.Array, **kw) -> Tree:
    """Apply the aggregation across a stacked cohort pytree.

    Weights are RAW per-client coefficients (a weighted *sum*, not a
    mean): the streaming server step passes unnormalized validity weights
    per chunk and divides by the running totals once per round.  Callers
    wanting a mean must normalize w_m/w_rest themselves."""
    return jax.tree.map(
        lambda x, m: masked_agg_leaf(x, m, w_m, w_rest, **kw),
        cohort, mask_tree)
