"""Jit'd wrapper: masked cohort aggregation over parameter pytrees.

Backend selection: the Pallas kernel targets TPU; on CPU (this container)
the XLA reference path runs instead — set ``force_pallas_interpret=True``
to exercise the kernel body in interpret mode (tests do).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.masked_agg.kernel import masked_agg_pallas
from repro.kernels.masked_agg.ref import masked_agg_ref

Tree = Any


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def masked_agg_leaf(x: jax.Array, mask: jax.Array, w_m: jax.Array,
                    w_rest: jax.Array, *,
                    force_pallas_interpret: bool = False) -> jax.Array:
    """One stacked leaf: x (Z, ...) + broadcastable mask -> aggregated (…)."""
    z = x.shape[0]
    body = x.reshape(z, -1)
    # mask is broadcastable against one cohort member's shape (x.shape[1:])
    mask_flat = jnp.broadcast_to(jnp.asarray(mask),
                                 x.shape[1:]).reshape(-1)
    if force_pallas_interpret:
        out = masked_agg_pallas(body, mask_flat, w_m, w_rest, interpret=True)
    elif _use_pallas():
        out = masked_agg_pallas(body, mask_flat, w_m, w_rest)
    else:
        out = masked_agg_ref(body, mask_flat, w_m, w_rest)
    return out.reshape(x.shape[1:])


def masked_agg_tree(cohort: Tree, mask_tree: Tree, w_m: jax.Array,
                    w_rest: jax.Array, **kw) -> Tree:
    """Apply the aggregation across a stacked cohort pytree (FedHeN server
    step: w_m = valid/|Z| weights, w_rest = complex-only weights)."""
    return jax.tree.map(
        lambda x, m: masked_agg_leaf(x, m, w_m, w_rest, **kw),
        cohort, mask_tree)
