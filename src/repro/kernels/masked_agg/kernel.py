"""Pallas TPU kernel: masked cohort aggregation (FedHeN server hot path).

The server step reduces a stacked cohort (Z client models) into one model
with different weights inside/outside the index set M.  The op is purely
memory-bound (read Z x N, write N), so the kernel's job is to stream the
cohort through VMEM exactly once with lane-aligned tiles:

* grid over N in ``block_n`` tiles (lane-dim multiple of 128),
* the whole cohort axis Z (<= ~32 active devices) rides along inside the
  tile: block (Z, block_n) -> VMEM,
* weights are selected per element from (w_m, w_rest) by the mask tile and
  reduced over Z in one fused multiply-add in f32, written back in the
  storage dtype.

VMEM budget: Z=32, block_n=2048, bf16 -> 128 KiB per input tile plus the
mask/out tiles; well under the ~16 MiB/core VMEM on v5e.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(x_ref, mask_ref, wm_ref, wr_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)              # (Z, block_n)
    mask = mask_ref[...]                            # (1, block_n) bool
    wm = wm_ref[...].astype(jnp.float32)            # (Z, 1)
    wr = wr_ref[...].astype(jnp.float32)            # (Z, 1)
    w = jnp.where(mask, wm, wr)                     # (Z, block_n)
    x = jnp.where(w > 0, x, 0.0)                    # NaN-device gating
    out_ref[...] = jnp.sum(x * w, axis=0,
                           keepdims=True).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def masked_agg_pallas(x: jax.Array, mask: jax.Array, w_m: jax.Array,
                      w_rest: jax.Array, *, block_n: int = 2048,
                      interpret: bool = False) -> jax.Array:
    """x: (Z, N); mask: (N,) bool; w_m/w_rest: (Z,) -> (N,) in x.dtype."""
    z, n = x.shape
    pad = (-n) % block_n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, (0, pad))
    np_ = x.shape[1]
    grid = (np_ // block_n,)

    out = pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((z, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), x.dtype),
        interpret=interpret,
    )(x, mask[None, :], w_m[:, None], w_rest[:, None])
    return out[0, :n]
