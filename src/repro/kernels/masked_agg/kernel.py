"""Pallas TPU kernel: masked cohort aggregation (FedHeN server hot path).

The server step reduces a stacked cohort (Z client models) into one model
with different weights inside/outside the index set M.  The op is purely
memory-bound (read Z x N, write N), so the kernel's job is to stream the
cohort through VMEM exactly once with lane-aligned tiles:

* grid over N in ``block_n`` tiles (lane-dim multiple of 128),
* the whole cohort axis Z (<= ~32 active devices) rides along inside the
  tile: block (Z, block_n) -> VMEM,
* weights are selected per element from (w_m, w_rest) by the mask tile and
  reduced over Z in one fused multiply-add in f32, written back in the
  storage dtype.

Four variants:

* ``masked_agg_pallas`` — the one-shot reduction (out = masked sum).
* ``masked_agg_acc_pallas`` — the streaming fold's accumulating form:
  ``out = acc + masked sum`` with ``input_output_aliases`` so the running
  f32 accumulator is updated **in place** — the fold writes N floats
  instead of reading+writing two accumulator copies, halving accumulator
  HBM traffic.  Inputs may be bf16; accumulation is always f32.
* ``masked_agg_acc_deq_pallas`` — the quantized-upload fold: the cohort
  tile arrives as int8 payload + per-group f32 scales (the wire format of
  ``core/comm.py``) and is dequantized *inside* the accumulate, so the
  server never materializes an f32 copy of the uploads — int8 tiles also
  cut the fold's HBM read traffic 4x vs f32.  ``quant_block`` must divide
  ``block_n`` so scale groups tile with the grid; the dequant reshape
  keeps the 128-lane axis intact ((Z, block_n) -> (Z, groups, 128-mult)).
* ``masked_scatter_acc_pallas`` — the top-k sparse-upload fold (wire v2):
  each client ships ``k`` compacted values (+ scale sidecar over the
  compacted payload) and their int32 flat positions; the kernel
  dequantizes the compacted payload tile-locally and scatters it into
  the accumulator block by block.  TPU has no dynamic lane scatter, so
  the scatter is a one-hot contraction: per grid block the kept indices
  are compared against the block's position range
  (``broadcasted_iota``) and the values matmul through the resulting
  one-hot — the (k_tile, block_n) one-hot lives only in VMEM, and the
  dense ``(Z, n_flat)`` f32 cohort copy never materializes anywhere.
  The k axis is chunked at ``k_tile`` to bound the one-hot's VMEM
  footprint (512 x 2048 f32 = 4 MiB).

Neither wrapper is ``jax.jit``-ed: both always run inside the already
jitted round (or a jitted test harness), where an extra jit would only add
eager-dispatch overhead and a second compilation cache.

VMEM budget: Z=32, block_n=2048, bf16 -> 128 KiB per input tile plus the
mask/acc/out tiles; well under the ~16 MiB/core VMEM on v5e.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(x_ref, mask_ref, wm_ref, wr_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)              # (Z, block_n)
    mask = mask_ref[...]                            # (1, block_n) bool
    wm = wm_ref[...].astype(jnp.float32)            # (Z, 1)
    wr = wr_ref[...].astype(jnp.float32)            # (Z, 1)
    w = jnp.where(mask, wm, wr)                     # (Z, block_n)
    x = jnp.where(w > 0, x, 0.0)                    # NaN-device gating
    out_ref[...] = jnp.sum(x * w, axis=0,
                           keepdims=True).astype(out_ref.dtype)


def masked_agg_pallas(x: jax.Array, mask: jax.Array, w_m: jax.Array,
                      w_rest: jax.Array, *, block_n: int = 2048,
                      interpret: bool = False) -> jax.Array:
    """x: (Z, N); mask: (N,) bool; w_m/w_rest: (Z,) -> (N,) in x.dtype."""
    z, n = x.shape
    pad = (-n) % block_n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, (0, pad))
    np_ = x.shape[1]
    grid = (np_ // block_n,)

    out = pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((z, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), x.dtype),
        interpret=interpret,
    )(x, mask[None, :], w_m[:, None], w_rest[:, None])
    return out[0, :n]


def _agg_acc_kernel(acc_ref, x_ref, mask_ref, wm_ref, wr_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)              # (Z, block_n)
    w = jnp.where(mask_ref[...],
                  wm_ref[...].astype(jnp.float32),
                  wr_ref[...].astype(jnp.float32))  # (Z, block_n)
    x = jnp.where(w > 0, x, 0.0)                    # NaN-device gating
    out_ref[...] = acc_ref[...] + jnp.sum(x * w, axis=0, keepdims=True)


def masked_agg_acc_pallas(acc: jax.Array, x: jax.Array, mask: jax.Array,
                          w_m: jax.Array, w_rest: jax.Array, *,
                          block_n: int = 2048,
                          interpret: bool = False) -> jax.Array:
    """Accumulating fold: acc (N,) f32 + masked sum of x (Z, N) -> (N,) f32.

    ``acc`` is aliased to the output (in-place update).  x may be any
    float dtype (bf16 streaming); the accumulation is f32.  N should be a
    multiple of ``block_n`` (the flat layout guarantees it); other sizes
    are padded, which costs the alias a copy.
    """
    if acc.dtype != jnp.float32:
        raise ValueError(f"accumulator must be f32, got {acc.dtype}")
    z, n = x.shape
    pad = (-n) % block_n
    if pad:
        acc = jnp.pad(acc, (0, pad))
        x = jnp.pad(x, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, (0, pad))
    np_ = x.shape[1]
    grid = (np_ // block_n,)

    out = pl.pallas_call(
        _agg_acc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), jnp.float32),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(acc[None, :], x, mask[None, :], w_m[:, None], w_rest[:, None])
    return out[0, :n]


def _make_agg_acc_deq_kernel(quant_block: int):
    def kernel(acc_ref, q_ref, scale_ref, mask_ref, wm_ref, wr_ref, out_ref):
        z, bn = q_ref.shape
        g = q_ref[...].astype(jnp.float32).reshape(z, bn // quant_block,
                                                   quant_block)
        x = (g * scale_ref[...][..., None]).reshape(z, bn)  # fused dequant
        w = jnp.where(mask_ref[...],
                      wm_ref[...].astype(jnp.float32),
                      wr_ref[...].astype(jnp.float32))      # (Z, block_n)
        x = jnp.where(w > 0, x, 0.0)                        # NaN-device gating
        out_ref[...] = acc_ref[...] + jnp.sum(x * w, axis=0, keepdims=True)
    return kernel


def masked_agg_acc_deq_pallas(acc: jax.Array, q: jax.Array,
                              scales: jax.Array, mask: jax.Array,
                              w_m: jax.Array, w_rest: jax.Array, *,
                              quant_block: int, block_n: int = 2048,
                              interpret: bool = False) -> jax.Array:
    """Dequantizing accumulating fold: acc (N,) f32 + masked sum of the
    int8 payload q (Z, N) x per-group scales (Z, N/quant_block) -> (N,) f32.

    ``acc`` is aliased to the output (in-place update); the payload is
    dequantized tile-locally in VMEM, never materialized in f32.  N must be
    a multiple of ``quant_block`` (the flat layout guarantees it: the wire
    contract requires quant_block | 128 | n_flat) and ``block_n`` must be a
    group multiple so scale groups tile with the grid.
    """
    if acc.dtype != jnp.float32:
        raise ValueError(f"accumulator must be f32, got {acc.dtype}")
    if q.dtype != jnp.int8:
        raise ValueError(f"payload must be int8, got {q.dtype}")
    if block_n % quant_block:
        raise ValueError(f"block_n={block_n} not a multiple of "
                         f"quant_block={quant_block}")
    z, n = q.shape
    if n % quant_block:
        raise ValueError(f"N={n} not a multiple of quant_block={quant_block}")
    pad = (-n) % block_n
    if pad:
        acc = jnp.pad(acc, (0, pad))
        q = jnp.pad(q, ((0, 0), (0, pad)))
        scales = jnp.pad(scales, ((0, 0), (0, pad // quant_block)))
        mask = jnp.pad(mask, (0, pad))
    np_ = q.shape[1]
    grid = (np_ // block_n,)
    block_g = block_n // quant_block

    out = pl.pallas_call(
        _make_agg_acc_deq_kernel(quant_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, block_g), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), jnp.float32),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(acc[None, :], q, scales, mask[None, :], w_m[:, None], w_rest[:, None])
    return out[0, :n]


# one-hot scatter contraction tile along the compacted-k axis: bounds the
# (k_tile, block_n) one-hot to 512 x 2048 f32 = 4 MiB of VMEM
_SCATTER_K_TILE = 512


def _make_scatter_acc_kernel(quant_block: int, block_n: int, k_tile: int):
    def kernel(acc_ref, v_ref, s_ref, idx_ref, mask_ref, wm_ref, wr_ref,
               out_ref):
        i = pl.program_id(0)
        z, k = v_ref.shape
        g = v_ref[...].astype(jnp.float32).reshape(z, k // quant_block,
                                                   quant_block)
        v = (g * s_ref[...][..., None]).reshape(z, k)   # fused dequant
        wm = wm_ref[...].astype(jnp.float32)            # (Z, 1)
        wr = wr_ref[...].astype(jnp.float32)            # (Z, 1)
        # NaN-device gating BEFORE the contraction: a poisoned row would
        # spread NaN over the whole block through the matmul's 0-terms
        v = jnp.where((wm > 0) | (wr > 0), v, 0.0)
        rel = idx_ref[...] - i * block_n                # (Z, k) int32
        mask = mask_ref[...]                            # (1, block_n)
        total = jnp.zeros((1, block_n), jnp.float32)
        for row in range(z):
            w_l = jnp.where(mask, wm[row, 0], wr[row, 0])   # (1, block_n)
            scat = jnp.zeros((block_n,), jnp.float32)
            for j0 in range(0, k, k_tile):
                j1 = min(j0 + k_tile, k)
                cols = jax.lax.broadcasted_iota(jnp.int32,
                                                (j1 - j0, block_n), 1)
                onehot = (rel[row, j0:j1, None] == cols).astype(jnp.float32)
                scat = scat + v[row, j0:j1] @ onehot
            total = total + jnp.where(w_l > 0, scat[None, :], 0.0) * w_l
        out_ref[...] = acc_ref[...] + total
    return kernel


def masked_scatter_acc_pallas(acc: jax.Array, values: jax.Array,
                              scales, indices: jax.Array,
                              mask: jax.Array, w_m: jax.Array,
                              w_rest: jax.Array, *, quant_block: int,
                              block_n: int = 2048,
                              interpret: bool = False) -> jax.Array:
    """Sparse scatter-fold: acc (N,) f32 += masked scatter of each
    client's compacted payload values (Z, k) x per-group scales
    (Z, k/quant_block) at flat positions indices (Z, k) int32.

    ``acc`` is aliased to the output (in-place update).  ``values`` may
    be int8/bf16/f32; ``scales=None`` means no sidecar (a ones sidecar is
    synthesized so one kernel body serves every wire dtype).  ``k`` must
    be a ``quant_block`` multiple (``comm.topk_count`` rounds up to a
    lane multiple, which any valid ``quant_block`` divides).  Per-row
    indices must be distinct (``top_k`` guarantees it) and inside
    ``[0, N)``; the weight at each target position is selected by the
    mask there (w_m inside M, w_rest outside), zero weights gate the
    value, and a row with both weights zero (NaN/padding device) is
    zeroed before the contraction.
    """
    if acc.dtype != jnp.float32:
        raise ValueError(f"accumulator must be f32, got {acc.dtype}")
    z, k = values.shape
    if k % quant_block:
        raise ValueError(f"k={k} not a multiple of "
                         f"quant_block={quant_block}")
    if indices.shape != (z, k):
        raise ValueError(f"indices shape {indices.shape} != {(z, k)}")
    if scales is None:
        scales = jnp.ones((z, k // quant_block), jnp.float32)
    n = acc.shape[0]
    pad = (-n) % block_n
    if pad:
        acc = jnp.pad(acc, (0, pad))
        mask = jnp.pad(mask, (0, pad))
    np_ = acc.shape[0]
    grid = (np_ // block_n,)

    out = pl.pallas_call(
        _make_scatter_acc_kernel(quant_block, block_n,
                                 min(k, _SCATTER_K_TILE)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, k), lambda i: (0, 0)),
            pl.BlockSpec((z, k // quant_block), lambda i: (0, 0)),
            pl.BlockSpec((z, k), lambda i: (0, 0)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), jnp.float32),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(acc[None, :], values, scales, indices.astype(jnp.int32),
      mask[None, :], w_m[:, None], w_rest[:, None])
    return out[0, :n]
