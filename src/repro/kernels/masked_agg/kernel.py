"""Pallas TPU kernel: masked cohort aggregation (FedHeN server hot path).

The server step reduces a stacked cohort (Z client models) into one model
with different weights inside/outside the index set M.  The op is purely
memory-bound (read Z x N, write N), so the kernel's job is to stream the
cohort through VMEM exactly once with lane-aligned tiles:

* grid over N in ``block_n`` tiles (lane-dim multiple of 128),
* the whole cohort axis Z (<= ~32 active devices) rides along inside the
  tile: block (Z, block_n) -> VMEM,
* weights are selected per element from (w_m, w_rest) by the mask tile and
  reduced over Z in one fused multiply-add in f32, written back in the
  storage dtype.

Three variants:

* ``masked_agg_pallas`` — the one-shot reduction (out = masked sum).
* ``masked_agg_acc_pallas`` — the streaming fold's accumulating form:
  ``out = acc + masked sum`` with ``input_output_aliases`` so the running
  f32 accumulator is updated **in place** — the fold writes N floats
  instead of reading+writing two accumulator copies, halving accumulator
  HBM traffic.  Inputs may be bf16; accumulation is always f32.
* ``masked_agg_acc_deq_pallas`` — the quantized-upload fold: the cohort
  tile arrives as int8 payload + per-group f32 scales (the wire format of
  ``core/comm.py``) and is dequantized *inside* the accumulate, so the
  server never materializes an f32 copy of the uploads — int8 tiles also
  cut the fold's HBM read traffic 4x vs f32.  ``quant_block`` must divide
  ``block_n`` so scale groups tile with the grid; the dequant reshape
  keeps the 128-lane axis intact ((Z, block_n) -> (Z, groups, 128-mult)).

Neither wrapper is ``jax.jit``-ed: both always run inside the already
jitted round (or a jitted test harness), where an extra jit would only add
eager-dispatch overhead and a second compilation cache.

VMEM budget: Z=32, block_n=2048, bf16 -> 128 KiB per input tile plus the
mask/acc/out tiles; well under the ~16 MiB/core VMEM on v5e.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(x_ref, mask_ref, wm_ref, wr_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)              # (Z, block_n)
    mask = mask_ref[...]                            # (1, block_n) bool
    wm = wm_ref[...].astype(jnp.float32)            # (Z, 1)
    wr = wr_ref[...].astype(jnp.float32)            # (Z, 1)
    w = jnp.where(mask, wm, wr)                     # (Z, block_n)
    x = jnp.where(w > 0, x, 0.0)                    # NaN-device gating
    out_ref[...] = jnp.sum(x * w, axis=0,
                           keepdims=True).astype(out_ref.dtype)


def masked_agg_pallas(x: jax.Array, mask: jax.Array, w_m: jax.Array,
                      w_rest: jax.Array, *, block_n: int = 2048,
                      interpret: bool = False) -> jax.Array:
    """x: (Z, N); mask: (N,) bool; w_m/w_rest: (Z,) -> (N,) in x.dtype."""
    z, n = x.shape
    pad = (-n) % block_n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, (0, pad))
    np_ = x.shape[1]
    grid = (np_ // block_n,)

    out = pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((z, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), x.dtype),
        interpret=interpret,
    )(x, mask[None, :], w_m[:, None], w_rest[:, None])
    return out[0, :n]


def _agg_acc_kernel(acc_ref, x_ref, mask_ref, wm_ref, wr_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)              # (Z, block_n)
    w = jnp.where(mask_ref[...],
                  wm_ref[...].astype(jnp.float32),
                  wr_ref[...].astype(jnp.float32))  # (Z, block_n)
    x = jnp.where(w > 0, x, 0.0)                    # NaN-device gating
    out_ref[...] = acc_ref[...] + jnp.sum(x * w, axis=0, keepdims=True)


def masked_agg_acc_pallas(acc: jax.Array, x: jax.Array, mask: jax.Array,
                          w_m: jax.Array, w_rest: jax.Array, *,
                          block_n: int = 2048,
                          interpret: bool = False) -> jax.Array:
    """Accumulating fold: acc (N,) f32 + masked sum of x (Z, N) -> (N,) f32.

    ``acc`` is aliased to the output (in-place update).  x may be any
    float dtype (bf16 streaming); the accumulation is f32.  N should be a
    multiple of ``block_n`` (the flat layout guarantees it); other sizes
    are padded, which costs the alias a copy.
    """
    if acc.dtype != jnp.float32:
        raise ValueError(f"accumulator must be f32, got {acc.dtype}")
    z, n = x.shape
    pad = (-n) % block_n
    if pad:
        acc = jnp.pad(acc, (0, pad))
        x = jnp.pad(x, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, (0, pad))
    np_ = x.shape[1]
    grid = (np_ // block_n,)

    out = pl.pallas_call(
        _agg_acc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), jnp.float32),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(acc[None, :], x, mask[None, :], w_m[:, None], w_rest[:, None])
    return out[0, :n]


def _make_agg_acc_deq_kernel(quant_block: int):
    def kernel(acc_ref, q_ref, scale_ref, mask_ref, wm_ref, wr_ref, out_ref):
        z, bn = q_ref.shape
        g = q_ref[...].astype(jnp.float32).reshape(z, bn // quant_block,
                                                   quant_block)
        x = (g * scale_ref[...][..., None]).reshape(z, bn)  # fused dequant
        w = jnp.where(mask_ref[...],
                      wm_ref[...].astype(jnp.float32),
                      wr_ref[...].astype(jnp.float32))      # (Z, block_n)
        x = jnp.where(w > 0, x, 0.0)                        # NaN-device gating
        out_ref[...] = acc_ref[...] + jnp.sum(x * w, axis=0, keepdims=True)
    return kernel


def masked_agg_acc_deq_pallas(acc: jax.Array, q: jax.Array,
                              scales: jax.Array, mask: jax.Array,
                              w_m: jax.Array, w_rest: jax.Array, *,
                              quant_block: int, block_n: int = 2048,
                              interpret: bool = False) -> jax.Array:
    """Dequantizing accumulating fold: acc (N,) f32 + masked sum of the
    int8 payload q (Z, N) x per-group scales (Z, N/quant_block) -> (N,) f32.

    ``acc`` is aliased to the output (in-place update); the payload is
    dequantized tile-locally in VMEM, never materialized in f32.  N must be
    a multiple of ``quant_block`` (the flat layout guarantees it: the wire
    contract requires quant_block | 128 | n_flat) and ``block_n`` must be a
    group multiple so scale groups tile with the grid.
    """
    if acc.dtype != jnp.float32:
        raise ValueError(f"accumulator must be f32, got {acc.dtype}")
    if q.dtype != jnp.int8:
        raise ValueError(f"payload must be int8, got {q.dtype}")
    if block_n % quant_block:
        raise ValueError(f"block_n={block_n} not a multiple of "
                         f"quant_block={quant_block}")
    z, n = q.shape
    if n % quant_block:
        raise ValueError(f"N={n} not a multiple of quant_block={quant_block}")
    pad = (-n) % block_n
    if pad:
        acc = jnp.pad(acc, (0, pad))
        q = jnp.pad(q, ((0, 0), (0, pad)))
        scales = jnp.pad(scales, ((0, 0), (0, pad // quant_block)))
        mask = jnp.pad(mask, (0, pad))
    np_ = q.shape[1]
    grid = (np_ // block_n,)
    block_g = block_n // quant_block

    out = pl.pallas_call(
        _make_agg_acc_deq_kernel(quant_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, block_g), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
            pl.BlockSpec((z, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), jnp.float32),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(acc[None, :], q, scales, mask[None, :], w_m[:, None], w_rest[:, None])
    return out[0, :n]
