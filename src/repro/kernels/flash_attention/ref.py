"""Pure-jnp oracle for the sliding-window flash attention kernel.

Semantics identical to ``models/attention.chunked_causal_attention`` but
restated independently (naive O(S^2) masked softmax) so the kernel test has
an oracle that shares no code with either implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window: int = 0,
                        softcap: float = 0.0) -> jax.Array:
    """q: (B, S, H, Dh); k/v: (B, S, Kh, Dh); causal (+ window) -> like q."""
    b, s, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf) * dh ** -0.5
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    pos = jnp.arange(s)
    mask = pos[:, None] >= pos[None, :]
    if window:
        mask &= (pos[:, None] - pos[None, :]) < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, vf)
    return out.reshape(b, s, h, dh).astype(q.dtype)
