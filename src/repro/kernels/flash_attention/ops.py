"""Jit'd wrapper for the flash attention kernel with backend dispatch.

On TPU the Pallas kernel runs; elsewhere the XLA chunked implementation
(models/attention.py) serves the same contract.  ``interpret=True``
exercises the kernel body on CPU (tests / debugging).
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.models.attention import chunked_causal_attention


def flash_attention(q, k, v, *, window: int = 0, softcap: float = 0.0,
                    force_pallas_interpret: bool = False):
    if force_pallas_interpret:
        return flash_attention_pallas(q, k, v, window=window,
                                      softcap=softcap, interpret=True)
    if jax.default_backend() == "tpu":
        return flash_attention_pallas(q, k, v, window=window,
                                      softcap=softcap)
    return chunked_causal_attention(q, k, v, window=window,
                                    softcap_val=softcap)
