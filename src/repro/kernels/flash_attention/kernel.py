"""Pallas TPU kernel: sliding-window flash attention (GQA, softcap).

TPU adaptation notes (vs the CUDA flash-attention the zoo's papers assume):

* TPU grids execute **sequentially** over the minor grid dimension, so the
  online-softmax accumulation state (m, l, acc) lives in VMEM scratch and
  is carried across the k-block grid dimension — no atomics, no shared-mem
  tiling, no warp shuffles.
* Tiles are MXU-aligned: the score tile is (G*block_q, block_k) so grouped
  (GQA) queries share their kv tile inside one matmul.
* The sliding window masks out-of-window k-blocks; TPU grids are static so
  masked blocks still iterate — the XLA wrapper narrows the k-range where
  window << S (see ops.py).

Forward only: training uses the XLA path (exact backward); this kernel is
the serving/prefill hot path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, n_kblocks: int, window: int,
                  softcap: float, scale: float):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    g = q_ref.shape[1]
    dh = q_ref.shape[-1]
    q = q_ref[0].reshape(g * block_q, dh).astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)              # (block_k, Dh)
    v = v_ref[0, 0].astype(jnp.float32)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (G*bq, bk)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap

    qb = pl.program_id(1)
    rows = jax.lax.broadcasted_iota(jnp.int32, (g * block_q, 1), 0)
    q_pos = qb * block_q + rows % block_q            # group-major rows
    k_pos = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_k), 1)
    delta = q_pos - k_pos
    mask = delta >= 0
    if window:
        mask &= delta < window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                              # (G*bq, 1)
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kb == n_kblocks - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        out_ref[0] = out.reshape(g, block_q, dh).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "block_q",
                                             "block_k", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           window: int = 0, softcap: float = 0.0,
                           block_q: int = 256, block_k: int = 256,
                           interpret: bool = False) -> jax.Array:
    """q: (B, S, H, Dh); k/v: (B, S, Kh, Dh) -> (B, S, H, Dh).

    Causal; ``window`` > 0 adds the sliding-window constraint.
    """
    b, s, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q or s % block_k:
        raise ValueError(f"S={s} must divide block sizes "
                         f"({block_q}, {block_k})")
    n_qblocks = s // block_q
    n_kblocks = s // block_k
    scale = dh ** -0.5

    # (B, S, Kh|H, Dh) -> (B*Kh, G|1, S, Dh): batch x kv-head on grid dim 0,
    # GQA groups ride inside the q tile.
    qx = q.reshape(b, s, kh, g, dh).transpose(0, 2, 3, 1, 4) \
          .reshape(b * kh, g, s, dh)
    kx = k.transpose(0, 2, 1, 3).reshape(b * kh, 1, s, dh)
    vx = v.transpose(0, 2, 1, 3).reshape(b * kh, 1, s, dh)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k,
        n_kblocks=n_kblocks, window=window, softcap=softcap, scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=(b * kh, n_qblocks, n_kblocks),
        in_specs=[
            pl.BlockSpec((1, g, block_q, dh),
                         lambda bk, qb, kb: (bk, 0, qb, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bk, qb, kb: (bk, 0, kb, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bk, qb, kb: (bk, 0, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, block_q, dh),
                               lambda bk, qb, kb: (bk, 0, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kh, g, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * block_q, 1), jnp.float32),
            pltpu.VMEM((g * block_q, 1), jnp.float32),
            pltpu.VMEM((g * block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qx, kx, vx)
    out = out.reshape(b, kh, g, s, dh).transpose(0, 3, 1, 2, 4)
    return out.reshape(b, s, h, dh)
