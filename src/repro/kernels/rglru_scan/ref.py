"""Pure-jnp oracle for the RG-LRU linear-recurrence kernel.

    y_t = a_t * y_{t-1} + b_t        (elementwise, per channel)

Sequential implementation — intentionally the dumbest possible version.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lru_scan_ref(a: jax.Array, b: jax.Array,
                 y0: jax.Array | None = None) -> jax.Array:
    """a, b: (B, S, D) f32 -> y: (B, S, D)."""
    bsz, s, d = a.shape
    y = jnp.zeros((bsz, d), jnp.float32) if y0 is None else y0
    ys = []
    for t in range(s):
        y = a[:, t] * y + b[:, t]
        ys.append(y)
    return jnp.stack(ys, axis=1)
