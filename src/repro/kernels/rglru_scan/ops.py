"""Jit'd wrapper for the RG-LRU recurrence with backend dispatch.

TPU -> Pallas carry-in-VMEM kernel; CPU -> the associative-scan XLA path
used by models/rglru.py (log-depth, good on CPU/GPU).
"""

from __future__ import annotations

import jax

from repro.kernels.rglru_scan.kernel import lru_scan_pallas


def lru_scan(a, b, *, force_pallas_interpret: bool = False):
    if force_pallas_interpret:
        return lru_scan_pallas(a, b, interpret=True)
    if jax.default_backend() == "tpu":
        return lru_scan_pallas(a, b)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, y = jax.lax.associative_scan(combine, (a, b), axis=1)
    return y
