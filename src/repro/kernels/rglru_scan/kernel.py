"""Pallas TPU kernel: RG-LRU linear recurrence (RecurrentGemma hot loop).

GPU implementations parallelize the recurrence with a work-efficient scan
across thread blocks.  The TPU-native shape is different: the VPU is very
fast at elementwise FMAs over (8, 128)-tiled registers, and the grid's
sequential-minor-dimension execution gives us a free carry mechanism.  So:

* grid = (B, D / block_d, S / block_s) with the TIME dimension innermost,
* the running state y (block_d lanes) lives in VMEM scratch and carries
  across time blocks,
* within a time block the recurrence unrolls over block_s steps of pure
  VPU FMA on (1, block_d) registers — time is sequential anyway; what
  matters is that the channel dimension fills the vector lanes.

This is the DESIGN.md "adapt, don't port" case: an associative-scan port
would waste the MXU and pay log(S) passes over HBM; the carry-in-VMEM
sequential grid reads a/b exactly once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lru_kernel(a_ref, b_ref, out_ref, y_scr, *, block_s: int):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        y_scr[...] = jnp.zeros_like(y_scr)

    a = a_ref[0].astype(jnp.float32)                 # (block_s, block_d)
    b = b_ref[0].astype(jnp.float32)
    y = y_scr[...]                                   # (1, block_d)

    rows = []
    for t in range(block_s):                         # unrolled VPU FMAs
        y = a[t:t + 1] * y + b[t:t + 1]
        rows.append(y)
    out = jnp.concatenate(rows, axis=0)              # (block_s, block_d)
    y_scr[...] = y
    out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "block_s",
                                             "interpret"))
def lru_scan_pallas(a: jax.Array, b: jax.Array, *, block_d: int = 512,
                    block_s: int = 32, interpret: bool = False) -> jax.Array:
    """a, b: (B, S, D) -> y: (B, S, D) with y_t = a_t*y_{t-1} + b_t."""
    bsz, s, d = a.shape
    block_d = min(block_d, d)
    block_s = min(block_s, s)
    if d % block_d or s % block_s:
        raise ValueError(f"(S={s}, D={d}) must divide blocks "
                         f"({block_s}, {block_d})")

    kernel = functools.partial(_lru_kernel, block_s=block_s)
    out = pl.pallas_call(
        kernel,
        grid=(bsz, d // block_d, s // block_s),
        in_specs=[
            pl.BlockSpec((1, block_s, block_d),
                         lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, block_s, block_d),
                         lambda bi, di, si: (bi, si, di)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_d),
                               lambda bi, di, si: (bi, si, di)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, d), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out
