"""Pytree checkpointing (npz-based, no external deps).

Round-resumable federated state: ``save_server`` / ``restore_server`` wrap
the complex tree (+ optional decouple simple host) with the round counter,
so ``launch/train.py`` can resume mid-run.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any
_SEP = "/"


def _flatten_with_paths(tree: Tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_tree(path: str, tree: Tree, metadata: Optional[Dict] = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten_with_paths(tree)
    # bf16 isn't npz-native: stash as uint16 view + dtype tag
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            arrays[k] = v.view(np.uint16)
            dtypes[k] = "bfloat16"
        else:
            arrays[k] = v
            dtypes[k] = str(v.dtype)
    arrays["__dtypes__"] = np.frombuffer(
        json.dumps(dtypes).encode(), dtype=np.uint8)
    if metadata is not None:
        arrays["__meta__"] = np.frombuffer(
            json.dumps(metadata).encode(), dtype=np.uint8)
    np.savez(path, **arrays)


def restore_tree(path: str, like: Tree) -> Tuple[Tree, Dict]:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    with np.load(path) as data:
        dtypes = json.loads(bytes(data["__dtypes__"]).decode())
        meta = (json.loads(bytes(data["__meta__"]).decode())
                if "__meta__" in data else {})
        flat_like = _flatten_with_paths(like)
        restored = {}
        for k, ref in flat_like.items():
            if k not in data:
                raise KeyError(f"checkpoint missing leaf {k}")
            v = data[k]
            if dtypes.get(k) == "bfloat16":
                v = v.view(jnp.bfloat16)
            if tuple(v.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch at {k}: {v.shape} vs {ref.shape}")
            restored[k] = jnp.asarray(v)
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)
    keys = [_SEP.join(_path_str(p) for p in path)
            for path, _ in leaves_paths[0]]
    return jax.tree_util.tree_unflatten(
        leaves_paths[1], [restored[k] for k in keys]), meta


def save_server(path: str, server, extra_meta: Optional[Dict] = None) -> None:
    tree = {"complex": server.complex}
    if server.simple_host is not None:
        tree["simple_host"] = server.simple_host
    meta = {"round": server.round, **(extra_meta or {})}
    save_tree(path, tree, meta)


def restore_server(path: str, server):
    from repro.core.federated import ServerState
    like = {"complex": server.complex}
    if server.simple_host is not None:
        like["simple_host"] = server.simple_host
    tree, meta = restore_tree(path, like)
    return ServerState(complex=tree["complex"],
                       simple_host=tree.get("simple_host"),
                       round=int(meta.get("round", 0)))
