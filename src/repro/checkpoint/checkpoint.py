"""Pytree checkpointing (npz-based, no external deps).

Round-resumable federated state: ``save_server`` / ``restore_server`` wrap
the complex tree (+ optional decouple simple host) with the round counter,
so ``launch/train.py`` can resume mid-run.

``save_server_flat`` / ``restore_server_flat`` are the flat-buffer path:
each model is ONE contiguous vector packed through the trainer's static
``core.flatten.FlatLayout`` and encoded by the SAME wire encoder the
communication path uses (``core/comm.py``) — an f32 wire round-trips
exactly; bf16/int8 wires make the checkpoint as lossy as the broadcast
already is, at the matching size reduction.  The layout is rebuildable
from the treedef alone (offsets are a pure function of treedef + shapes +
block_n), so a flat checkpoint needs no per-leaf key schema.

``save_trainer`` / ``restore_trainer`` wrap either format with the
population-scale state a resumable run needs beyond the server tree: the
cohort sampler's identity facts (validated on restore — the sampler is
pure in ``(seed, round)``, so no RNG stream is saved) and the per-client
state matrix (``core/client_state.py``) as a sidecar array.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any
_SEP = "/"


def _flatten_with_paths(tree: Tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def _savez_exact(path: str, arrays: Dict[str, np.ndarray]) -> None:
    """``np.savez`` at the VERBATIM path.  Called with a filename, savez
    appends '.npz' when missing — which silently breaks resume (the saver
    writes ``run.ckpt.npz`` while the restore guard stats ``run.ckpt``).
    An open file handle bypasses the renaming."""
    with open(path, "wb") as f:
        np.savez(f, **arrays)


def save_tree(path: str, tree: Tree, metadata: Optional[Dict] = None,
              extra_arrays: Optional[Dict[str, np.ndarray]] = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten_with_paths(tree)
    # bf16 isn't npz-native: stash as uint16 view + dtype tag
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            arrays[k] = v.view(np.uint16)
            dtypes[k] = "bfloat16"
        else:
            arrays[k] = v
            dtypes[k] = str(v.dtype)
    arrays["__dtypes__"] = np.frombuffer(
        json.dumps(dtypes).encode(), dtype=np.uint8)
    if metadata is not None:
        arrays["__meta__"] = np.frombuffer(
            json.dumps(metadata).encode(), dtype=np.uint8)
    # sidecar arrays (dunder-named by convention, e.g. the per-client
    # state matrix): stored verbatim next to the tree leaves; restore_tree
    # ignores keys it was not asked for, so readers opt in
    arrays.update(extra_arrays or {})
    _savez_exact(path, arrays)


def restore_tree(path: str, like: Tree) -> Tuple[Tree, Dict]:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    with np.load(path) as data:
        dtypes = json.loads(bytes(data["__dtypes__"]).decode())
        meta = (json.loads(bytes(data["__meta__"]).decode())
                if "__meta__" in data else {})
        flat_like = _flatten_with_paths(like)
        restored = {}
        for k, ref in flat_like.items():
            if k not in data:
                raise KeyError(f"checkpoint missing leaf {k}")
            v = data[k]
            if dtypes.get(k) == "bfloat16":
                v = v.view(jnp.bfloat16)
            if tuple(v.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch at {k}: {v.shape} vs {ref.shape}")
            restored[k] = jnp.asarray(v)
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)
    keys = [_SEP.join(_path_str(p) for p in path)
            for path, _ in leaves_paths[0]]
    return jax.tree_util.tree_unflatten(
        leaves_paths[1], [restored[k] for k in keys]), meta


def save_server(path: str, server, extra_meta: Optional[Dict] = None,
                extra_arrays: Optional[Dict[str, np.ndarray]] = None) -> None:
    tree = {"complex": server.complex}
    if server.simple_host is not None:
        tree["simple_host"] = server.simple_host
    meta = {"round": server.round, **(extra_meta or {})}
    save_tree(path, tree, meta, extra_arrays=extra_arrays)


def restore_server(path: str, server):
    from repro.core.federated import ServerState
    like = {"complex": server.complex}
    if server.simple_host is not None:
        like["simple_host"] = server.simple_host
    tree, meta = restore_tree(path, like)
    return ServerState(complex=tree["complex"],
                       simple_host=tree.get("simple_host"),
                       round=int(meta.get("round", 0)))


# ---------------------------------------------------------------------------
# Flat-buffer checkpoints (one packed vector per model, wire-encoded)
# ---------------------------------------------------------------------------

def _store_payload(arrays: Dict, name: str, payload: np.ndarray) -> None:
    if payload.dtype == jnp.bfloat16:      # npz can't hold bf16 natively
        arrays[name] = payload.view(np.uint16)
    else:
        arrays[name] = payload


def save_server_flat(path: str, server, layout, *, wire=None,
                     extra_meta: Optional[Dict] = None,
                     extra_arrays: Optional[Dict[str, np.ndarray]] = None
                     ) -> None:
    """Save the server state as wire-encoded flat buffers.

    ``layout`` is the trainer's static ``FlatLayout``; ``wire`` a
    ``core.comm.WireSpec`` (default f32 = lossless).
    """
    from repro.core import comm
    spec = wire if wire is not None else comm.WireSpec()
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    arrays: Dict[str, np.ndarray] = {}
    parts = {"complex": server.complex}
    if server.simple_host is not None:
        parts["simple_host"] = server.simple_host
    for name, tree in parts.items():
        buf = comm.encode_tree(spec, layout, tree)
        _store_payload(arrays, f"{name}.payload", np.asarray(buf.payload))
        if buf.scales is not None:
            arrays[f"{name}.scales"] = np.asarray(buf.scales)
    meta = {"round": server.round, "wire_dtype": spec.dtype,
            "quant_block": spec.quant_block, "n_flat": layout.n_flat,
            "layout_sig": layout.signature,
            "parts": sorted(parts), **(extra_meta or {})}
    arrays["__meta__"] = np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8)
    arrays.update(extra_arrays or {})
    _savez_exact(path, arrays)


def restore_server_flat(path: str, server, layout):
    """Restore a ``save_server_flat`` checkpoint into ``server``'s
    structure (the layout must match the one it was saved with)."""
    from repro.core import comm
    from repro.core.federated import ServerState
    with np.load(path) as data:
        meta = json.loads(bytes(data["__meta__"]).decode())
        if int(meta["n_flat"]) != layout.n_flat:
            raise ValueError(f"layout mismatch: checkpoint n_flat="
                             f"{meta['n_flat']} vs {layout.n_flat}")
        # n_flat collides easily (rounded up to block_n) — the slot-table
        # fingerprint is what actually proves the offsets line up
        if meta["layout_sig"] != layout.signature:
            raise ValueError(f"layout mismatch: checkpoint slot table "
                             f"{meta['layout_sig']} vs {layout.signature} "
                             f"(same n_flat, different packing)")
        spec = comm.WireSpec(meta["wire_dtype"], int(meta["quant_block"]))
        trees = {}
        for name in meta["parts"]:
            payload = data[f"{name}.payload"]
            if spec.dtype == "bfloat16":
                payload = payload.view(jnp.bfloat16)
            scales = (jnp.asarray(data[f"{name}.scales"])
                      if f"{name}.scales" in data else None)
            trees[name] = comm.decode_tree(
                spec, layout, comm.WireBuffer(jnp.asarray(payload), scales))
    if ("simple_host" in trees) != (server.simple_host is not None):
        raise ValueError("checkpoint simple_host presence does not match "
                         "the trainer's algorithm")
    return ServerState(complex=trees["complex"],
                       simple_host=trees.get("simple_host"),
                       round=int(meta.get("round", 0)))


# ---------------------------------------------------------------------------
# Trainer checkpoints (server state + sampler identity + client state)
# ---------------------------------------------------------------------------

_CLIENT_STATE_KEY = "__client_state__"
_CV_STORE_KEY = "__cv_store__"
_CV_GLOBAL_KEY = "__cv_global__"
_EF_STORE_KEY = "__ef_store__"


def save_trainer(path: str, trainer, *, fmt: str = "tree") -> None:
    """Save a ``FederatedTrainer``'s full resumable state.

    On top of the server tree (``fmt="tree"``) or wire-encoded flat
    buffers (``fmt="flat"``), the checkpoint carries:

    * the cohort sampler's identity facts (seed/mode/geometry) in meta —
      the sampler is pure in ``(seed, round)``, so restoring the round
      counter restores the cohort sequence; the facts exist so restore
      can FAIL LOUDLY if the resuming config would draw different cohorts;
    * the per-client state matrix (participation counters, version tags,
      reserved columns) as a ``__client_state__`` sidecar array + its
      column schema in meta, restored by name for schema compatibility;
    * under ``variance_reduction="scaffold"``, the full control-variate
      store (``__cv_store__``, the ``(N, n_flat)`` per-client rows) and
      the server control variate (``__cv_global__``) — SCAFFOLD's state
      is part of the optimizer, so a resume that dropped it would change
      the trajectory.  Both are raw f32 in every checkpoint format.
    * under ``error_feedback=True``, the per-client wire-compression
      residual store (``__ef_store__``, same ``(N, n_flat)`` shape as the
      control variates) — the residuals ARE the compression error the
      clients still owe the server, so a resume that dropped them would
      silently discard un-uploaded signal.  Raw f32 in every format.
    """
    extra_meta = {
        "sampler": trainer.sampler.state_dict(),
        "client_state_columns": list(trainer.client_state.columns),
        "variance_reduction": trainer.fed.variance_reduction,
        "error_feedback": trainer.fed.error_feedback,
    }
    extra_arrays = {
        _CLIENT_STATE_KEY: np.asarray(trainer.client_state.array),
    }
    if trainer.cv_store is not None:
        extra_arrays[_CV_STORE_KEY] = trainer.cv_store.to_array()
        extra_arrays[_CV_GLOBAL_KEY] = np.asarray(trainer.cv_global)
    if trainer.ef_store is not None:
        extra_arrays[_EF_STORE_KEY] = trainer.ef_store.to_array()
    if fmt == "flat":
        save_server_flat(path, trainer.server, trainer.layout,
                         wire=trainer.wire, extra_meta=extra_meta,
                         extra_arrays=extra_arrays)
    elif fmt == "tree":
        save_server(path, trainer.server, extra_meta=extra_meta,
                    extra_arrays=extra_arrays)
    else:
        raise ValueError(f"unknown checkpoint format {fmt!r}")


def restore_trainer(path: str, trainer, *, fmt: str = "tree") -> None:
    """Restore ``save_trainer`` state in place (sets ``trainer.server``,
    validates the sampler facts, reloads the client-state matrix).

    Also accepts plain ``save_server``/``save_server_flat`` checkpoints
    (pre-trainer-checkpoint runs): absent sampler meta validates
    trivially and an absent client-state sidecar leaves the fresh matrix
    in place.
    """
    if fmt == "flat":
        trainer.server = restore_server_flat(path, trainer.server,
                                             trainer.layout)
    elif fmt == "tree":
        trainer.server = restore_server(path, trainer.server)
    else:
        raise ValueError(f"unknown checkpoint format {fmt!r}")
    with np.load(path) as data:
        meta = (json.loads(bytes(data["__meta__"]).decode())
                if "__meta__" in data else {})
        trainer.sampler.validate_state(meta.get("sampler"))
        if _CLIENT_STATE_KEY in data:
            trainer.client_state.load(
                data[_CLIENT_STATE_KEY],
                meta.get("client_state_columns",
                         list(trainer.client_state.columns)))
        if trainer.cv_store is not None:
            if _CV_STORE_KEY in data:
                trainer.cv_store.load(data[_CV_STORE_KEY])
                trainer.cv_global = jnp.asarray(data[_CV_GLOBAL_KEY])
            else:
                raise ValueError(
                    "trainer has variance_reduction='scaffold' but the "
                    "checkpoint carries no __cv_store__ sidecar (saved "
                    f"with variance_reduction="
                    f"{meta.get('variance_reduction', 'none')!r}); "
                    "resuming would silently reset the control variates")
        if trainer.ef_store is not None:
            if _EF_STORE_KEY in data:
                trainer.ef_store.load(data[_EF_STORE_KEY])
            else:
                raise ValueError(
                    "trainer has error_feedback=True but the checkpoint "
                    "carries no __ef_store__ sidecar (saved with "
                    f"error_feedback="
                    f"{meta.get('error_feedback', False)!r}); resuming "
                    "would silently drop the clients' compression "
                    "residuals")
