"""Static flat-buffer packing layout for the aggregation hot path.

The FedHeN server fold is a masked reduction over *every* parameter of a
cohort chunk — the pytree structure is irrelevant to the math.  PR 2's
streaming engine still paid the tree tax: one ``masked_agg`` launch per
leaf (~dozens per fold), per-leaf ``block_n`` padding waste on small
bias/norm leaves, and a mask re-broadcast inside every scan iteration.

``FlatLayout`` removes all of it.  It is computed **once per trainer** from
the complex model's treedef + leaf shapes (all static), and assigns every
leaf a contiguous, lane-aligned slice of one flat vector:

* ``pack_stacked`` packs a trained chunk (Z stacked client models) into a
  single ``(Z, n_flat)`` buffer — padding regions are zero, so they can
  never contribute to a weighted sum;
* ``pack_mask`` lowers the index-set-M mask tree to one precomputed flat
  bitvector (padding = False — irrelevant, the padded inputs are zero);
* ``unpack`` restores the original tree from a flat vector at finalize.

The layout contract: offsets are a pure function of (treedef, leaf shapes,
align, total_multiple), so a layout built at ``__init__`` stays valid for
every round, checkpoint restore, and donated buffer of that trainer.
Summation order over the cohort axis is unchanged (the kernel reduces Z
identically per lane); summation *within* a leaf never happens, so flat
vs tree results differ only by float non-associativity across kernel tile
boundaries — in practice bit-identical per element.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Tree = Any

LANES = 128  # TPU lane width: per-leaf alignment keeps every slice tiled


class LeafSlot(NamedTuple):
    """Where one leaf lives inside the flat buffer (all static ints)."""
    offset: int          # start element in the flat vector
    size: int            # true element count (prod(shape))
    padded: int          # size rounded up to the lane alignment
    shape: Tuple[int, ...]
    dtype: Any           # jnp dtype of the source leaf


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Static packing plan: one slot per leaf, lane-aligned, fixed total."""
    treedef: Any
    slots: Tuple[LeafSlot, ...]
    n_flat: int          # total flat length (multiple of ``total_multiple``)
    align: int
    total_multiple: int

    @property
    def n_leaves(self) -> int:
        return len(self.slots)

    @property
    def n_params(self) -> int:
        """True parameter count (excludes alignment padding)."""
        return sum(s.size for s in self.slots)

    @property
    def signature(self) -> str:
        """Stable fingerprint of the packing plan (slot offsets, shapes,
        dtypes).  Two different layouts frequently collide on ``n_flat``
        (it is rounded up to ``total_multiple``), so consumers that
        persist flat buffers (checkpoint restore) must compare this, not
        just the length, before unpacking."""
        desc = repr([(s.offset, s.size, s.padded, s.shape,
                      str(jnp.dtype(s.dtype))) for s in self.slots])
        return hashlib.sha1(desc.encode()).hexdigest()[:16]

    def stream_bytes(self, dtype=jnp.float32, *, quant_block: int = 0) -> int:
        """Bytes one packed client occupies at the given stream dtype.

        For an int8 wire (``quant_block > 0``) the buffer carries an f32
        scale sidecar of one scale per ``quant_block`` elements — auto
        chunking must budget for it, not just the payload."""
        n = self.n_flat * jnp.dtype(dtype).itemsize
        if quant_block and jnp.dtype(dtype) == jnp.int8:
            n += (self.n_flat // quant_block) * 4
        return n


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m if m > 1 else n


def build_layout(tree: Tree, *, align: int = LANES,
                 total_multiple: int = 0) -> FlatLayout:
    """Assign every leaf of ``tree`` an aligned slice of one flat vector.

    Args:
      tree: any pytree of arrays (or ``ShapeDtypeStruct``s) — only static
        shapes/dtypes are read, never values.
      align: per-slot alignment in elements (default: the 128-lane TPU
        width, so every slot starts on a lane boundary).
      total_multiple: additionally round the total length up to this (use
        the kernel's ``block_n``) so the packed buffer needs no call-time
        padding and the accumulator can alias in place.

    Returns: a :class:`FlatLayout` whose offsets are a pure function of
    (treedef, leaf shapes, align, total_multiple) — build it once, reuse
    it for every round, checkpoint and wire exchange of that model.
    """
    leaves, treedef = jax.tree.flatten(tree)
    slots = []
    offset = 0
    for x in leaves:
        size = 1
        for d in x.shape:
            size *= d
        padded = _round_up(size, align)
        slots.append(LeafSlot(offset, size, padded, tuple(x.shape), x.dtype))
        offset += padded
    n_flat = _round_up(offset, max(total_multiple, 1))
    n_flat = max(n_flat, max(total_multiple, align, 1))
    return FlatLayout(treedef=treedef, slots=tuple(slots), n_flat=n_flat,
                      align=align, total_multiple=total_multiple)


_LAYOUT_CACHE: Dict[Any, FlatLayout] = {}


def layout_of(tree: Tree, *, align: int = LANES,
              total_multiple: int = 0, stacked: bool = False) -> FlatLayout:
    """Cached ``build_layout`` keyed on the static (treedef, shapes) sig.

    ``stacked=True`` strips the leading cohort axis from every leaf first
    (build a layout for one client from a stacked chunk)."""
    leaves, treedef = jax.tree.flatten(tree)
    if stacked:
        leaves = [jax.ShapeDtypeStruct(x.shape[1:], x.dtype) for x in leaves]
        tree = jax.tree.unflatten(treedef, leaves)
    key = (treedef, tuple((x.shape, str(jnp.dtype(x.dtype))) for x in leaves),
           align, total_multiple)
    hit = _LAYOUT_CACHE.get(key)
    if hit is None:
        hit = build_layout(tree, align=align, total_multiple=total_multiple)
        _LAYOUT_CACHE[key] = hit
    return hit


# ---------------------------------------------------------------------------
# Pack / unpack
# ---------------------------------------------------------------------------

def pack_stacked(layout: FlatLayout, tree: Tree, *,
                 dtype=jnp.float32) -> jax.Array:
    """Pack a stacked tree into one contiguous per-client buffer.

    Args:
      layout: the static packing plan (built for ONE client — no cohort
        axis).
      tree: tree with leaves ``(Z, *slot.shape)`` — ``Z`` stacked client
        models sharing the layout's treedef.
      dtype: buffer dtype (``bfloat16`` halves fold read traffic;
        accumulation downstream stays f32).

    Returns: one ``(Z, layout.n_flat)`` buffer.  Alignment padding is
    zero-filled, so padded lanes contribute exactly 0 to any weighted sum
    over the buffer.
    """
    leaves = jax.tree.flatten(tree)[0]
    z = leaves[0].shape[0]
    parts = []
    for x, slot in zip(leaves, layout.slots):
        body = x.reshape(z, slot.size).astype(dtype)
        if slot.padded != slot.size:
            body = jnp.pad(body, ((0, 0), (0, slot.padded - slot.size)))
        parts.append(body)
    used = sum(s.padded for s in layout.slots)
    if layout.n_flat != used:
        parts.append(jnp.zeros((z, layout.n_flat - used), dtype))
    return jnp.concatenate(parts, axis=1)


def pack(layout: FlatLayout, tree: Tree, *, dtype=jnp.float32) -> jax.Array:
    """Pack ONE (unstacked) model tree into a ``(n_flat,)`` vector.

    The single-model form of :func:`pack_stacked` (same zero-padding
    contract) — the unit the wire encoder, the checkpoint writer and the
    async engine's version buffer all operate on."""
    stacked = jax.tree.map(lambda x: x[None], tree)
    return pack_stacked(layout, stacked, dtype=dtype)[0]


def unpack(layout: FlatLayout, flat: jax.Array, *, cast: bool = True) -> Tree:
    """Inverse of :func:`pack`: restore the tree from one flat vector.

    Args:
      layout: the packing plan the vector was produced with.
      flat: ``(n_flat,)`` vector (alignment padding present but ignored).
      cast: cast each leaf back to its slot dtype (else leaves keep
        ``flat.dtype`` — the finalize path casts once at the end instead).

    Returns: a tree with the layout's treedef and leaf ``shape``s.
    """
    leaves = []
    for slot in layout.slots:
        x = jax.lax.dynamic_slice_in_dim(flat, slot.offset, slot.size)
        x = x.reshape(slot.shape)
        leaves.append(x.astype(slot.dtype) if cast else x)
    return jax.tree.unflatten(layout.treedef, leaves)


def unpack_stacked(layout: FlatLayout, flat: jax.Array, *,
                   cast: bool = True) -> Tree:
    """Inverse of :func:`pack_stacked`: ``(V, n_flat)`` -> stacked tree.

    Args:
      layout: the packing plan (per-row; the leading axis is untouched).
      flat: ``(V, n_flat)`` buffer — ``V`` packed models (e.g. the async
        engine's version-tagged broadcast stack).
      cast: cast leaves back to their slot dtypes.

    Returns: a tree whose leaves are ``(V, *slot.shape)`` — index the
    leading axis to recover one model (the async round scan does this with
    ``lax.dynamic_index_in_dim`` per chunk).
    """
    leaves = []
    v = flat.shape[0]
    for slot in layout.slots:
        x = jax.lax.dynamic_slice_in_dim(flat, slot.offset, slot.size,
                                         axis=1)
        x = x.reshape((v,) + slot.shape)
        leaves.append(x.astype(slot.dtype) if cast else x)
    return jax.tree.unflatten(layout.treedef, leaves)


def pack_mask(layout: FlatLayout, mask_tree: Tree) -> jax.Array:
    """Lower the index-set-M mask tree to one flat bool bitvector.

    Args:
      layout: the packing plan of the model the mask describes.
      mask_tree: same treedef as the model; each leaf broadcastable to its
        slot's ``shape`` (scalars mark a whole leaf in/out of M).

    Returns: ``(n_flat,)`` bool vector, precomputed once per trainer and
    passed into the round jit as an argument.  Padding lanes are False;
    since packed inputs are zero there, the choice cannot affect the
    aggregate."""
    leaves = jax.tree.flatten(mask_tree)[0]
    parts = []
    for m, slot in zip(leaves, layout.slots):
        flat = jnp.broadcast_to(jnp.asarray(m), slot.shape).reshape(-1)
        if slot.padded != slot.size:
            flat = jnp.pad(flat, (0, slot.padded - slot.size))
        parts.append(flat)
    used = sum(s.padded for s in layout.slots)
    if layout.n_flat != used:
        parts.append(jnp.zeros((layout.n_flat - used,), bool))
    return jnp.concatenate(parts)


# ---------------------------------------------------------------------------
# Memory-budget chunk heuristic (ROADMAP: chunk-size autotuning)
# ---------------------------------------------------------------------------

# A training client's round working set is roughly this many copies of its
# packed parameter vector: params + grads + SGD update temps + activation
# slack — all in f32 regardless of the fold's streaming dtype — plus ONE
# fold/stream buffer copy that does scale with ``agg_stream_dtype``.
# Deliberately conservative; the budget knob
# (FedConfig.agg_memory_budget_mb) is the tuning surface.
CLIENT_FOOTPRINT_MULTIPLIER = 6.0


def auto_cohort_chunk(layout: FlatLayout, *, budget_bytes: float, k: int,
                      stream_dtype=jnp.float32, quant_block: int = 0,
                      multiplier: float = CLIENT_FOOTPRINT_MULTIPLIER) -> int:
    """Largest chunk whose per-client footprint x chunk fits the budget.

    ``chunk = clamp(budget / per_client, 1, k)`` — the ROADMAP autotuning
    rule: per-client footprint x chunk <= HBM headroom.  Only the one
    stream-buffer copy shrinks with a narrower ``stream_dtype``; the other
    ``multiplier - 1`` copies (params, grads, update temps, activations)
    stay f32, so bf16 streaming must not halve the whole estimate.  An
    int8 wire's scale sidecar (``quant_block``) is part of the stream copy.
    """
    per_client = (layout.stream_bytes(jnp.float32) * (multiplier - 1.0)
                  + layout.stream_bytes(stream_dtype,
                                        quant_block=quant_block))
    chunk = int(budget_bytes // max(per_client, 1.0))
    return max(1, min(chunk, max(k, 1)))
