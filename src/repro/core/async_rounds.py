"""Asynchronous round engine: bounded-lag chunk streaming with
staleness-weighted folds (FedAsync/FedBuff semantics over the flat-buffer
stack).

FedHeN trains devices of different complexities jointly, which makes
stragglers structural: the big-architecture cohort members gate the round
clock for everyone.  This module removes that gate.  The synchronous
engine (``core/federated.py``) broadcasts the round's server params, scans
the cohort chunk by chunk, and only publishes a new model once *every*
chunk has folded — so the slowest chunk sets the round period.  The async
engine lets chunk training **overlap the server fold across rounds**:

**Bounded-lag contract.**  Let ``F`` be the number of chunk folds per
round (simple chunks first, then complex — the same stream order as the
synchronous scan) and ``t`` a chunk's position in that stream.  With
``FedConfig.async_lag = L``, chunk ``t`` of round ``r`` trains on the
server params published at global fold ``r*F + t - L`` — the newest
*round* model available at that fold time.  Concretely the chunk's
broadcast is ``staleness = ceil((L - t) / F)`` rounds old (clamped to
``[0, r]``): the first ``L`` chunks of every round started training
before the previous round's fold finished, so they carry a one-round-
(or more-)stale, version-tagged broadcast.  ``L = 0`` makes every chunk
train on the fresh round broadcast — **bit-for-bit the synchronous
engine** (the parity oracle, test- and CI-enforced).

**Version-tagged broadcasts.**  The engine keeps the last
``ceil(L / F) + 1`` published server models as one stacked ``(V, n_flat)``
flat buffer (``core.flatten.pack``), rolled once per round.  Inside the
round jit the whole stack crosses the wire once
(``comm.encode``/``decode`` batched over ``V`` — identical bits to the
synchronous ``broadcast_roundtrip`` per version) and each chunk selects
its version with one ``lax.dynamic_index_in_dim``.  Download accounting
is version-aware: each client's last-fetched version tag lives in the
trainer's per-client state matrix (``core.client_state``, the
``version_tag`` column) and one vectorized tag-compare per round bills
only the clients whose chunk trains on a version they do not hold —
billing-identical to the retired per-client ``comm.VersionCache`` dict
(parity-tested), but O(cohort) with no O(N_clients) host dict.  So
measured bytes stay truthful under stale-broadcast reuse at any
population size.

**Staleness-weighted folds.**  A stale upload moved away from a model the
server has since replaced; folding it at full weight drags the average
backwards.  Uploads are folded with the FedAsync polynomial decay
``w = 1 / (1 + s)^a`` (``s`` = staleness in rounds,
``a = FedConfig.async_decay``; ``FedConfig.async_staleness = "none"``
disables it).  The coefficient multiplies the client's validity weight
and enters ``aggregate.streaming_fold`` through the exact same masked
weight path as NaN-device/padding exclusion — no second aggregation code
path, and weight-0 devices stay gated before the multiply on every
backend.  Fresh chunks (``s = 0``) fold at weight exactly 1.0, which is
why the ``L = 0`` parity is bit-exact rather than merely close.

The engine SHARES the synchronous machinery rather than mirroring it:
the same ``make_client_trainer``, the same ``aggregate.make_engine`` fold
triple (flat or tree, any wire — int8 uploads still fold through the
dequantizing ``masked_agg`` accumulate), and the ONE chunk-stream scan
``federated.stream_population`` (the async extras — per-chunk version
index and staleness coefficient — are optional arguments of that shared
scan, so the two engines cannot drift).  Chunk padding with weight-0
clients and per-client RNG derivation are therefore identical by
construction: a round's result at a given schedule is invariant to
chunking up to float summation order, exactly like the synchronous
engine.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregate, comm, federated, flatten

STALENESS_SCHEMES = ("poly", "none")


def staleness_weight(staleness, *, scheme: str = "poly",
                     decay: float = 0.5) -> jax.Array:
    """Fold coefficient for an upload that trained on a stale broadcast.

    Args:
      staleness: scalar or array of staleness values ``s`` (broadcast
        versions behind the current one, in rounds; 0 = fresh).
      scheme: ``"poly"`` — the FedAsync polynomial decay
        ``1 / (1 + s)^decay``; ``"none"`` — constant 1 (staleness
        ignored).
      decay: the polynomial exponent ``a`` (>= 0).

    Returns: f32 weights of ``staleness``'s shape, exactly 1.0 at
    ``s = 0`` for every scheme (the bit-for-bit lag=0 parity relies on
    this).
    """
    s = jnp.asarray(staleness, jnp.float32)
    if scheme == "none":
        return jnp.ones_like(s)
    if scheme == "poly":
        return (1.0 + s) ** jnp.float32(-decay)
    raise ValueError(f"unknown staleness scheme {scheme!r} "
                     f"(one of {STALENESS_SCHEMES})")


def fold_schedule(n_folds: int, lag: int, round_index: int) -> np.ndarray:
    """Per-chunk broadcast staleness of one round's fold stream.

    Args:
      n_folds: chunk folds per round ``F`` (simple + complex populations).
      lag: ``FedConfig.async_lag`` — folds of bounded staleness ``L``.
      round_index: the round ``r`` being scheduled (clamps staleness so no
        chunk can train on a pre-initialization model).

    Returns: int array of shape ``(n_folds,)``: position ``t`` trains on
    the round broadcast published ``ceil((L - t) / F)`` rounds ago,
    clamped to ``[0, round_index]``.  All zeros when ``lag = 0``.
    """
    t = np.arange(n_folds)
    d = -((t - lag) // n_folds)          # ceil((lag - t) / n_folds)
    return np.minimum(np.maximum(d, 0), round_index)


class AsyncRoundEngine:
    """Drives asynchronous rounds for a :class:`~repro.core.federated.
    FederatedTrainer` (which delegates ``run_round`` here when
    ``FedConfig.async_lag > 0``).

    The engine owns the version stack, the staleness schedule, the async
    round jit, and the version-aware byte accounting; server state still
    lives on the trainer, so checkpointing and evaluation are unchanged.
    Construct directly with an explicit ``lag`` to run the async code
    path at a lag the trainer's config would not choose — the lag=0
    parity tests and the CI benchmark gate do exactly that.
    """

    def __init__(self, trainer, *, lag: Optional[int] = None,
                 scheme: Optional[str] = None,
                 decay: Optional[float] = None):
        fed = trainer.fed
        self.trainer = trainer
        self.lag = fed.async_lag if lag is None else lag
        self.scheme = fed.async_staleness if scheme is None else scheme
        self.decay = fed.async_decay if decay is None else decay
        if self.lag < 0:
            raise ValueError(f"lag must be >= 0, got {self.lag}")
        if self.scheme not in STALENESS_SCHEMES:
            raise ValueError(f"unknown staleness scheme {self.scheme!r}")
        self.algo = fed.algorithm
        self.layout = trainer.layout
        self.wire = trainer.wire
        # static chunk geometry — the synchronous scan's exact rule
        self.chunk_s, self.n_chunks_s = federated.chunk_geometry(
            trainer.k_simple, trainer.cohort_chunk)
        self.chunk_c, self.n_chunks_c = federated.chunk_geometry(
            trainer.k_complex, trainer.cohort_chunk)
        self.folds_per_round = self.n_chunks_s + self.n_chunks_c
        # version stack depth: deepest offset any chunk can reach, plus
        # the fresh slot — static, so the round jit never retraces
        self.n_versions = -(-self.lag // self.folds_per_round) + 1
        self._reset_versions()
        # per-client one-way wire cost: the trainer's numbers, not a
        # recomputation — sync and async billing share one source (the
        # upload direction carries the wire-v2 delta payload sizes)
        self._per_simple = trainer.per_simple_bytes
        self._per_complex = trainer.per_complex_bytes
        self._per_simple_up = trainer.per_simple_bytes_up
        self._per_complex_up = trainer.per_complex_bytes_up
        self.last_bytes_down = 0.0
        self.last_bytes_up = 0.0
        donate = (0, 1) if jax.default_backend() != "cpu" else ()
        self._round_fn = jax.jit(self._make_round_fn(),
                                 donate_argnums=donate)
        # telemetry rides the trainer's registry (one event stream per
        # run); the dispatch adds the compile/execute split when enabled
        self._dispatch = federated.RoundDispatch(trainer.obs,
                                                 self._round_fn)

    # -- version stack -------------------------------------------------------

    def _reset_versions(self):
        """(Re)seed the version stack and download ledger from the
        trainer's CURRENT server state.

        Called at construction and whenever ``trainer.server`` is
        replaced from outside the engine (checkpoint restore in
        ``launch/train.py --resume``): the replaced state's history is
        unknown, so every slot becomes the current model — the same
        pre-history semantics a fresh engine starts with — and the
        clients' cached version tags are wiped (they referred to the
        discarded history)."""
        tr = self.trainer
        flat = flatten.pack(self.layout, tr.server.complex)
        self.versions = jnp.tile(flat[None], (self.n_versions, 1))
        self.versions_host = None
        if self.algo == "decouple":
            host = flatten.pack(self.layout, tr.server.simple_host)
            self.versions_host = jnp.tile(host[None], (self.n_versions, 1))
        tr.client_state.reset_version_tags()
        # cumulative billing tallies (the retired VersionCache dict's
        # counts, now engine-owned); telemetry emits per-round deltas, so
        # also remember where the last round left off
        self.cache_hits = 0
        self.cache_misses = 0
        self._seen_cache_counts = (0, 0)
        self._published_server = tr.server

    # -- schedule ------------------------------------------------------------

    def schedule(self, round_index: int) -> Tuple[np.ndarray, np.ndarray]:
        """(staleness_simple, staleness_complex) for one round — the fold
        stream split back into the two population scans."""
        s_all = fold_schedule(self.folds_per_round, self.lag, round_index)
        return s_all[:self.n_chunks_s], s_all[self.n_chunks_s:]

    # -- the jitted async round ----------------------------------------------

    def _make_round_fn(self):
        tr = self.trainer
        adapter, fed = tr.adapter, tr.fed
        algo = self.algo
        scaffold_on = fed.variance_reduction == "scaffold"
        cv_layout = self.layout if scaffold_on else None
        train_simple = federated.make_client_trainer(adapter.loss_simple,
                                                     fed, cv_layout=cv_layout)
        complex_loss = (adapter.loss_side if algo == "fedhen"
                        else adapter.loss_complex)
        train_complex = federated.make_client_trainer(complex_loss, fed,
                                                      cv_layout=cv_layout)
        layout, wire = self.layout, self.wire
        k_simple, k_complex = tr.k_simple, tr.k_complex
        # finalize only reads dtypes from the template — static structs
        # keep the server tree out of the round's argument list
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            tr.server.complex)
        spec = tr.engine_spec

        def make_agg(flat_mask):
            return aggregate.make_engine(spec.bind(flat_mask=flat_mask))

        def decode_versions(versions):
            """(V, n_flat) packed stack -> stacked broadcast trees, each
            version through the same wire trip a synchronous broadcast
            takes (identity wires skip the encode, like the sync path)."""
            if not wire.is_identity:
                versions = comm.decode(wire, comm.encode(wire, versions))
            return flatten.unpack_stacked(layout, versions)

        def version_select(bcasts):
            """``get_src`` for the shared chunk scan: one dynamic index
            into the stacked broadcast trees per chunk."""
            return lambda idx: jax.tree.map(
                lambda x: jax.lax.dynamic_index_in_dim(
                    x, idx, 0, keepdims=False), bcasts)

        delta_mode = wire.uses_deltas
        ef_on = fed.error_feedback
        k_top_s, k_top_c = tr.k_top_simple, tr.k_top_complex

        def round_fn(versions, versions_host, data_s, data_c,
                     rng, flat_mask, idx_s, w_s, idx_c, w_c,
                     real_s=None, real_c=None,
                     cv_global=None, cv_s=None, cv_c=None,
                     ef_s=None, ef_c=None):
            # real_s / real_c: super-cohort slot reality masks (uniform
            # sampling mode only — absent, the traced program is exactly
            # the pre-existing async round).  cv_global / cv_s / cv_c:
            # SCAFFOLD's server control variate and the cohort's gathered
            # store rows — the "none" trace takes none of them.
            # ef_s / ef_c: gathered error-feedback residual rows (wire v2
            # with error_feedback only).  Under lag > 0 the wire-v2 delta
            # is measured vs the chunk's SELECTED STALE broadcast — the
            # model the client really trained from.
            agg_init, agg_fold, agg_finalize = make_agg(flat_mask)
            rs, rc = jax.random.split(rng)
            bcasts_c = decode_versions(versions)
            bcasts_s = (decode_versions(versions_host)
                        if algo == "decouple" else bcasts_c)
            sc_s = sc_c = None
            if scaffold_on:
                # the option-II delta's x is whatever broadcast the chunk
                # trained on — under lag > 0 that is the chunk's SELECTED
                # STALE version (packed from get_src's result inside the
                # shared scan), so dc measures the drift actually taken
                sc_s = federated.ScaffoldCtx(
                    rows=cv_s, c_global=cv_global, pop_mask=flat_mask,
                    layout=layout,
                    inv_k_lr=1.0 / (federated.local_step_count(data_s, fed)
                                    * fed.lr))
                sc_c = federated.ScaffoldCtx(
                    rows=cv_c, c_global=cv_global, pop_mask=None,
                    layout=layout,
                    inv_k_lr=1.0 / (federated.local_step_count(data_c, fed)
                                    * fed.lr))
            up_s = up_c = None
            if delta_mode:
                up_s = federated.WireUploadCtx(wire, layout, k_top_s, ef_s)
                up_c = federated.WireUploadCtx(wire, layout, k_top_c, ef_c)
            state = agg_init(template)
            (state, loss_s, valid_s, rows_s,
             efrows_s) = federated.stream_population(
                state, version_select(bcasts_s), train_simple, data_s, rs,
                agg_fold, k=k_simple, chunk=self.chunk_s,
                n_chunks=self.n_chunks_s, is_simple_flag=True,
                skip_nan=fed.skip_nan_devices,
                version_idx=idx_s, staleness_w=w_s, real_mask=real_s,
                scaffold=sc_s, upload=up_s)
            (state, loss_c, valid_c, rows_c,
             efrows_c) = federated.stream_population(
                state, version_select(bcasts_c), train_complex, data_c, rc,
                agg_fold, k=k_complex, chunk=self.chunk_c,
                n_chunks=self.n_chunks_c, is_simple_flag=False,
                skip_nan=fed.skip_nan_devices,
                version_idx=idx_c, staleness_w=w_c, real_mask=real_c,
                scaffold=sc_c, upload=up_c)
            cv_out = None
            if scaffold_on:
                cv_out = (cv_global + state.cv_acc / float(fed.n_devices),
                          rows_s, rows_c)
            ef_out = (efrows_s, efrows_c) if ef_on else None
            new_complex, new_host = agg_finalize(state, template=template)
            # publish: roll the new round model into the version stack
            new_versions = jnp.concatenate(
                [flatten.pack(layout, new_complex)[None], versions[:-1]],
                axis=0)
            new_versions_host = None
            if algo == "decouple":
                new_versions_host = jnp.concatenate(
                    [flatten.pack(layout, new_host)[None],
                     versions_host[:-1]], axis=0)
            metrics = {"loss_simple": loss_s, "loss_complex": loss_c,
                       "n_valid": valid_s + valid_c}
            return (new_complex, new_host, new_versions,
                    new_versions_host, metrics, cv_out, ef_out)

        return round_fn

    # -- byte accounting (version-aware) -------------------------------------

    def _bill_download(self, plan, s_s, s_c, round_index: int) -> float:
        """Measured download of one round: each real client fetches the
        version its chunk trains on — billed once per (client, version)
        by the vectorized tag-compare on the trainer's client-state
        matrix (``ClientStateMatrix.bill_downloads``), so cached stale
        broadcasts cost 0.  Pad slots (super-cohort routing) wrap real
        clients that already fetched this round, so padding is never
        billed (same contract as the synchronous accounting)."""
        down = 0.0
        for ids, real, staleness, chunk, nbytes in (
                (plan.simple_ids, plan.simple_real, s_s,
                 self.chunk_s, self._per_simple),
                (plan.complex_ids, plan.complex_real, s_c,
                 self.chunk_c, self._per_complex)):
            pos = np.arange(ids.size)
            tags = round_index - np.asarray(staleness)[pos // chunk]
            billed, hits, misses = self.trainer.client_state.bill_downloads(
                ids[real], tags[real], nbytes)
            down += billed
            self.cache_hits += hits
            self.cache_misses += misses
        return float(down)

    # -- public API ----------------------------------------------------------

    def _round_args(self):
        """One round's concrete argument tuple (shared by run/lower)."""
        tr = self.trainer
        if tr.server is not self._published_server:
            # the server state was replaced from outside (checkpoint
            # restore): the version stack must follow it, or every chunk
            # would keep training on the discarded pre-restore broadcast
            self._reset_versions()
        r = tr.server.round
        s_s, s_c = self.schedule(r)
        w_s = staleness_weight(s_s, scheme=self.scheme, decay=self.decay)
        w_c = staleness_weight(s_c, scheme=self.scheme, decay=self.decay)
        plan = tr._sample_plan()
        key = jax.random.PRNGKey(tr.fed.seed * 100003 + r)
        args = (self.versions, self.versions_host,
                tr._gather(plan.simple_ids), tr._gather(plan.complex_ids),
                key, tr._flat_mask_arg(), jnp.asarray(s_s, jnp.int32), w_s,
                jnp.asarray(s_c, jnp.int32), w_c)
        cv = tr._cv_args(plan)
        ef = tr._ef_args(plan)
        if tr.fed.sample_uniform:
            args += (jnp.asarray(plan.simple_real),
                     jnp.asarray(plan.complex_real))
        elif cv or ef:
            args += (None, None)     # skip the real-mask slots positionally
        if ef and not cv:
            cv = (None, None, None)  # skip the cv slots positionally
        return args + cv + ef, (plan, s_s, s_c, r)

    def lower_round(self):
        """AOT-lower the async round jit with this trainer's shapes (the
        async mirror of ``FederatedTrainer.lower_round``; consumes one
        cohort sample)."""
        args, _ = self._round_args()
        return self._round_fn.lower(*args)

    def _emit_async_health(self, s_s, s_c) -> None:
        """Async-specific client health: the round's per-chunk staleness
        histogram (``{staleness: chunk count}`` over the fold stream) and
        the version-cache hit/miss deltas (a hit is a stale broadcast the
        client already held — the reuse the byte accounting credits)."""
        obs = self.trainer.obs
        hist: dict = {}
        for s in list(s_s) + list(s_c):
            hist[int(s)] = hist.get(int(s), 0) + 1
        obs.ledger("staleness_hist",
                   {str(k): v for k, v in sorted(hist.items())})
        seen_h, seen_m = self._seen_cache_counts
        obs.counter("version_cache_hit", self.cache_hits - seen_h)
        obs.counter("version_cache_miss", self.cache_misses - seen_m)
        self._seen_cache_counts = (self.cache_hits, self.cache_misses)

    def run_round(self):
        """One async round: schedule staleness, train + fold the chunk
        stream, publish the new version, update the trainer's server
        state and measured byte totals."""
        tr = self.trainer
        obs = tr.obs
        obs.set_round(tr.server.round)
        with obs.span("round", engine="async", lag=self.lag):
            with obs.span("sample_gather"):
                args, (plan, s_s, s_c, r) = self._round_args()
            (new_complex, new_host, self.versions, self.versions_host,
             metrics, cv_out, ef_out) = self._dispatch(*args)
            if cv_out is not None:
                tr._apply_cv_update(plan, cv_out)
            if ef_out is not None:
                tr._apply_ef_update(plan, ef_out)
            tr.client_state.record_round(plan.real_ids(), r)
            tr.server = federated.ServerState(
                complex=new_complex, simple_host=new_host, round=r + 1)
            self._published_server = tr.server
            down = self._bill_download(plan, s_s, s_c, r)
            # cv exchange: c_global is republished every round (no version
            # to cache), c_i deltas ride the upload — both billed raw f32,
            # the trainer's honest-accounting numbers (0 when off)
            down += float(plan.n_real_simple * tr.per_simple_cv_bytes
                          + plan.n_real_complex * tr.per_complex_cv_bytes)
            up = float(plan.n_real_simple * (self._per_simple_up
                                             + tr.per_simple_cv_bytes)
                       + plan.n_real_complex * (self._per_complex_up
                                                + tr.per_complex_cv_bytes))
            self.last_bytes_down, self.last_bytes_up = down, up
            tr.total_bytes_down += down
            tr.total_bytes_up += up
            tr.total_bytes += down + up
            metrics = {k: float(v) for k, v in metrics.items()}
            if obs.enabled:
                federated.emit_round_phases(obs, populations=[
                    ("simple", tr.k_simple, self.chunk_s,
                     self.n_chunks_s, s_s),
                    ("complex", tr.k_complex, self.chunk_c,
                     self.n_chunks_c, s_c)],
                    bytes_down=down, wire=tr.fed.comm_dtype)
                self._emit_async_health(s_s, s_c)
                tr._emit_round_health(
                    metrics, down=down, up=up,
                    k_real=plan.n_real_simple + plan.n_real_complex)
        return metrics
