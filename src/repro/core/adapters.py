"""Model adapters: bind a concrete architecture to the FedHeN machinery.

An adapter exposes the paper's three client objectives over a *complex*
parameter tree:

* ``loss_complex``            — f_j(w_c)                      (ClientTraining)
* ``loss_simple``             — f_i([w_c]_M)                  (simple devices;
  touches only M-parameters, so its gradient is zero outside M)
* ``loss_side``               — f_j(w_c) + f_j([w_c]_M)       (ClientTrainingSideObj)

plus ``subnet_mask`` (index set M) and evaluation metrics for both heads.
``loss_side`` is computed in ONE forward pass (the subnet is a depth
prefix -> early-exit head), matching the paper's "side objective adds
minimal cost" property.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import masking
from repro.models import common, resnet
from repro.models import transformer as tfm
from repro.models.common import NO_POLICY, Policy

Tree = Any
Batch = Dict[str, jax.Array]


def _ce(logits, labels):
    return common.softmax_cross_entropy(logits, labels)


def _acc(logits, labels):
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))


# ---------------------------------------------------------------------------
# ResNet / CIFAR (the paper's own experimental setting)
# ---------------------------------------------------------------------------

class ResNetAdapter:
    """PreActResNet18-GN complex / 2-stage+mixpool simple (paper §3)."""

    def __init__(self, n_classes: int = 10):
        self.n_classes = n_classes

    def init(self, key) -> Tree:
        return resnet.init_params(key, self.n_classes)

    def subnet_mask(self, params: Tree) -> Tree:
        return masking.resnet_subnet_mask(params)

    def loss_complex(self, params: Tree, batch: Batch) -> jax.Array:
        _, final = resnet.forward(params, batch["images"])
        return _ce(final, batch["labels"])

    def loss_simple(self, params: Tree, batch: Batch) -> jax.Array:
        logits = resnet.forward_simple(params, batch["images"])
        return _ce(logits, batch["labels"])

    def loss_side(self, params: Tree, batch: Batch) -> jax.Array:
        exit_logits, final = resnet.forward(params, batch["images"])
        return _ce(final, batch["labels"]) + _ce(exit_logits, batch["labels"])

    def evaluate(self, params: Tree, batch: Batch) -> Dict[str, jax.Array]:
        exit_logits, final = resnet.forward(params, batch["images"])
        return {"acc_complex": _acc(final, batch["labels"]),
                "acc_simple": _acc(exit_logits, batch["labels"])}


# ---------------------------------------------------------------------------
# Decoder LM zoo
# ---------------------------------------------------------------------------

class LMAdapter:
    """Any ModelConfig from the zoo.  Batch: tokens (B, S+1) [, extra_embeds].

    For multi-codebook (musicgen) tokens are (B, S+1, n_codebooks) and the
    loss averages codebook CEs; for VLM, ``extra_embeds`` are prepended and
    the loss covers text positions only.
    """

    def __init__(self, cfg: ModelConfig, policy: Policy = NO_POLICY,
                 remat: bool = False):
        self.cfg = cfg
        self.policy = policy
        self.remat = remat

    def init(self, key) -> Tree:
        return tfm.init_params(key, self.cfg)

    def subnet_mask(self, params: Tree) -> Tree:
        return masking.transformer_subnet_mask(params, self.cfg)

    # -- loss plumbing -----------------------------------------------------

    def _inputs(self, batch: Batch):
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        extra = batch.get("extra_embeds")
        return inputs, labels, extra

    def _head_loss(self, params, h, labels, extra, head, chunk: int = 256):
        """CE between head logits and labels.

        Long sequences are processed in remat'd chunks so the (B, S, V)
        logits tensor is never materialized at once (the unembedding is
        recomputed per chunk in the backward pass) — essential at
        vocab >= 256k x seq 4k on 16 GB chips.
        """
        if extra is not None:
            # VLM: frontend tokens are prepended; loss on text positions only
            h = h[:, extra.shape[1]:]
        b, s = h.shape[0], h.shape[1]

        if getattr(self.policy, "dp2d", False):
            # 2D data parallel: per-chip batch is ~1, so full-length logits
            # are small per chip AND chunk-scanned CE would pin a tied-
            # embedding grad all-reduce inside the loop (measured
            # 70 GiB/step).  Compute CE in one piece.
            chunk = s

        def chunk_nll_sum(h_c, lab_c):
            logits = tfm.logits_from_hidden(params, self.cfg, h_c, head,
                                            self.policy)
            if self.cfg.n_codebooks > 1:
                per = [common.softmax_cross_entropy_sum(logits[..., c, :],
                                                        lab_c[..., c])
                       for c in range(self.cfg.n_codebooks)]
                return sum(per) / len(per)
            return common.softmax_cross_entropy_sum(logits, lab_c)

        n_tok = b * s
        if s <= 2 * chunk or s % chunk:
            return chunk_nll_sum(h, labels) / n_tok

        nc = s // chunk
        h_c = h.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)
        lab_c = labels.reshape((b, nc, chunk) + labels.shape[2:]
                               ).transpose(1, 0, 2, *range(3, labels.ndim + 1))

        @jax.checkpoint
        def body(acc, xs):
            hc, lc = xs
            return acc + chunk_nll_sum(hc, lc), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                (h_c, lab_c))
        return total / n_tok

    def loss_complex(self, params: Tree, batch: Batch) -> jax.Array:
        inputs, labels, extra = self._inputs(batch)
        _, final_h, aux = tfm.forward(params, self.cfg, inputs,
                                      extra_embeds=extra, policy=self.policy,
                                      remat=self.remat)
        loss = self._head_loss(params, final_h, labels, extra, "final")
        return loss + aux["load_balance"] + aux["router_z"]

    def loss_simple(self, params: Tree, batch: Batch) -> jax.Array:
        inputs, labels, extra = self._inputs(batch)
        exit_h = tfm.forward_simple(params, self.cfg, inputs,
                                    extra_embeds=extra, policy=self.policy,
                                    remat=self.remat)
        return self._head_loss(params, exit_h, labels, extra, "exit")

    def loss_side(self, params: Tree, batch: Batch) -> jax.Array:
        """f(w_c) + f([w_c]_M) — one forward pass, two heads."""
        inputs, labels, extra = self._inputs(batch)
        exit_h, final_h, aux = tfm.forward(params, self.cfg, inputs,
                                           extra_embeds=extra,
                                           policy=self.policy,
                                           remat=self.remat)
        loss = (self._head_loss(params, final_h, labels, extra, "final")
                + self._head_loss(params, exit_h, labels, extra, "exit"))
        return loss + aux["load_balance"] + aux["router_z"]

    def evaluate(self, params: Tree, batch: Batch) -> Dict[str, jax.Array]:
        inputs, labels, extra = self._inputs(batch)
        exit_h, final_h, _ = tfm.forward(params, self.cfg, inputs,
                                         extra_embeds=extra,
                                         policy=self.policy)
        out = {}
        for head, h in (("complex", final_h), ("simple", exit_h)):
            logits = tfm.logits_from_hidden(
                params, self.cfg, h, "final" if head == "complex" else "exit",
                self.policy)
            if extra is not None:
                logits = logits[:, extra.shape[1]:]
            lab = labels[..., 0] if self.cfg.n_codebooks > 1 else labels
            lg = logits[..., 0, :] if self.cfg.n_codebooks > 1 else logits
            out[f"acc_{head}"] = _acc(lg, lab)
            out[f"loss_{head}"] = _ce(lg, lab)
        return out
