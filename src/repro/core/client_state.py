"""Sharded per-client state: one flat ``(N_clients + 1, width)`` matrix.

Per-client bookkeeping used to live in Python dicts
(``comm.VersionCache._held`` most prominently) — fine at 100 clients,
a rewrite at the ROADMAP's "millions of users".  This module moves every
per-client scalar the runtime tracks into ONE flat numpy matrix with a
static column schema (the ``FlatLayout`` idea applied to the client
axis): rounds touch it only through vectorized gather/scatter by the
sampled ids, so per-round host cost is O(cohort) regardless of the
population size (CI-gated flat from 10^3 to 10^6 clients by
``benchmarks/client_scale.py``).

**Column schema** (:data:`COLUMNS`, one f64 column each — exact for
integer counters up to 2^53):

* ``participation`` — rounds this client was sampled in (really
  sampled: pad slots never count).  Feeds the unbiasedness telemetry
  (participation histogram) and, later, importance-weighted sampling.
* ``last_round``    — last round index the client participated in
  (-1 = never).
* ``version_tag``   — the server version tag this client last
  downloaded (-1 = nothing cached).  Replaces the ``VersionCache`` dict
  with one vectorized tag-compare per round (:meth:`bill_downloads`),
  billing-identical to the dict (parity-tested).
* ``cv_scale``     — L2 norm of the client's SCAFFOLD control-variate
  row, written on every state-store scatter
  (:meth:`set_cv_scale`; zero when ``variance_reduction="none"``).
* ``ef_scale``     — L2 norm of the client's wire-compression
  error-feedback residual row, written on every residual-store scatter
  (:meth:`set_ef_scale`; zero when ``error_feedback=False``).

**The sentinel row.**  The matrix has ``N + 1`` rows; row ``N`` is a
scratch row that ids may legally point at when a caller wants a
scatter target that must not alias any real client (pad-slot routing).
Every read path masks it out.

The matrix is **host state** (numpy, updated in place by fancy
indexing): per-round updates touch O(cohort) rows with no O(N) copies —
a device-resident jnp scatter would copy the whole matrix per round on
backends without donation (CPU tier-1).  Round jits that need per-client
columns (SCAFFOLD's control variates) take the O(cohort) ``gather`` of
the sampled rows as an argument and return updated rows to ``scatter``
back — the same in/out contract the cohort data already uses.
Checkpointing ships the raw array + column list
(``checkpoint.save_trainer``), restored by :meth:`load`.
"""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

COLUMNS = ("participation", "last_round", "version_tag",
           "ef_scale", "cv_scale")

_PART = COLUMNS.index("participation")
_LAST = COLUMNS.index("last_round")
_TAG = COLUMNS.index("version_tag")
_CV = COLUMNS.index("cv_scale")
_EF = COLUMNS.index("ef_scale")

NEVER = -1.0          # version_tag / last_round value for "no history"


class ClientStateMatrix:
    """All per-client runtime state as one flat host matrix.

    Mutating methods take *unique* real client ids (one slot per client
    per call — the sampler guarantees it; duplicate ids in one call
    would collapse into one row update, like any scatter).
    """

    def __init__(self, n_clients: int):
        if n_clients <= 0:
            raise ValueError(f"n_clients must be > 0, got {n_clients}")
        self.n_clients = int(n_clients)
        self._m = np.zeros((self.n_clients + 1, len(COLUMNS)), np.float64)
        self._m[:, _LAST] = NEVER
        self._m[:, _TAG] = NEVER

    # -- schema ---------------------------------------------------------------

    @property
    def columns(self) -> Tuple[str, ...]:
        return COLUMNS

    @property
    def sentinel(self) -> int:
        """The scratch row id pad slots may target."""
        return self.n_clients

    @property
    def array(self) -> np.ndarray:
        """The raw ``(N + 1, width)`` matrix (checkpoint payload)."""
        return self._m

    @property
    def nbytes(self) -> int:
        return self._m.nbytes

    def column(self, name: str) -> np.ndarray:
        """One column over the REAL clients (sentinel row excluded)."""
        return self._m[:self.n_clients, COLUMNS.index(name)]

    # -- per-round updates (O(cohort), vectorized) ---------------------------

    def record_round(self, ids: np.ndarray, round_index: int) -> None:
        """Mark ``ids`` (unique, real) as this round's participants."""
        ids = np.asarray(ids, dtype=np.int64)
        self._m[ids, _PART] += 1.0
        self._m[ids, _LAST] = float(round_index)

    def bill_downloads(self, ids: np.ndarray, tags: np.ndarray,
                       nbytes: float) -> Tuple[float, int, int]:
        """Vectorized version-tagged download billing.

        Each client in ``ids`` (unique, real) fetches server version
        ``tags[i]``; a client whose cached ``version_tag`` already
        equals it is a cache *hit* (0 bytes — the stale-broadcast reuse
        the async engine's measured savings come from), anything else a
        *miss* billed ``nbytes`` and recorded.  Semantics are identical
        to ``comm.VersionCache.bill`` called per client (parity-tested);
        cost is one compare + one scatter over O(cohort) rows.

        Returns ``(billed_bytes, hits, misses)``.
        """
        ids = np.asarray(ids, dtype=np.int64)
        tags = np.asarray(tags, dtype=np.float64)
        hit = self._m[ids, _TAG] == tags
        misses = int(ids.size - hit.sum())
        self._m[ids, _TAG] = tags
        return float(misses * nbytes), int(hit.sum()), misses

    def set_cv_scale(self, ids: np.ndarray, norms: np.ndarray) -> None:
        """Record the L2 norm of each updated SCAFFOLD control-variate
        row (core/state_store.py scatter path) — the per-client drift
        signal the participation telemetry reads.  O(cohort)."""
        self._m[np.asarray(ids, dtype=np.int64), _CV] = \
            np.asarray(norms, dtype=np.float64)

    def set_ef_scale(self, ids: np.ndarray, norms: np.ndarray) -> None:
        """Record the L2 norm of each updated error-feedback residual
        row (the wire-compression bookkeeping the ``ef_scale`` column
        was reserved for) — how much compression error each client is
        still carrying.  O(cohort)."""
        self._m[np.asarray(ids, dtype=np.int64), _EF] = \
            np.asarray(norms, dtype=np.float64)

    def reset_version_tags(self) -> None:
        """Forget every client's cached version (checkpoint restore /
        external server replacement: the version history the tags
        referred to is gone)."""
        self._m[:, _TAG] = NEVER

    # -- round-jit seam -------------------------------------------------------

    def gather(self, ids: np.ndarray) -> np.ndarray:
        """The sampled rows ``(k, width)`` — what a round jit consuming
        per-client columns (SCAFFOLD, error feedback) takes as input."""
        return self._m[np.asarray(ids, dtype=np.int64)]

    def scatter(self, ids: np.ndarray, rows: np.ndarray) -> None:
        """Write updated rows back (unique ids; sentinel row allowed —
        it is scratch by contract)."""
        self._m[np.asarray(ids, dtype=np.int64)] = rows

    # -- telemetry ------------------------------------------------------------

    def participation_histogram(self, max_bucket: int = 10) -> Dict[str, int]:
        """``{participation count: n_clients}`` over real clients, counts
        above ``max_bucket`` clamped into the last bucket (``"10+"``).
        O(N) — called only on the telemetry-enabled path."""
        part = np.minimum(self.column("participation").astype(np.int64),
                          max_bucket)
        counts = np.bincount(part, minlength=max_bucket + 1)
        hist = {str(i): int(c) for i, c in enumerate(counts[:-1]) if c}
        if counts[max_bucket]:
            hist[f"{max_bucket}+"] = int(counts[max_bucket])
        return hist

    def tracked_clients(self) -> int:
        """Clients that have participated at least once."""
        return int((self.column("participation") > 0).sum())

    # -- checkpoint integration ----------------------------------------------

    def load(self, array: np.ndarray, columns: Sequence[str]) -> None:
        """Restore from a checkpointed payload.  Columns are matched by
        NAME so a checkpoint written under an older/newer schema restores
        the columns both sides know (unknown new columns keep their
        initialized defaults)."""
        array = np.asarray(array, dtype=np.float64)
        if array.shape[0] != self.n_clients + 1:
            raise ValueError(
                f"client-state size mismatch: checkpoint has "
                f"{array.shape[0] - 1} clients, trainer {self.n_clients}")
        if len(columns) != array.shape[1]:
            raise ValueError(f"column list {list(columns)} does not match "
                             f"payload width {array.shape[1]}")
        for j, name in enumerate(columns):
            if name in COLUMNS:
                self._m[:, COLUMNS.index(name)] = array[:, j]
