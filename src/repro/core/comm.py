"""Quantized flat-buffer communication over ``core.flatten.FlatLayout``.

FedHeN's headline claim is *communication savings*; related systems (FedHe,
HeteroFL) make the savings concrete with reduced-payload exchange.  This
module is the wire layer of that claim: the PR 3 ``(Z, n_flat)`` packed
representation — one contiguous lane-aligned buffer per client — becomes
the unit of both directions of the protocol:

* **broadcast** (server -> client): the server's flat vector is encoded to
  the wire dtype and the client trains on the decoded copy, so the round
  sees the real quantization error;
* **upload** (client -> server): each trained chunk is encoded to the same
  wire format and the fold *dequantizes inside the accumulate* — the
  ``masked_agg`` kernel's ``masked_agg_acc_deq`` variant consumes int8
  payloads + per-group f32 scales directly, so no separate materialized
  f32 copy of the cohort ever exists on the server.

Wire formats (``WireSpec.dtype``):

* ``float32`` — the identity wire (paper accounting; no transform);
* ``bfloat16`` — 2-byte payload, no sidecar;
* ``int8`` — symmetric per-group quantization ``q = round(x / s)``,
  ``s = max|x| / 127`` per contiguous group of ``quant_block`` elements,
  plus an f32 scale sidecar (``ceil(n / quant_block)`` scales).

``quant_block`` must divide the layout's lane alignment (128), so a scale
group never crosses a ``LeafSlot`` boundary: quantization error is bounded
*per slot* by that slot's own magnitudes, alignment-padding groups are
all-zero (scale 0 -> payload 0 -> decode 0), and the CPU fallback can fold
leaf by leaf without changing group boundaries.

Byte accounting is **measured, not estimated**: ``wire_bytes`` runs the
real encoder under ``jax.eval_shape`` and sums the output buffer sizes, so
the trainer's per-round numbers are the encoder's actual output — payload
*and* sidecar — for the true (compact) element counts.  Alignment padding
is a local layout artifact the sender strips (offsets are static on both
ends), so it is never billed to the wire.

**Wire v2 — the compressed upload path.**  Three composable mechanisms
ride the *upload* direction only (the broadcast stays dense and
deterministic); any of them switches uploads from parameters to deltas
``d = y - x`` against the broadcast the client trained on
(``WireSpec.uses_deltas``), leaving every pre-existing configuration's
traced program untouched:

* **top-k sparsification** (``topk_frac < 1``): each client ships only
  the ``k`` largest-|d| entries as an index+value payload
  (:func:`sparse_encode`); ``k`` is the true element count times
  ``topk_frac``, rounded up to a lane multiple so int8 scale groups tile
  the compacted payload exactly.  The server folds the sparse payload
  through a scatter-fold ``masked_agg`` variant — no dense f32 cohort
  copy materializes.
* **stochastic rounding** (``stochastic=True``): the int8/bf16 encode
  rounds with per-client seeded random bits instead of
  round-to-nearest, making the quantizer unbiased so rounding noise
  averages out across the cohort.  The XLA implementation here is the
  bit-reproducible CPU reference for ``pltpu.stochastic_round``: int8
  takes ``floor(v + u)`` with ``u = bits * 2**-32``; bf16 adds the low
  16 random bits to the f32 bit pattern and truncates the mantissa.
* **error feedback** (``error_feedback=True``): each client keeps a
  residual row ``r`` in a second ``FlatStateStore``; it uploads
  ``encode(d + r)`` and keeps ``r' = (d + r) - decode(encode(d + r))``,
  so compression error is carried into the next round instead of lost.
  EF requires a lossy upload (a quantized/bf16 wire or ``topk_frac <
  1``) — on a lossless wire the residual is identically zero.

Upload billing under wire v2 is still measured: ``wire_bytes_up`` runs
the real sparse encoder under ``jax.eval_shape`` (values + scale sidecar
+ int32 indices) and degenerates to ``wire_bytes`` when ``topk_frac ==
1``.

Under the asynchronous round engine (``core/async_rounds.py``) broadcasts
are **version-tagged**: a chunk that trains on a stale version its clients
already hold does not re-download it.  :class:`VersionCache` keeps that
accounting truthful — one download is billed per (client, version), so the
measured per-round download shrinks exactly when a cached stale broadcast
is reused, and degenerates to the synchronous numbers at ``async_lag=0``
(every round publishes a fresh version, so every client re-downloads).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import flatten

Tree = Any

WIRE_DTYPES = ("float32", "bfloat16", "int8")

# int8 symmetric range: +-127 (−128 unused, keeps the code symmetric)
_QMAX = 127.0


@dataclasses.dataclass(frozen=True)
class WireSpec:
    """Static description of the wire format for one federated link.

    ``dtype`` is the payload dtype; ``quant_block`` is the elements-per-
    scale group (int8 only; must divide the lane alignment so groups stay
    inside slots — see module docstring).  The wire-v2 upload knobs:
    ``topk_frac`` keeps that fraction of each upload's entries (top-k by
    magnitude, 1.0 = dense), ``stochastic`` switches the lossy encode to
    seeded stochastic rounding, ``error_feedback`` carries per-client
    compression-error residuals across rounds.  Any of the three moves
    uploads to delta space (``uses_deltas``); none touches the broadcast.
    """
    dtype: str = "float32"
    quant_block: int = 128
    topk_frac: float = 1.0
    stochastic: bool = False
    error_feedback: bool = False

    def __post_init__(self):
        if self.dtype not in WIRE_DTYPES:
            raise ValueError(f"wire dtype must be one of {WIRE_DTYPES}, "
                             f"got {self.dtype!r}")
        if self.quant_block <= 0 or flatten.LANES % self.quant_block:
            raise ValueError(f"quant_block must divide the lane alignment "
                             f"({flatten.LANES}), got {self.quant_block}")
        if not 0.0 < self.topk_frac <= 1.0:
            raise ValueError(f"topk_frac must be in (0, 1], got "
                             f"{self.topk_frac}")
        if self.stochastic and self.dtype == "float32":
            raise ValueError("stochastic rounding requires a lossy wire "
                             "dtype (bfloat16 or int8), not float32")
        if self.error_feedback and self.dtype == "float32" \
                and self.topk_frac == 1.0:
            raise ValueError(
                "error_feedback requires a lossy upload path (bfloat16/"
                "int8 wire or topk_frac < 1); on the dense float32 wire "
                "the residual is identically zero")

    @property
    def is_identity(self) -> bool:
        return self.dtype == "float32"

    @property
    def is_quantized(self) -> bool:
        return self.dtype == "int8"

    @property
    def is_sparse(self) -> bool:
        """True when uploads ship top-k index+value payloads."""
        return self.topk_frac < 1.0

    @property
    def uses_deltas(self) -> bool:
        """True when uploads are deltas against the broadcast (the wire-v2
        path).  False keeps the pre-existing params-space upload traced
        program byte-identical."""
        return self.is_sparse or self.stochastic or self.error_feedback

    @property
    def payload_dtype(self):
        return jnp.dtype(self.dtype)


class WireBuffer(NamedTuple):
    """One encoded flat buffer: payload in the wire dtype (+ the f32 scale
    sidecar for quantized wires, else ``None``)."""
    payload: jax.Array
    scales: Optional[jax.Array]


class SparseWireBuffer(NamedTuple):
    """One top-k encoded flat buffer: the ``k`` kept values in the wire
    dtype (+ the f32 scale sidecar over the *compacted* payload for
    quantized wires), and their int32 flat positions."""
    payload: jax.Array
    scales: Optional[jax.Array]
    indices: jax.Array


def buffer_nbytes(buf: WireBuffer) -> int:
    """Measured wire size of one encoded buffer (payload + sidecar).
    Works on concrete arrays and ``ShapeDtypeStruct``s alike."""
    n = buf.payload.size * jnp.dtype(buf.payload.dtype).itemsize
    if buf.scales is not None:
        n += buf.scales.size * jnp.dtype(buf.scales.dtype).itemsize
    return int(n)


def sparse_buffer_nbytes(buf: SparseWireBuffer) -> int:
    """Measured wire size of one sparse upload: values + scale sidecar +
    int32 index payload (the indices are real traffic — billing them is
    what makes the top-k ratio honest)."""
    n = buffer_nbytes(WireBuffer(buf.payload, buf.scales))
    return n + int(buf.indices.size
                   * jnp.dtype(buf.indices.dtype).itemsize)


# ---------------------------------------------------------------------------
# Stochastic rounding (bit-reproducible CPU reference for
# pltpu.stochastic_round: uint32 bits drive both shapes)
# ---------------------------------------------------------------------------

def random_round_bits(key: jax.Array, shape) -> jax.Array:
    """Uniform uint32 rounding bits — the CPU-side stand-in for
    ``pltpu.prng_random_bits`` (one 32-bit word per element)."""
    return jax.random.bits(key, shape, jnp.uint32)


def stochastic_round_int(v: jax.Array, bits: jax.Array) -> jax.Array:
    """Stochastically round pre-scaled values to integers:
    ``floor(v + u)`` with ``u = bits * 2**-32`` uniform in [0, 1), so
    ``E[result] = v`` exactly.  Clipped to the symmetric int8 range
    (f32 addition can round ``127 + u`` up to 128.0)."""
    u = bits.astype(jnp.float32) * jnp.float32(2.0 ** -32)
    return jnp.clip(jnp.floor(v + u), -_QMAX, _QMAX)


def stochastic_round_bf16(x: jax.Array, bits: jax.Array) -> jax.Array:
    """Stochastic f32 -> bf16: add the low 16 random bits to the f32 bit
    pattern and truncate the mantissa — the carry into the kept bits
    fires with probability equal to the dropped fraction, so the
    rounding is unbiased in magnitude (and, by sign symmetry of the
    payload format, in value).  This is the mantissa-truncation shape
    ``pltpu.stochastic_round`` implements in hardware."""
    u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    u = (u + (bits & jnp.uint32(0xFFFF))) & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(u, jnp.float32).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# Quantize / dequantize (symmetric per-group int8)
# ---------------------------------------------------------------------------

def quantize(x: jax.Array, quant_block: int, *,
             key: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-group int8 quantization.

    Args:
      x: ``(..., n)`` values (cast to f32 internally); ``n`` must be a
        multiple of ``quant_block``.  Leading axes (cohort ``Z``, version
        stack ``V``) are batched through unchanged.
      quant_block: elements per scale group, ``s = max|group| / 127``.
      key: optional PRNG key — when given, round with
        :func:`stochastic_round_int` (unbiased) instead of
        round-to-nearest.  ``None`` keeps the deterministic encode
        bit-identical to the pre-v2 wire.

    Returns: ``(q, scales)`` with ``q`` int8 of ``x``'s shape and
    ``scales`` f32 of shape ``(..., n / quant_block)``.

    All-zero groups get scale 0 and payload 0 (decode is exactly 0, so
    alignment padding stays invisible to any sum).  Non-finite inputs
    produce a non-finite scale; the fold's weight gating zeroes those
    devices before the multiply, mirroring the f32 NaN-device contract.
    """
    n = x.shape[-1]
    if n % quant_block:
        raise ValueError(f"length {n} not a multiple of "
                         f"quant_block={quant_block}")
    g = x.astype(jnp.float32).reshape(x.shape[:-1] + (-1, quant_block))
    scales = jnp.max(jnp.abs(g), axis=-1) / _QMAX
    v = g / jnp.maximum(scales[..., None], 1e-30)
    if key is None:
        q = jnp.round(v)
    else:
        q = stochastic_round_int(v, random_round_bits(key, v.shape))
    q = jnp.where(scales[..., None] > 0, q, 0.0)
    q = jnp.clip(q, -_QMAX, _QMAX).astype(jnp.int8)
    return q.reshape(x.shape), scales


def dequantize(q: jax.Array, scales: jax.Array,
               quant_block: int) -> jax.Array:
    """Inverse of :func:`quantize`.

    Args:
      q: int8 payload ``(..., n)`` (``n`` a multiple of ``quant_block``).
      scales: f32 ``(..., n / quant_block)`` per-group scales.
      quant_block: the grouping both were produced with.

    Returns: f32 ``(..., n)`` — ``q * scale`` per group.  The server-side
    fold never calls this on uploads; the dequantizing ``masked_agg``
    accumulate fuses it into the FMA instead."""
    g = q.astype(jnp.float32).reshape(q.shape[:-1] + (-1, quant_block))
    return (g * scales[..., None]).reshape(q.shape)


# ---------------------------------------------------------------------------
# Encode / decode (one flat vector or a stacked (Z, n) chunk)
# ---------------------------------------------------------------------------

def encode(spec: WireSpec, flat: jax.Array, *,
           key: Optional[jax.Array] = None) -> WireBuffer:
    """Encode a flat vector for the wire.

    Args:
      spec: the wire format.
      flat: ``(..., n)`` f32 values — one packed model per trailing
        vector; leading axes (version stack, cohort) batch through.
      key: optional PRNG key — with ``spec.stochastic`` the lossy encode
        (int8 quantize / bf16 cast) rounds stochastically.  Callers on
        the broadcast path never pass one, so the downlink stays
        deterministic; only the per-client upload encode seeds it.

    Returns: a :class:`WireBuffer` — payload in ``spec.payload_dtype`` of
    ``flat``'s shape, plus the f32 scale sidecar for int8 wires.  Lengths
    that are not a group multiple are zero-padded into the last group (the
    sidecar covers ``ceil(n / quant_block)`` groups); payload keeps the
    caller's length."""
    key = key if spec.stochastic else None
    if spec.is_quantized:
        n = flat.shape[-1]
        pad = (-n) % spec.quant_block
        body = jnp.pad(flat.astype(jnp.float32),
                       [(0, 0)] * (flat.ndim - 1) + [(0, pad)]) \
            if pad else flat
        q, scales = quantize(body, spec.quant_block, key=key)
        return WireBuffer(q[..., :n], scales)
    if spec.dtype == "bfloat16" and key is not None:
        bits = random_round_bits(key, flat.shape)
        return WireBuffer(stochastic_round_bf16(flat, bits), None)
    return WireBuffer(flat.astype(spec.payload_dtype), None)


def decode(spec: WireSpec, buf: WireBuffer) -> jax.Array:
    """Decode a wire buffer back to values.

    Args:
      spec: the wire format the buffer was encoded with.
      buf: payload ``(..., n)`` (+ scales for int8).

    Returns: f32 ``(..., n)`` of the payload's length — what a client
    actually trains on (the broadcast's real quantization error included).
    """
    if spec.is_quantized:
        n = buf.payload.shape[-1]
        pad = (-n) % spec.quant_block
        q = jnp.pad(buf.payload, [(0, 0)] * (buf.payload.ndim - 1)
                    + [(0, pad)]) if pad else buf.payload
        return dequantize(q, buf.scales, spec.quant_block)[..., :n]
    return buf.payload.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Top-k sparse encode / decode (wire v2 uploads)
# ---------------------------------------------------------------------------

def topk_count(spec: WireSpec, n_elements: int) -> int:
    """Entries a sparse upload of ``n_elements`` true elements keeps:
    ``ceil(n * topk_frac)`` rounded up to a lane multiple (128), so int8
    scale groups tile the compacted payload exactly and the payload stays
    lane-aligned.  Dense specs keep everything."""
    if not spec.is_sparse:
        return int(n_elements)
    k = max(1, math.ceil(n_elements * spec.topk_frac))
    return -(-k // flatten.LANES) * flatten.LANES


def sparse_encode(spec: WireSpec, flat: jax.Array, k: int, *,
                  key: Optional[jax.Array] = None) -> SparseWireBuffer:
    """Top-k encode one flat vector: keep the ``k`` largest-|x| entries,
    encode the compacted values through the dense wire encoder (int8
    scale groups cover the compacted payload), and ship their sorted
    int32 flat positions alongside.

    Args:
      spec: the wire format; ``k`` must be a ``quant_block`` multiple
        (``topk_count`` guarantees a lane multiple) and ``<= n``.
      flat: ``(n,)`` f32 values (one client's delta).
      key: optional PRNG key for stochastic rounding of the values.

    Returns: a :class:`SparseWireBuffer`.  Indices are sorted ascending —
    deterministic, and scale groups over the compacted payload then
    cover position-contiguous runs of the flat vector."""
    flat = flat.astype(jnp.float32)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = jnp.sort(idx).astype(jnp.int32)
    dense = encode(spec, jnp.take(flat, idx), key=key)
    return SparseWireBuffer(dense.payload, dense.scales, idx)


def sparse_decode_values(spec: WireSpec, buf: SparseWireBuffer
                         ) -> jax.Array:
    """Decode only the compacted ``(..., k)`` values of a sparse buffer
    (what the scatter-fold consumes together with ``buf.indices``)."""
    return decode(spec, WireBuffer(buf.payload, buf.scales))


def sparse_decode(spec: WireSpec, buf: SparseWireBuffer,
                  n: int) -> jax.Array:
    """Reference dense decode of one sparse upload: the decoded values
    scattered into an ``(n,)`` f32 zero vector.  The server fold never
    calls this — the scatter-fold ``masked_agg`` variant accumulates the
    compacted payload directly — but tests and the EF residual math pin
    their semantics against it."""
    vals = sparse_decode_values(spec, buf)
    return jnp.zeros((n,), jnp.float32).at[buf.indices].add(vals)


# ---------------------------------------------------------------------------
# Measured byte accounting
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def wire_bytes(spec: WireSpec, n_elements: int) -> int:
    """Measured wire size of an ``n_elements`` exchange: the real encoder's
    output buffers under ``jax.eval_shape`` (no compute), payload + scale
    sidecar.  This is what the trainer bills per client per direction."""
    buf = jax.eval_shape(functools.partial(encode, spec),
                         jax.ShapeDtypeStruct((n_elements,), jnp.float32))
    return buffer_nbytes(buf)


def analytic_wire_bytes(spec: WireSpec, n_elements: int) -> int:
    """Closed-form size the measured number must match (consistency test):
    ``n * itemsize`` plus ``ceil(n / quant_block) * 4`` for int8."""
    n = n_elements * spec.payload_dtype.itemsize
    if spec.is_quantized:
        n += (-(-n_elements // spec.quant_block)) * 4
    return n


@functools.lru_cache(maxsize=None)
def wire_bytes_up(spec: WireSpec, n_elements: int) -> int:
    """Measured size of one *upload* of ``n_elements`` true elements.

    Dense wires bill exactly :func:`wire_bytes` (the upload payload has
    the broadcast's shape, delta-space or not).  Sparse wires run the
    real top-k encoder under ``jax.eval_shape``: values payload + scale
    sidecar + int32 indices for ``topk_count(spec, n_elements)`` kept
    entries — the same ``k`` the runtime encode uses, so this is the
    byte-exact size of the buffers a client actually ships."""
    if not spec.is_sparse:
        return wire_bytes(spec, n_elements)
    k = topk_count(spec, n_elements)
    # eval_shape only needs a vector long enough for top_k's k
    n_vec = max(-(-n_elements // flatten.LANES) * flatten.LANES, k)
    buf = jax.eval_shape(
        functools.partial(sparse_encode, spec, k=k),
        jax.ShapeDtypeStruct((n_vec,), jnp.float32))
    return sparse_buffer_nbytes(buf)


def analytic_wire_bytes_up(spec: WireSpec, n_elements: int) -> int:
    """Closed-form upload size the measured number must match:
    ``k * itemsize`` values + ``k/quant_block * 4`` scales (int8) +
    ``k * 4`` int32 indices, with ``k = topk_count``."""
    if not spec.is_sparse:
        return analytic_wire_bytes(spec, n_elements)
    k = topk_count(spec, n_elements)
    n = k * spec.payload_dtype.itemsize + k * 4
    if spec.is_quantized:
        n += (k // spec.quant_block) * 4
    return n


class VersionCache:
    """Version-tagged download accounting for the async broadcast.

    The asynchronous engine lets a chunk train on a stale server version;
    a client that already holds that version (it downloaded it in an
    earlier round) must not be billed a second download, or the measured
    savings of broadcast reuse would be fiction.  This host-side ledger
    tracks which version tag each client last fetched:

    * ``bill(client_id, tag, nbytes)`` — returns ``nbytes`` and records
      the fetch if the client's cached tag differs, else returns 0;
    * ``holds(client_id, tag)`` — query without billing.

    Tags are opaque hashables (the engine uses the publishing round
    index).  With ``async_lag=0`` every round publishes a fresh tag, so
    every sampled client re-downloads and the accounting reproduces the
    synchronous numbers exactly.

    ``hits`` / ``misses`` count ``bill`` outcomes since construction —
    a hit is a reused stale broadcast, the async engine's measured
    savings.

    **Retired from the round path.**  A per-client Python dict is
    O(N_clients) host state; the runtime now keeps version tags in the
    flat per-client state matrix (``core.client_state``, the
    ``version_tag`` column) and bills one vectorized tag-compare per
    round (``ClientStateMatrix.bill_downloads``).  This class stays as
    the executable *reference semantics* the vectorized billing is
    parity-tested against.
    """

    def __init__(self):
        self._held: Dict[Any, Any] = {}
        self.hits = 0
        self.misses = 0

    def holds(self, client_id, tag) -> bool:
        """True when ``client_id`` already fetched version ``tag``."""
        return self._held.get(client_id) == tag

    def bill(self, client_id, tag, nbytes: int) -> int:
        """Bytes this client's download of version ``tag`` costs now:
        ``nbytes`` on a cache miss (recorded), 0 on a hit."""
        if self.holds(client_id, tag):
            self.hits += 1
            return 0
        self.misses += 1
        self._held[client_id] = tag
        return int(nbytes)


# ---------------------------------------------------------------------------
# Tree-level paths (broadcast + checkpoint reuse the same encoder)
# ---------------------------------------------------------------------------

def encode_tree(spec: WireSpec, layout: flatten.FlatLayout,
                tree: Tree) -> WireBuffer:
    """Pack a parameter tree through ``layout`` and encode the flat vector
    — the broadcast/checkpoint unit (one contiguous buffer per model)."""
    return encode(spec, flatten.pack(layout, tree))


def decode_tree(spec: WireSpec, layout: flatten.FlatLayout,
                buf: WireBuffer, template: Optional[Tree] = None) -> Tree:
    """Decode a wire buffer and unpack to the layout's tree (leaf dtypes
    from the layout).  When ``template`` is given its treedef must equal
    the layout's — a mismatch means the buffer would unpack into the
    wrong structure."""
    if template is not None and \
            jax.tree.structure(template) != layout.treedef:
        raise ValueError("template treedef does not match the layout's")
    return flatten.unpack(layout, decode(spec, buf))


def broadcast_roundtrip(spec: WireSpec, layout: flatten.FlatLayout,
                        tree: Tree) -> Tree:
    """What a client receives: the server tree after one encode/decode trip
    through the wire.  Identity (no ops traced) for the f32 wire."""
    if spec.is_identity:
        return tree
    return decode_tree(spec, layout, encode_tree(spec, layout, tree))
