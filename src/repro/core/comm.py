"""Quantized flat-buffer communication over ``core.flatten.FlatLayout``.

FedHeN's headline claim is *communication savings*; related systems (FedHe,
HeteroFL) make the savings concrete with reduced-payload exchange.  This
module is the wire layer of that claim: the PR 3 ``(Z, n_flat)`` packed
representation — one contiguous lane-aligned buffer per client — becomes
the unit of both directions of the protocol:

* **broadcast** (server -> client): the server's flat vector is encoded to
  the wire dtype and the client trains on the decoded copy, so the round
  sees the real quantization error;
* **upload** (client -> server): each trained chunk is encoded to the same
  wire format and the fold *dequantizes inside the accumulate* — the
  ``masked_agg`` kernel's ``masked_agg_acc_deq`` variant consumes int8
  payloads + per-group f32 scales directly, so no separate materialized
  f32 copy of the cohort ever exists on the server.

Wire formats (``WireSpec.dtype``):

* ``float32`` — the identity wire (paper accounting; no transform);
* ``bfloat16`` — 2-byte payload, no sidecar;
* ``int8`` — symmetric per-group quantization ``q = round(x / s)``,
  ``s = max|x| / 127`` per contiguous group of ``quant_block`` elements,
  plus an f32 scale sidecar (``ceil(n / quant_block)`` scales).

``quant_block`` must divide the layout's lane alignment (128), so a scale
group never crosses a ``LeafSlot`` boundary: quantization error is bounded
*per slot* by that slot's own magnitudes, alignment-padding groups are
all-zero (scale 0 -> payload 0 -> decode 0), and the CPU fallback can fold
leaf by leaf without changing group boundaries.

Byte accounting is **measured, not estimated**: ``wire_bytes`` runs the
real encoder under ``jax.eval_shape`` and sums the output buffer sizes, so
the trainer's per-round numbers are the encoder's actual output — payload
*and* sidecar — for the true (compact) element counts.  Alignment padding
is a local layout artifact the sender strips (offsets are static on both
ends), so it is never billed to the wire.

Under the asynchronous round engine (``core/async_rounds.py``) broadcasts
are **version-tagged**: a chunk that trains on a stale version its clients
already hold does not re-download it.  :class:`VersionCache` keeps that
accounting truthful — one download is billed per (client, version), so the
measured per-round download shrinks exactly when a cached stale broadcast
is reused, and degenerates to the synchronous numbers at ``async_lag=0``
(every round publishes a fresh version, so every client re-downloads).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import flatten

Tree = Any

WIRE_DTYPES = ("float32", "bfloat16", "int8")

# int8 symmetric range: +-127 (−128 unused, keeps the code symmetric)
_QMAX = 127.0


@dataclasses.dataclass(frozen=True)
class WireSpec:
    """Static description of the wire format for one federated link.

    ``dtype`` is the payload dtype; ``quant_block`` is the elements-per-
    scale group (int8 only; must divide the lane alignment so groups stay
    inside slots — see module docstring).
    """
    dtype: str = "float32"
    quant_block: int = 128

    def __post_init__(self):
        if self.dtype not in WIRE_DTYPES:
            raise ValueError(f"wire dtype must be one of {WIRE_DTYPES}, "
                             f"got {self.dtype!r}")
        if self.quant_block <= 0 or flatten.LANES % self.quant_block:
            raise ValueError(f"quant_block must divide the lane alignment "
                             f"({flatten.LANES}), got {self.quant_block}")

    @property
    def is_identity(self) -> bool:
        return self.dtype == "float32"

    @property
    def is_quantized(self) -> bool:
        return self.dtype == "int8"

    @property
    def payload_dtype(self):
        return jnp.dtype(self.dtype)


class WireBuffer(NamedTuple):
    """One encoded flat buffer: payload in the wire dtype (+ the f32 scale
    sidecar for quantized wires, else ``None``)."""
    payload: jax.Array
    scales: Optional[jax.Array]


def buffer_nbytes(buf: WireBuffer) -> int:
    """Measured wire size of one encoded buffer (payload + sidecar).
    Works on concrete arrays and ``ShapeDtypeStruct``s alike."""
    n = buf.payload.size * jnp.dtype(buf.payload.dtype).itemsize
    if buf.scales is not None:
        n += buf.scales.size * jnp.dtype(buf.scales.dtype).itemsize
    return int(n)


# ---------------------------------------------------------------------------
# Quantize / dequantize (symmetric per-group int8)
# ---------------------------------------------------------------------------

def quantize(x: jax.Array, quant_block: int) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-group int8 quantization.

    Args:
      x: ``(..., n)`` values (cast to f32 internally); ``n`` must be a
        multiple of ``quant_block``.  Leading axes (cohort ``Z``, version
        stack ``V``) are batched through unchanged.
      quant_block: elements per scale group, ``s = max|group| / 127``.

    Returns: ``(q, scales)`` with ``q`` int8 of ``x``'s shape and
    ``scales`` f32 of shape ``(..., n / quant_block)``.

    All-zero groups get scale 0 and payload 0 (decode is exactly 0, so
    alignment padding stays invisible to any sum).  Non-finite inputs
    produce a non-finite scale; the fold's weight gating zeroes those
    devices before the multiply, mirroring the f32 NaN-device contract.
    """
    n = x.shape[-1]
    if n % quant_block:
        raise ValueError(f"length {n} not a multiple of "
                         f"quant_block={quant_block}")
    g = x.astype(jnp.float32).reshape(x.shape[:-1] + (-1, quant_block))
    scales = jnp.max(jnp.abs(g), axis=-1) / _QMAX
    q = jnp.round(g / jnp.maximum(scales[..., None], 1e-30))
    q = jnp.where(scales[..., None] > 0, q, 0.0)
    q = jnp.clip(q, -_QMAX, _QMAX).astype(jnp.int8)
    return q.reshape(x.shape), scales


def dequantize(q: jax.Array, scales: jax.Array,
               quant_block: int) -> jax.Array:
    """Inverse of :func:`quantize`.

    Args:
      q: int8 payload ``(..., n)`` (``n`` a multiple of ``quant_block``).
      scales: f32 ``(..., n / quant_block)`` per-group scales.
      quant_block: the grouping both were produced with.

    Returns: f32 ``(..., n)`` — ``q * scale`` per group.  The server-side
    fold never calls this on uploads; the dequantizing ``masked_agg``
    accumulate fuses it into the FMA instead."""
    g = q.astype(jnp.float32).reshape(q.shape[:-1] + (-1, quant_block))
    return (g * scales[..., None]).reshape(q.shape)


# ---------------------------------------------------------------------------
# Encode / decode (one flat vector or a stacked (Z, n) chunk)
# ---------------------------------------------------------------------------

def encode(spec: WireSpec, flat: jax.Array) -> WireBuffer:
    """Encode a flat vector for the wire.

    Args:
      spec: the wire format.
      flat: ``(..., n)`` f32 values — one packed model per trailing
        vector; leading axes (version stack, cohort) batch through.

    Returns: a :class:`WireBuffer` — payload in ``spec.payload_dtype`` of
    ``flat``'s shape, plus the f32 scale sidecar for int8 wires.  Lengths
    that are not a group multiple are zero-padded into the last group (the
    sidecar covers ``ceil(n / quant_block)`` groups); payload keeps the
    caller's length."""
    if spec.is_quantized:
        n = flat.shape[-1]
        pad = (-n) % spec.quant_block
        body = jnp.pad(flat.astype(jnp.float32),
                       [(0, 0)] * (flat.ndim - 1) + [(0, pad)]) \
            if pad else flat
        q, scales = quantize(body, spec.quant_block)
        return WireBuffer(q[..., :n], scales)
    return WireBuffer(flat.astype(spec.payload_dtype), None)


def decode(spec: WireSpec, buf: WireBuffer) -> jax.Array:
    """Decode a wire buffer back to values.

    Args:
      spec: the wire format the buffer was encoded with.
      buf: payload ``(..., n)`` (+ scales for int8).

    Returns: f32 ``(..., n)`` of the payload's length — what a client
    actually trains on (the broadcast's real quantization error included).
    """
    if spec.is_quantized:
        n = buf.payload.shape[-1]
        pad = (-n) % spec.quant_block
        q = jnp.pad(buf.payload, [(0, 0)] * (buf.payload.ndim - 1)
                    + [(0, pad)]) if pad else buf.payload
        return dequantize(q, buf.scales, spec.quant_block)[..., :n]
    return buf.payload.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Measured byte accounting
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def wire_bytes(spec: WireSpec, n_elements: int) -> int:
    """Measured wire size of an ``n_elements`` exchange: the real encoder's
    output buffers under ``jax.eval_shape`` (no compute), payload + scale
    sidecar.  This is what the trainer bills per client per direction."""
    buf = jax.eval_shape(functools.partial(encode, spec),
                         jax.ShapeDtypeStruct((n_elements,), jnp.float32))
    return buffer_nbytes(buf)


def analytic_wire_bytes(spec: WireSpec, n_elements: int) -> int:
    """Closed-form size the measured number must match (consistency test):
    ``n * itemsize`` plus ``ceil(n / quant_block) * 4`` for int8."""
    n = n_elements * spec.payload_dtype.itemsize
    if spec.is_quantized:
        n += (-(-n_elements // spec.quant_block)) * 4
    return n


class VersionCache:
    """Version-tagged download accounting for the async broadcast.

    The asynchronous engine lets a chunk train on a stale server version;
    a client that already holds that version (it downloaded it in an
    earlier round) must not be billed a second download, or the measured
    savings of broadcast reuse would be fiction.  This host-side ledger
    tracks which version tag each client last fetched:

    * ``bill(client_id, tag, nbytes)`` — returns ``nbytes`` and records
      the fetch if the client's cached tag differs, else returns 0;
    * ``holds(client_id, tag)`` — query without billing.

    Tags are opaque hashables (the engine uses the publishing round
    index).  With ``async_lag=0`` every round publishes a fresh tag, so
    every sampled client re-downloads and the accounting reproduces the
    synchronous numbers exactly.

    ``hits`` / ``misses`` count ``bill`` outcomes since construction —
    a hit is a reused stale broadcast, the async engine's measured
    savings.

    **Retired from the round path.**  A per-client Python dict is
    O(N_clients) host state; the runtime now keeps version tags in the
    flat per-client state matrix (``core.client_state``, the
    ``version_tag`` column) and bills one vectorized tag-compare per
    round (``ClientStateMatrix.bill_downloads``).  This class stays as
    the executable *reference semantics* the vectorized billing is
    parity-tested against.
    """

    def __init__(self):
        self._held: Dict[Any, Any] = {}
        self.hits = 0
        self.misses = 0

    def holds(self, client_id, tag) -> bool:
        """True when ``client_id`` already fetched version ``tag``."""
        return self._held.get(client_id) == tag

    def bill(self, client_id, tag, nbytes: int) -> int:
        """Bytes this client's download of version ``tag`` costs now:
        ``nbytes`` on a cache miss (recorded), 0 on a hit."""
        if self.holds(client_id, tag):
            self.hits += 1
            return 0
        self.misses += 1
        self._held[client_id] = tag
        return int(nbytes)


# ---------------------------------------------------------------------------
# Tree-level paths (broadcast + checkpoint reuse the same encoder)
# ---------------------------------------------------------------------------

def encode_tree(spec: WireSpec, layout: flatten.FlatLayout,
                tree: Tree) -> WireBuffer:
    """Pack a parameter tree through ``layout`` and encode the flat vector
    — the broadcast/checkpoint unit (one contiguous buffer per model)."""
    return encode(spec, flatten.pack(layout, tree))


def decode_tree(spec: WireSpec, layout: flatten.FlatLayout,
                buf: WireBuffer, template: Optional[Tree] = None) -> Tree:
    """Decode a wire buffer and unpack to the layout's tree (leaf dtypes
    from the layout).  When ``template`` is given its treedef must equal
    the layout's — a mismatch means the buffer would unpack into the
    wrong structure."""
    if template is not None and \
            jax.tree.structure(template) != layout.treedef:
        raise ValueError("template treedef does not match the layout's")
    return flatten.unpack(layout, decode(spec, buf))


def broadcast_roundtrip(spec: WireSpec, layout: flatten.FlatLayout,
                        tree: Tree) -> Tree:
    """What a client receives: the server tree after one encode/decode trip
    through the wire.  Identity (no ops traced) for the f32 wire."""
    if spec.is_identity:
        return tree
    return decode_tree(spec, layout, encode_tree(spec, layout, tree))
