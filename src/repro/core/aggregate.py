"""Server aggregation — FedHeN Alg. 1 ln. 16-22, plus NoSide and Decouple.

All three operate on a *stacked cohort*: client models share the complex
treedef with a leading cohort axis ``Z``.  Simple clients' complex-only
slices are carried untouched (they are weighted out by the masks), so one
stacked representation serves every algorithm.

Two entry points:

* One-shot (``fedhen_server_update`` / ``decouple_server_update``): the
  whole cohort is stacked and reduced at once.  Reference semantics.
* Streaming (``streaming_init`` / ``streaming_fold`` / ``streaming_finalize``):
  the cohort arrives in chunks; each chunk is folded into running
  *unnormalized* masked sums (one accumulator tree selecting inside-M /
  outside-M weights per element, plus the two weight totals), and the
  division happens once at ``streaming_finalize``.  This is the contract the
  round engine's ``lax.scan`` over cohort chunks uses (core/federated.py):
  server memory is O(chunk), the result matches the one-shot path up to
  float summation order.

The hot path — a weighted masked sum over the cohort axis — is exactly the
``masked_agg`` Pallas kernel's contract; ``streaming_fold`` dispatches to it
on TPU via ``kernels/masked_agg/ops.py``, with the XLA reference as the CPU
fallback (what the dry-run lowers, since Pallas cannot lower on the CPU
backend).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import masking
from repro.kernels.masked_agg import ops as agg_ops

Tree = Any

ALGORITHMS = ("fedhen", "noside", "decouple")


def _gated_wsum_leaf(x: jax.Array, weights: jax.Array) -> jax.Array:
    """f32 weighted sum of one stacked leaf over the cohort axis.

    Gates before multiplying: a NaN device with weight 0 must not poison
    the sum (paper's NaN-device exclusion)."""
    w = weights.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
    xf = jnp.where(w > 0, x.astype(jnp.float32), 0.0)
    return jnp.sum(xf * w, axis=0)


def _wmean(stacked: Tree, weights: jax.Array) -> Tree:
    """Weighted mean over leading cohort axis.  weights: (Z,) already
    normalized (sums to 1 over the intended group)."""
    return jax.tree.map(
        lambda x: _gated_wsum_leaf(x, weights).astype(x.dtype), stacked)


def _norm_weights(raw: jax.Array) -> jax.Array:
    total = jnp.sum(raw)
    return jnp.where(total > 0, raw / jnp.maximum(total, 1e-12),
                     jnp.zeros_like(raw))


def fedhen_server_update(cohort: Tree, is_simple: jax.Array,
                         valid: jax.Array, mask: Tree) -> Tree:
    """FedHeN / NoSide server step (they share it — paper Appendix A).

    cohort: stacked client models (Z, ...) in complex structure.
    is_simple: (Z,) bool; valid: (Z,) bool (NaN-device exclusion).
    mask: index-set-M mask tree.

    Returns the new complex server model; the simple server model is its
    M-slice by construction (invariant tested in tests/test_aggregate.py).
    """
    valid_f = valid.astype(jnp.float32)
    w_all = _norm_weights(valid_f)                          # ln. 18: 1/|Z|
    w_complex = _norm_weights(valid_f * (~is_simple))       # ln. 22: 1/|Z_c|
    mean_all = _wmean(cohort, w_all)
    mean_complex = _wmean(cohort, w_complex)
    # ln. 18-20: M slice <- mean over ALL devices; ln. 22: M' <- complex mean
    return masking.where_mask(mask, mean_all, mean_complex)


def decouple_server_update(cohort: Tree, is_simple: jax.Array,
                           valid: jax.Array, mask: Tree) -> Tree:
    """Decouple (Alg. 3): two independent FedAvg runs in one stacked tree.

    M slice <- mean over simple devices only; M' <- mean over complex only.
    (The simple server model lives in the M slice; the complex server model's
    M slice is tracked separately by the caller — see ``ServerState``.)
    """
    valid_f = valid.astype(jnp.float32)
    w_simple = _norm_weights(valid_f * is_simple)
    w_complex = _norm_weights(valid_f * (~is_simple))
    mean_simple = _wmean(cohort, w_simple)
    mean_complex = _wmean(cohort, w_complex)
    return masking.where_mask(mask, mean_simple, mean_complex), mean_complex


def masked_cohort_mean(cohort: Tree, weights_m: jax.Array,
                       weights_rest: jax.Array, mask: Tree) -> Tree:
    """General primitive: different cohort weights inside/outside M.

    This is the op the ``masked_agg`` kernel implements on TPU.
    """
    mean_m = _wmean(cohort, weights_m)
    mean_rest = _wmean(cohort, weights_rest)
    return masking.where_mask(mask, mean_m, mean_rest)


# ---------------------------------------------------------------------------
# Streaming aggregation (chunked cohorts)
# ---------------------------------------------------------------------------

class StreamState(NamedTuple):
    """Running sums of a chunked server aggregation (a jit/scan carry).

    ``acc`` is one f32 tree of *unnormalized* masked sums: inside M each
    element accumulates ``sum_z w_in[z] * x[z]``, outside M
    ``sum_z w_out[z] * x[z]`` — exactly one ``masked_agg`` kernel pass per
    chunk.  ``acc_out`` (decouple only, else ``None``) additionally carries
    the *full-tree* ``w_out`` sums, because decouple's new complex model is
    the complex-group mean everywhere, including inside M.  ``tot_in`` /
    ``tot_out`` are the scalar weight totals the finalize divides by.
    """
    acc: Tree
    acc_out: Optional[Tree]
    tot_in: jax.Array
    tot_out: jax.Array


def _chunk_weights(is_simple: jax.Array, valid: jax.Array,
                   algorithm: str) -> Tuple[jax.Array, jax.Array]:
    """Raw (unnormalized) per-client weights of one chunk.

    ``w_in`` weights the inside-M accumulator: every valid device for
    fedhen/noside (Alg. 1 ln. 18), simple devices only for decouple.
    ``w_out`` weights outside M: complex devices only (ln. 22), for all
    three algorithms.
    """
    if algorithm not in ALGORITHMS:
        raise ValueError(algorithm)
    valid_f = valid.astype(jnp.float32)
    w_in = valid_f * is_simple if algorithm == "decouple" else valid_f
    w_out = valid_f * (~is_simple)
    return w_in, w_out


def streaming_init(params_like: Tree, algorithm: str) -> StreamState:
    """Zero accumulators shaped like one (unstacked) complex model."""
    if algorithm not in ALGORITHMS:
        raise ValueError(algorithm)
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                         params_like)
    acc_out = zeros if algorithm == "decouple" else None
    return StreamState(zeros, acc_out, jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32))


def streaming_fold(state: StreamState, chunk: Tree, is_simple: jax.Array,
                   valid: jax.Array, mask: Tree, *, algorithm: str,
                   force_pallas_interpret: bool = False) -> StreamState:
    """Fold one stacked chunk (z, ...) of client models into the sums.

    Invalid (NaN / padding) devices carry weight 0 and are gated before the
    multiply, so they can never poison the accumulators.  The masked partial
    sum is one ``masked_agg`` kernel call per leaf on TPU.
    """
    w_in, w_out = _chunk_weights(is_simple, valid, algorithm)
    chunk32 = jax.tree.map(lambda x: x.astype(jnp.float32), chunk)
    part = agg_ops.masked_agg_tree(
        chunk32, mask, w_in, w_out,
        force_pallas_interpret=force_pallas_interpret)
    acc = jax.tree.map(jnp.add, state.acc, part)
    acc_out = state.acc_out
    if acc_out is not None:
        acc_out = jax.tree.map(
            lambda a, x: a + _gated_wsum_leaf(x, w_out), acc_out, chunk32)
    return StreamState(acc, acc_out, state.tot_in + jnp.sum(w_in),
                       state.tot_out + jnp.sum(w_out))


def streaming_finalize(state: StreamState, mask: Tree, template: Tree, *,
                       algorithm: str) -> Tuple[Tree, Optional[Tree]]:
    """Normalize the sums into server models, cast to ``template`` dtypes.

    Returns ``(new_complex, new_simple_host)``; the host is ``None`` except
    for decouple (matching ``ServerState``).  A group with zero total weight
    yields zeros, like ``_norm_weights`` in the one-shot path.
    """
    def safe_div(tree, tot):
        inv = jnp.where(tot > 0, 1.0 / jnp.maximum(tot, 1e-12), 0.0)
        return jax.tree.map(lambda a: a * inv, tree)

    mean_in = safe_div(state.acc, state.tot_in)
    mean_out = safe_div(state.acc, state.tot_out)
    cast = lambda tree: jax.tree.map(
        lambda a, t: a.astype(t.dtype), tree, template)
    combined = cast(masking.where_mask(mask, mean_in, mean_out))
    if algorithm == "decouple":
        new_complex = cast(safe_div(state.acc_out, state.tot_out))
        return new_complex, combined
    return combined, None
