"""Server aggregation — FedHeN Alg. 1 ln. 16-22, plus NoSide and Decouple.

All three operate on a *stacked cohort*: client models share the complex
treedef with a leading cohort axis ``Z``.  Simple clients' complex-only
slices are carried untouched (they are weighted out by the masks), so one
stacked representation serves every algorithm.

Three entry points:

* One-shot (``fedhen_server_update`` / ``decouple_server_update``): the
  whole cohort is stacked and reduced at once.  Reference semantics — the
  parity oracle every streaming engine is tested against.
* Flat streaming (``streaming_init`` / ``streaming_fold`` /
  ``streaming_finalize``) — THE production fold.  ``StreamState`` carries
  one flat f32 accumulator vector (plus one more for decouple): each
  trained chunk is packed into a single contiguous ``(Z, n_flat)`` buffer
  by the trainer's static ``core.flatten.FlatLayout`` and folded with ONE
  accumulating ``masked_agg`` launch (``input_output_aliases`` updates the
  running sum in place on TPU), against one precomputed flat mask
  bitvector.  Chunks may stream in bf16; accumulation is always f32.
  Under a wire format (``core/comm.py``) the fold consumes the *encoded
  uploads* — int8 payloads fold through the dequantizing accumulate
  variant, never materializing an f32 copy of the chunk.
  Unpacking back to the parameter tree happens once, at finalize.

  **Flat layout contract**: the layout's offsets are static per (treedef,
  leaf shapes, align, block_n) — built once per trainer and valid for
  every round.  Per-element results match the tree path exactly up to
  float summation order across kernel tile boundaries (the cohort axis is
  reduced in the same order per lane).
* Tree streaming (``tree_streaming_init`` / ``tree_streaming_fold`` /
  ``tree_streaming_finalize``): the PR 2 per-leaf engine (one
  ``masked_agg`` launch per leaf), kept as the streaming parity reference
  and selectable via ``FedConfig.agg_engine="tree"``.

Both streaming engines fold chunks into running *unnormalized* masked sums
plus two scalar weight totals; the division happens once at finalize, so
server memory is O(chunk) and the result matches the one-shot path up to
float summation order.

**Weight contract.**  ``valid`` is a per-client coefficient, not just a
bool: a bool marks plain validity (NaN exclusion, padding), while a float
carries validity *times* any per-client coefficient — the asynchronous
engine (``core/async_rounds.py``) multiplies its staleness decay
``1/(1+s)^a`` into it, so staleness weighting rides the exact same masked
weight path as NaN/padding exclusion and needs no second code path.  A
weight of 0 gates the client's values before the multiply on every path
(a NaN device at weight 0 can never poison the sums), and all-1 float
weights are bit-identical to bool validity.

The hot path — a weighted masked sum over the cohort axis — is exactly the
``masked_agg`` Pallas kernel's contract; the folds dispatch to it on TPU
via ``kernels/masked_agg/ops.py``, with the XLA reference as the CPU
fallback (what the dry-run lowers, since Pallas cannot lower on the CPU
backend).
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import comm, flatten, masking
from repro.kernels.masked_agg import ops as agg_ops

Tree = Any

ALGORITHMS = ("fedhen", "noside", "decouple")


# ---------------------------------------------------------------------------
# EngineSpec: the one object a fold engine is configured by
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class EngineSpec:
    """Everything a fold engine needs, in one frozen value.

    The engine kwargs used to thread loose through ``make_engine`` /
    ``streaming_{init,fold,finalize}`` / ``launch/steps.py`` — seven
    arguments per call site, drifting independently.  An ``EngineSpec``
    is built ONCE (``from_config`` next to the ``FedConfig`` that owns
    the knobs) and handed whole to every seam; trace-time values the
    config cannot know (the mask tree, the trainer's layout, a
    ``flat_mask`` that is a round *argument*) are attached with
    :meth:`bind`.

    ``eq=False``: ``mask``/``flat_mask`` may hold (traced) arrays, so
    identity comparison is the only safe equality — specs are plumbing,
    never dict keys.

    The legacy loose-kwarg signatures still work via shims that emit
    ``DeprecationWarning`` and build the equivalent spec, so both paths
    run literally the same code (jaxpr-identity-tested in
    tests/test_aggregate.py).
    """

    engine: str = "flat"
    algorithm: str = "fedhen"
    mask: Tree = None
    layout: Optional[flatten.FlatLayout] = None
    flat_mask: Optional[jax.Array] = None
    block_n: int = 2048
    stream_dtype: Any = jnp.float32
    wire: Optional[comm.WireSpec] = None
    variance_reduction: str = "none"

    def __post_init__(self):
        if self.engine not in ("flat", "tree"):
            raise ValueError(f"unknown agg engine {self.engine!r}")
        if self.algorithm not in ALGORITHMS:
            raise ValueError(self.algorithm)
        if (self.engine == "tree" and self.wire is not None
                and self.wire.is_quantized):
            raise ValueError("int8 wire requires the flat engine "
                             "(dequantizing fold is a flat-buffer op)")
        if (self.engine == "tree" and self.wire is not None
                and self.wire.uses_deltas):
            raise ValueError("compressed uploads (topk/stochastic/"
                             "error-feedback wire) require the flat engine "
                             "(the delta fold is a flat-buffer op)")

    @classmethod
    def from_config(cls, fed, *, mask: Tree = None,
                    layout: Optional[flatten.FlatLayout] = None,
                    flat_mask: Optional[jax.Array] = None,
                    wire: Optional[comm.WireSpec] = None) -> "EngineSpec":
        """Build the spec from a ``FedConfig`` (the knobs' one source)."""
        return cls(engine=fed.agg_engine, algorithm=fed.algorithm,
                   mask=mask, layout=layout, flat_mask=flat_mask,
                   block_n=fed.agg_block_n,
                   stream_dtype=jnp.dtype(fed.agg_stream_dtype),
                   wire=wire, variance_reduction=fed.variance_reduction)

    def bind(self, **kw) -> "EngineSpec":
        """A copy with trace-time values attached (mask, layout,
        flat_mask, ...)."""
        return dataclasses.replace(self, **kw)


def _legacy_spec(where: str, **kw) -> EngineSpec:
    warnings.warn(f"{where} with loose engine kwargs is deprecated; "
                  f"pass an EngineSpec", DeprecationWarning, stacklevel=3)
    return EngineSpec(**kw)


def _gated_wsum_leaf(x: jax.Array, weights: jax.Array) -> jax.Array:
    """f32 weighted sum of one stacked leaf over the cohort axis.

    Gates before multiplying: a NaN device with weight 0 must not poison
    the sum (paper's NaN-device exclusion)."""
    w = weights.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
    xf = jnp.where(w > 0, x.astype(jnp.float32), 0.0)
    return jnp.sum(xf * w, axis=0)


def _wmean(stacked: Tree, weights: jax.Array) -> Tree:
    """Weighted mean over leading cohort axis.  weights: (Z,) already
    normalized (sums to 1 over the intended group)."""
    return jax.tree.map(
        lambda x: _gated_wsum_leaf(x, weights).astype(x.dtype), stacked)


def _norm_weights(raw: jax.Array) -> jax.Array:
    total = jnp.sum(raw)
    return jnp.where(total > 0, raw / jnp.maximum(total, 1e-12),
                     jnp.zeros_like(raw))


def fedhen_server_update(cohort: Tree, is_simple: jax.Array,
                         valid: jax.Array, mask: Tree) -> Tree:
    """FedHeN / NoSide server step (they share it — paper Appendix A).

    cohort: stacked client models (Z, ...) in complex structure.
    is_simple: (Z,) bool; valid: (Z,) bool (NaN-device exclusion).
    mask: index-set-M mask tree.

    Returns the new complex server model; the simple server model is its
    M-slice by construction (invariant tested in tests/test_aggregate.py).
    """
    valid_f = valid.astype(jnp.float32)
    w_all = _norm_weights(valid_f)                          # ln. 18: 1/|Z|
    w_complex = _norm_weights(valid_f * (~is_simple))       # ln. 22: 1/|Z_c|
    mean_all = _wmean(cohort, w_all)
    mean_complex = _wmean(cohort, w_complex)
    # ln. 18-20: M slice <- mean over ALL devices; ln. 22: M' <- complex mean
    return masking.where_mask(mask, mean_all, mean_complex)


def decouple_server_update(cohort: Tree, is_simple: jax.Array,
                           valid: jax.Array, mask: Tree) -> Tree:
    """Decouple (Alg. 3): two independent FedAvg runs in one stacked tree.

    M slice <- mean over simple devices only; M' <- mean over complex only.
    (The simple server model lives in the M slice; the complex server model's
    M slice is tracked separately by the caller — see ``ServerState``.)
    """
    valid_f = valid.astype(jnp.float32)
    w_simple = _norm_weights(valid_f * is_simple)
    w_complex = _norm_weights(valid_f * (~is_simple))
    mean_simple = _wmean(cohort, w_simple)
    mean_complex = _wmean(cohort, w_complex)
    return masking.where_mask(mask, mean_simple, mean_complex), mean_complex


def masked_cohort_mean(cohort: Tree, weights_m: jax.Array,
                       weights_rest: jax.Array, mask: Tree) -> Tree:
    """General primitive: different cohort weights inside/outside M.

    This is the op the ``masked_agg`` kernel implements on TPU.
    """
    mean_m = _wmean(cohort, weights_m)
    mean_rest = _wmean(cohort, weights_rest)
    return masking.where_mask(mask, mean_m, mean_rest)


# ---------------------------------------------------------------------------
# Shared streaming helpers
# ---------------------------------------------------------------------------

def _chunk_weights(is_simple: jax.Array, valid: jax.Array,
                   algorithm: str) -> Tuple[jax.Array, jax.Array]:
    """Raw (unnormalized) per-client weights of one chunk.

    ``valid`` may be bool (plain validity) or float (validity x any
    per-client coefficient, e.g. the async engine's staleness decay) —
    see the module's weight contract.  ``w_in`` weights the inside-M
    accumulator: every valid device for fedhen/noside (Alg. 1 ln. 18),
    simple devices only for decouple.  ``w_out`` weights outside M:
    complex devices only (ln. 22), for all three algorithms.
    """
    if algorithm not in ALGORITHMS:
        raise ValueError(algorithm)
    valid_f = valid.astype(jnp.float32)
    w_in = valid_f * is_simple if algorithm == "decouple" else valid_f
    w_out = valid_f * (~is_simple)
    return w_in, w_out


def _safe_inv(tot: jax.Array) -> jax.Array:
    """1/tot with the zero-weight-group guard (0 -> 0, never NaN)."""
    return jnp.where(tot > 0, 1.0 / jnp.maximum(tot, 1e-12), 0.0)


# ---------------------------------------------------------------------------
# Flat streaming aggregation (the production fold)
# ---------------------------------------------------------------------------

class StreamState(NamedTuple):
    """Running sums of a chunked server aggregation (a jit/scan carry).

    ``acc`` is ONE flat f32 vector of *unnormalized* masked sums over the
    trainer's ``FlatLayout``: inside M each element accumulates
    ``sum_z w_in[z] * x[z]``, outside M ``sum_z w_out[z] * x[z]`` — exactly
    one accumulating ``masked_agg`` kernel pass per chunk, updated in place.
    ``acc_out`` (decouple only, else ``None``) additionally carries the
    *whole-vector* ``w_out`` sums, because decouple's new complex model is
    the complex-group mean everywhere, including inside M.  ``tot_in`` /
    ``tot_out`` are the scalar weight totals the finalize divides by.
    ``cv_acc`` (SCAFFOLD only, else ``None``) is the second flat
    accumulator: the raw sum of per-client control-variate deltas folded
    through the exact same masked launch as the params; the round divides
    it by N_devices itself (``finalize`` never touches it).
    """
    acc: jax.Array
    acc_out: Optional[jax.Array]
    tot_in: jax.Array
    tot_out: jax.Array
    cv_acc: Optional[jax.Array] = None


class SparseChunk(NamedTuple):
    """One chunk's delta-mode uploads (wire v2, ``core/comm.py``).

    When the wire ``uses_deltas`` (top-k / stochastic / error-feedback),
    clients upload the encoded *difference* vs the flat decoded broadcast
    they trained on — ``base`` here, one ``(n_flat,)`` f32 vector shared
    by the chunk.  Each client's true upload is
    ``base + decode(row z of values)``, so the fold adds
    ``(sum_z w[z]) * base`` densely (ONE Z=1 masked accumulate with the
    summed weights) plus every encoded delta row at its own weight —
    the same total as folding the dense uploads, without materializing
    them.

    ``indices=None`` means the delta payload is dense (EF/stochastic
    without top-k): ``values`` is ``(Z, n_flat)`` and folds through the
    plain (bf16/f32) or dequantizing (int8 — ``scales`` present)
    accumulate.  With top-k, ``values``/``indices`` are the compacted
    ``(Z, k)`` payloads (``scales`` grouped over the compacted axis)
    scattered by the ``masked_scatter_acc`` kernel variant — no dense
    f32 cohort copy on either path."""
    base: jax.Array
    values: jax.Array
    scales: Optional[jax.Array]
    indices: Optional[jax.Array]


def _layout_for(tree: Tree, layout, block_n: int, *, stacked: bool = False):
    if layout is not None:
        return layout
    return flatten.layout_of(tree, total_multiple=block_n, stacked=stacked)


def streaming_init(params_like: Tree, algorithm, *,
                   layout: Optional[flatten.FlatLayout] = None,
                   block_n: int = 2048) -> StreamState:
    """Zero flat accumulators for one round of streaming aggregation.

    Args:
      params_like: ONE (unstacked) complex model tree — only shapes are
        read, to size the flat accumulator.
      algorithm: the :class:`EngineSpec` (preferred; decouple allocates
        the second accumulator, SCAFFOLD the control-variate one), or a
        legacy algorithm string (deprecated).
      layout / block_n: legacy-only loose kwargs; a spec carries its own.

    Returns: a :class:`StreamState` of f32 zeros (``(n_flat,)`` acc(s) +
    two scalar weight totals)."""
    spec = algorithm if isinstance(algorithm, EngineSpec) else _legacy_spec(
        "streaming_init(params_like, algorithm, ...)", algorithm=algorithm,
        layout=layout, block_n=block_n)
    layout = _layout_for(params_like, spec.layout, spec.block_n)
    zeros = jnp.zeros((layout.n_flat,), jnp.float32)
    acc_out = zeros if spec.algorithm == "decouple" else None
    cv_acc = (jnp.zeros((layout.n_flat,), jnp.float32)
              if spec.variance_reduction == "scaffold" else None)
    return StreamState(zeros, acc_out, jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32), cv_acc)


def streaming_fold(state: StreamState, chunk: Tree, is_simple: jax.Array,
                   valid: jax.Array, mask, *, algorithm: str = None,
                   layout: Optional[flatten.FlatLayout] = None,
                   flat_mask: Optional[jax.Array] = None,
                   block_n: int = 2048,
                   stream_dtype=jnp.float32,
                   wire: Optional[comm.WireSpec] = None,
                   force_pallas_interpret: bool = False,
                   cv_chunk: Optional[jax.Array] = None,
                   sparse_chunk: Optional[SparseChunk] = None) -> StreamState:
    """Fold one stacked chunk of client models into the flat sums.

    Args:
      state: the running :class:`StreamState` (from ``streaming_init`` or
        a previous fold).
      chunk: stacked client models, leaves ``(Z, *shape)``.
      is_simple: ``(Z,)`` bool — population membership per client.
      valid: ``(Z,)`` bool validity, or f32 per-client weights (validity x
        staleness coefficient — the async engine's path; see the module
        weight contract).
      mask: the :class:`EngineSpec` (preferred), or the legacy mask tree
        with the engine configuration as loose kwargs (deprecated).
      algorithm / layout / flat_mask / block_n / stream_dtype / wire:
        legacy-only loose kwargs; a spec carries its own.
      cv_chunk: optional ``(Z, n_flat)`` control-variate deltas (SCAFFOLD)
        folded into ``state.cv_acc`` with the same per-client weights and
        flat mask as the params — one extra accumulating launch, nothing
        else changes.
      sparse_chunk: delta-mode uploads (:class:`SparseChunk`; wire v2)
        REPLACING the dense ``chunk`` fold — ``chunk`` may then be
        ``None`` (the spec's layout sizes everything).  Requires a wire
        whose ``uses_deltas`` is true; the fold adds the shared base
        densely at the summed weights plus each encoded delta row
        (scatter-fold when ``indices`` is present, dequantizing/plain
        accumulate otherwise).
      force_pallas_interpret: run the kernel path in interpret mode
        (tests on CPU).

    Returns: the updated state (same shapes; ``acc`` stays f32).

    On the kernel path (TPU, or interpret mode in tests) the chunk is
    packed into one ``(Z, n_flat)`` buffer (``stream_dtype``; bf16 halves
    fold HBM traffic, accumulation stays f32) and reduced with ONE
    ``masked_agg`` launch — two for decouple, whose second accumulator uses
    ``w_out`` on both mask branches.  The CPU fallback keeps the same flat
    f32 accumulator but folds leaf by leaf into its slices (static slot
    offsets), row-streaming the cohort axis — no packed ``(Z, n_flat)``
    scratch buffer and no reduce op materializes, matching the kernel's
    summation order exactly.  Invalid (NaN / padding) devices carry weight
    0 and are gated before the multiply on both paths, so they can never
    poison the accumulators.

    ``wire`` switches the fold to the communication path (core/comm.py):
    the uploads are what the fold consumes.  A bf16 wire overrides
    ``stream_dtype``; an int8 wire quantizes the packed chunk (symmetric
    per-group, ``wire.quant_block`` elements per f32 scale — the kernel
    path packs the chunk to f32 first, the client-side encode, so the
    fold's peak temp matches the unquantized path) and folds it with the
    *dequantizing* accumulate — ``masked_agg_acc_deq`` on the kernel path,
    its XLA ref per leaf slice on CPU — so the *server side* never
    materializes a dequantized f32 copy of the uploads.  Quantization
    grouping is identical on both paths (groups never cross slots because
    ``quant_block`` divides the lane alignment).
    """
    if isinstance(mask, EngineSpec):
        spec = mask
    else:
        spec = _legacy_spec(
            "streaming_fold(..., mask, algorithm=...)", algorithm=algorithm,
            mask=mask, layout=layout, flat_mask=flat_mask, block_n=block_n,
            stream_dtype=stream_dtype, wire=wire)
    mask, layout, flat_mask = spec.mask, spec.layout, spec.flat_mask
    block_n, stream_dtype, wire = spec.block_n, spec.stream_dtype, spec.wire
    w_in, w_out = _chunk_weights(is_simple, valid, spec.algorithm)
    layout = _layout_for(chunk, layout, block_n, stacked=True)
    quantized = wire is not None and wire.is_quantized
    if wire is not None and not wire.is_identity and not quantized:
        stream_dtype = wire.payload_dtype      # bf16 wire == bf16 stream
    if sparse_chunk is not None:
        if wire is None or not wire.uses_deltas:
            raise ValueError("sparse_chunk requires a delta-mode wire "
                             "(topk_frac < 1, stochastic or error_feedback)")
        if flat_mask is None:
            flat_mask = flatten.pack_mask(layout, mask)
        acc = _fold_sparse(state.acc, sparse_chunk, flat_mask, w_in, w_out,
                           quant_block=wire.quant_block, block_n=block_n,
                           force_pallas_interpret=force_pallas_interpret)
        acc_out = state.acc_out
        if acc_out is not None:                # decouple reuses the upload
            acc_out = _fold_sparse(
                acc_out, sparse_chunk, flat_mask, w_out, w_out,
                quant_block=wire.quant_block, block_n=block_n,
                force_pallas_interpret=force_pallas_interpret)
    elif force_pallas_interpret or agg_ops.use_pallas():
        if flat_mask is None:
            flat_mask = flatten.pack_mask(layout, mask)
        if quantized:
            xz = flatten.pack_stacked(layout, chunk, dtype=jnp.float32)
            q, scales = comm.quantize(xz, wire.quant_block)
            deq = functools.partial(
                agg_ops.masked_agg_acc_deq_pallas, q=q, scales=scales,
                mask=flat_mask, quant_block=wire.quant_block,
                block_n=block_n, interpret=force_pallas_interpret)
            acc = deq(state.acc, w_m=w_in, w_rest=w_out)
            acc_out = state.acc_out
            if acc_out is not None:            # decouple reuses the upload
                acc_out = deq(acc_out, w_m=w_out, w_rest=w_out)
        else:
            xz = flatten.pack_stacked(layout, chunk, dtype=stream_dtype)
            acc = agg_ops.masked_agg_acc_pallas(
                state.acc, xz, flat_mask, w_in, w_out, block_n=block_n,
                interpret=force_pallas_interpret)
            acc_out = state.acc_out
            if acc_out is not None:
                acc_out = agg_ops.masked_agg_acc_pallas(
                    acc_out, xz, flat_mask, w_out, w_out, block_n=block_n,
                    interpret=force_pallas_interpret)
    elif quantized:
        acc = _fold_leaves_into_flat_deq(state.acc, chunk, mask, layout,
                                         w_in, w_out, wire.quant_block)
        acc_out = state.acc_out
        if acc_out is not None:
            acc_out = _fold_leaves_into_flat_deq(
                acc_out, chunk, mask, layout, w_out, w_out,
                wire.quant_block)
    else:
        acc = _fold_leaves_into_flat(state.acc, chunk, mask, layout,
                                     w_in, w_out, stream_dtype)
        acc_out = state.acc_out
        if acc_out is not None:
            acc_out = _fold_leaves_into_flat(acc_out, chunk, mask, layout,
                                             w_out, w_out, stream_dtype)
    cv_acc = state.cv_acc
    if cv_chunk is not None:
        if cv_acc is None:
            raise ValueError("cv_chunk passed but the stream state has no "
                             "cv accumulator (init with a SCAFFOLD spec)")
        if flat_mask is None:                  # CPU path never packed one
            flat_mask = flatten.pack_mask(layout, mask)
        cv_acc = _fold_cv(cv_acc, cv_chunk, flat_mask, w_in, w_out,
                          block_n=block_n,
                          force_pallas_interpret=force_pallas_interpret)
    return StreamState(acc, acc_out, state.tot_in + jnp.sum(w_in),
                       state.tot_out + jnp.sum(w_out), cv_acc)


def _fold_sparse(acc: jax.Array, sp: SparseChunk, flat_mask: jax.Array,
                 w_in: jax.Array, w_out: jax.Array, *, quant_block: int,
                 block_n: int,
                 force_pallas_interpret: bool = False) -> jax.Array:
    """Fold one delta-mode chunk: ``sum_z w[z] * (base + d_hat[z])``
    rewritten as ``(sum_z w[z]) * base + sum_z w[z] * d_hat[z]``.

    The base term is ONE Z=1 masked accumulate at the summed weights
    (base is the server broadcast — always finite, so summed weights
    need no per-client NaN gating; an all-invalid chunk sums to weight
    0 and contributes nothing).  The delta term dispatches on payload
    shape: compacted index+value rows go through the scatter-fold
    kernel/ref, dense rows through the dequantizing (int8) or plain
    (bf16/f32) accumulate — same NaN/pad weight gating as every fold."""
    kernel = force_pallas_interpret or agg_ops.use_pallas()
    base = sp.base.astype(jnp.float32)[None, :]
    sw_in, sw_out = jnp.sum(w_in)[None], jnp.sum(w_out)[None]
    if kernel:
        acc = agg_ops.masked_agg_acc_pallas(
            acc, base, flat_mask, sw_in, sw_out, block_n=block_n,
            interpret=force_pallas_interpret)
    else:
        acc = agg_ops.masked_agg_acc_ref(acc, base, flat_mask, sw_in, sw_out)
    if sp.indices is not None:
        if kernel:
            return agg_ops.masked_scatter_acc_pallas(
                acc, sp.values, sp.scales, sp.indices, flat_mask, w_in,
                w_out, quant_block=quant_block, block_n=block_n,
                interpret=force_pallas_interpret)
        return agg_ops.masked_scatter_acc_ref(
            acc, sp.values, sp.scales, sp.indices, flat_mask, w_in, w_out,
            quant_block=quant_block)
    if sp.scales is not None:                  # dense int8 delta payload
        if kernel:
            return agg_ops.masked_agg_acc_deq_pallas(
                acc, sp.values, sp.scales, flat_mask, w_in, w_out,
                quant_block=quant_block, block_n=block_n,
                interpret=force_pallas_interpret)
        return agg_ops.masked_agg_acc_deq_ref(
            acc, sp.values, sp.scales, flat_mask, w_in, w_out,
            quant_block=quant_block)
    vals = sp.values.astype(jnp.float32)       # dense bf16/f32 delta payload
    if kernel:
        return agg_ops.masked_agg_acc_pallas(
            acc, vals, flat_mask, w_in, w_out, block_n=block_n,
            interpret=force_pallas_interpret)
    return agg_ops.masked_agg_acc_ref(acc, vals, flat_mask, w_in, w_out)


def _fold_cv(cv_acc: jax.Array, cv_chunk: jax.Array, flat_mask: jax.Array,
             w_in: jax.Array, w_out: jax.Array, *, block_n: int,
             force_pallas_interpret: bool = False) -> jax.Array:
    """Fold a ``(Z, n_flat)`` control-variate delta chunk into the running
    cv sum — the identical masked accumulate launch the params take, so
    SCAFFOLD rides the kernel (and its weight-0 NaN gating) for free.
    Control variates are born flat (they ARE FlatLayout vectors), so this
    path is shared by the flat AND tree engines."""
    cv32 = cv_chunk.astype(jnp.float32)
    if force_pallas_interpret or agg_ops.use_pallas():
        return agg_ops.masked_agg_acc_pallas(
            cv_acc, cv32, flat_mask, w_in, w_out, block_n=block_n,
            interpret=force_pallas_interpret)
    return agg_ops.masked_agg_acc_ref(cv_acc, cv32, flat_mask, w_in, w_out)


def _fold_leaves_into_flat(acc: jax.Array, chunk: Tree, mask: Tree,
                           layout: flatten.FlatLayout, w_m: jax.Array,
                           w_rest: jax.Array, stream_dtype) -> jax.Array:
    """CPU lowering of the flat fold: per-leaf gated sums accumulated into
    the flat accumulator's static slices (in-place dynamic-update-slices),
    without materializing the packed ``(Z, n_flat)`` buffer."""
    for x, m, slot in zip(jax.tree.leaves(chunk), jax.tree.leaves(mask),
                          layout.slots):
        z = x.shape[0]
        body = x.reshape(z, -1).astype(stream_dtype)
        m_flat = jnp.broadcast_to(jnp.asarray(m), x.shape[1:]).reshape(-1)
        seg = jax.lax.dynamic_slice_in_dim(acc, slot.offset, slot.size)
        seg = agg_ops.masked_agg_acc_ref(seg, body, m_flat, w_m, w_rest)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, seg, slot.offset, 0)
    return acc


def _fold_leaves_into_flat_deq(acc: jax.Array, chunk: Tree, mask: Tree,
                               layout: flatten.FlatLayout, w_m: jax.Array,
                               w_rest: jax.Array, quant_block: int
                               ) -> jax.Array:
    """CPU lowering of the quantized fold: each leaf slice is quantized to
    the wire format (padded to the slot's aligned extent so scale groups
    match the packed-buffer path element for element) and folded with the
    dequantizing ref — XLA fuses quantize -> dequant -> FMA per leaf, so
    no f32 copy of the whole chunk materializes."""
    for x, m, slot in zip(jax.tree.leaves(chunk), jax.tree.leaves(mask),
                          layout.slots):
        z = x.shape[0]
        body = x.reshape(z, -1).astype(jnp.float32)
        m_flat = jnp.broadcast_to(jnp.asarray(m), x.shape[1:]).reshape(-1)
        if slot.padded != slot.size:
            body = jnp.pad(body, ((0, 0), (0, slot.padded - slot.size)))
            m_flat = jnp.pad(m_flat, (0, slot.padded - slot.size))
        q, scales = comm.quantize(body, quant_block)
        seg = jax.lax.dynamic_slice_in_dim(acc, slot.offset, slot.padded)
        seg = agg_ops.masked_agg_acc_deq_ref(seg, q, scales, m_flat,
                                             w_m, w_rest,
                                             quant_block=quant_block)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, seg, slot.offset, 0)
    return acc


def streaming_finalize(state: StreamState, mask, template: Tree = None, *,
                       algorithm: str = None,
                       layout: Optional[flatten.FlatLayout] = None,
                       flat_mask: Optional[jax.Array] = None,
                       block_n: int = 2048) -> Tuple[Tree, Optional[Tree]]:
    """Normalize the flat sums, unpack to trees, cast to ``template`` dtypes.

    Args:
      state: the fully folded :class:`StreamState`.
      mask: the :class:`EngineSpec` (preferred) or the legacy mask tree
        (deprecated, with the engine configuration as loose kwargs).
      template: tree providing the output leaf dtypes (shapes come from
        the layout; ``ShapeDtypeStruct`` leaves are fine).
      algorithm / layout / flat_mask / block_n: legacy-only loose kwargs.

    Returns: ``(new_complex, new_simple_host)``; the host is ``None`` except
    for decouple (matching ``ServerState``).  A group with zero total weight
    yields zeros, like ``_norm_weights`` in the one-shot path.
    ``state.cv_acc`` is deliberately NOT normalized here — SCAFFOLD's
    server update divides the raw delta sum by N_devices, not by the
    cohort weight totals (the round owns that step).
    """
    if isinstance(mask, EngineSpec):
        spec = mask
    else:
        spec = _legacy_spec(
            "streaming_finalize(state, mask, template, algorithm=...)",
            algorithm=algorithm, mask=mask, layout=layout,
            flat_mask=flat_mask, block_n=block_n)
    mask, layout, flat_mask = spec.mask, spec.layout, spec.flat_mask
    layout = _layout_for(template, layout, spec.block_n)
    if flat_mask is None:
        flat_mask = flatten.pack_mask(layout, mask)
    inv_in, inv_out = _safe_inv(state.tot_in), _safe_inv(state.tot_out)
    cast = lambda tree: jax.tree.map(
        lambda a, t: a.astype(t.dtype), tree, template)
    combined_flat = state.acc * jnp.where(flat_mask, inv_in, inv_out)
    combined = cast(flatten.unpack(layout, combined_flat, cast=False))
    if spec.algorithm == "decouple":
        new_complex = cast(flatten.unpack(layout, state.acc_out * inv_out,
                                          cast=False))
        return new_complex, combined
    return combined, None


def make_engine(engine, *, algorithm: str = None, mask: Tree = None,
                layout: Optional[flatten.FlatLayout] = None,
                flat_mask: Optional[jax.Array] = None,
                block_n: int = 2048, stream_dtype=jnp.float32,
                wire: Optional[comm.WireSpec] = None
                ) -> Tuple[Callable, Callable, Callable]:
    """The ``(init, fold, finalize)`` triple for a fold engine.

    The single dispatch point every consumer (the trainer's round, the
    launch-side round step, benchmarks) binds its engine through, so the
    flat/tree plumbing cannot drift between call sites:

    * ``init(params_like) -> state``
    * ``fold(state, chunk, is_simple, valid[, cv_chunk=...]) -> state``
    * ``finalize(state, template=...) -> (new_complex, simple_host)``

    Args:
      engine: an :class:`EngineSpec` (preferred) — the loose
        ``engine-string + kwargs`` form is deprecated and shimmed through
        the same spec, so both build literally identical programs.

    The spec's ``wire`` routes the fold through the communication path
    (the uploads are what the server folds): bf16 wires ride the stream
    dtype, int8 wires use the dequantizing accumulate — flat engine only
    (the tree engine predates the wire layer; FedConfig and the spec both
    enforce the pairing).
    """
    if isinstance(engine, EngineSpec):
        spec = engine
    else:
        spec = _legacy_spec(
            "make_engine(engine, algorithm=..., mask=...)", engine=engine,
            algorithm=algorithm, mask=mask, layout=layout,
            flat_mask=flat_mask, block_n=block_n, stream_dtype=stream_dtype,
            wire=wire)
    if spec.engine == "tree" and spec.wire is not None \
            and not spec.wire.is_identity:
        spec = spec.bind(stream_dtype=spec.wire.payload_dtype)
    if spec.engine == "flat":
        init = functools.partial(streaming_init, algorithm=spec)
        fold = functools.partial(streaming_fold, mask=spec)
        finalize = functools.partial(streaming_finalize, mask=spec)
    else:
        init = functools.partial(tree_streaming_init, algorithm=spec)
        fold = functools.partial(tree_streaming_fold, mask=spec)
        finalize = functools.partial(tree_streaming_finalize, mask=spec)
    return init, fold, finalize


def engine_attrs(engine, *, algorithm: str = None, block_n: int = None,
                 stream_dtype=jnp.float32,
                 wire: Optional[comm.WireSpec] = None) -> dict:
    """Static description of a configured fold engine, as plain scalars.

    What the telemetry ``run_config`` ledger records about the
    aggregation path — computed next to :func:`make_engine`'s dispatch so
    the recorded configuration cannot drift from the one that runs.
    Takes an :class:`EngineSpec` (preferred) or the deprecated loose
    kwargs.
    """
    if isinstance(engine, EngineSpec):
        spec = engine
    else:
        spec = _legacy_spec(
            "engine_attrs(engine, algorithm=..., block_n=...)",
            engine=engine, algorithm=algorithm,
            block_n=2048 if block_n is None else block_n)
        spec = spec.bind(stream_dtype=stream_dtype, wire=wire)
    attrs = {
        "agg_engine": spec.engine,
        "algorithm": spec.algorithm,
        "agg_block_n": int(spec.block_n),
        "agg_stream_dtype": str(jnp.dtype(spec.stream_dtype)),
        "variance_reduction": spec.variance_reduction,
    }
    if spec.wire is not None:
        attrs.update({
            "wire_dtype": str(spec.wire.payload_dtype),
            "wire_quantized": bool(spec.wire.is_quantized),
            "wire_quant_block": int(spec.wire.quant_block)
            if spec.wire.is_quantized else 0,
            "wire_topk_frac": float(spec.wire.topk_frac),
            "wire_stochastic": bool(spec.wire.stochastic),
            "wire_error_feedback": bool(spec.wire.error_feedback),
        })
    return attrs


# ---------------------------------------------------------------------------
# Tree streaming aggregation (PR 2 per-leaf engine — parity reference)
# ---------------------------------------------------------------------------

class TreeStreamState(NamedTuple):
    """Per-leaf analogue of ``StreamState``: ``acc``/``acc_out`` are f32
    *trees* shaped like one complex model (one ``masked_agg`` launch per
    leaf at fold time).  ``cv_acc`` stays FLAT even here — control
    variates are FlatLayout vectors on every engine (that is the point
    of the parity: flat-vs-tree must agree on the cv sum bit for bit)."""
    acc: Tree
    acc_out: Optional[Tree]
    tot_in: jax.Array
    tot_out: jax.Array
    cv_acc: Optional[jax.Array] = None


def tree_streaming_init(params_like: Tree, algorithm) -> TreeStreamState:
    """Zero accumulators shaped like one (unstacked) complex model.
    ``algorithm``: an :class:`EngineSpec` (preferred) or a legacy
    algorithm string (deprecated)."""
    spec = algorithm if isinstance(algorithm, EngineSpec) else _legacy_spec(
        "tree_streaming_init(params_like, algorithm)", engine="tree",
        algorithm=algorithm)
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                         params_like)
    acc_out = zeros if spec.algorithm == "decouple" else None
    cv_acc = None
    if spec.variance_reduction == "scaffold":
        if spec.layout is None:
            raise ValueError("SCAFFOLD on the tree engine needs the spec's "
                             "layout (the cv accumulator is flat)")
        cv_acc = jnp.zeros((spec.layout.n_flat,), jnp.float32)
    return TreeStreamState(zeros, acc_out, jnp.zeros((), jnp.float32),
                           jnp.zeros((), jnp.float32), cv_acc)


def tree_streaming_fold(state: TreeStreamState, chunk: Tree,
                        is_simple: jax.Array, valid: jax.Array, mask,
                        *, algorithm: str = None, block_n: int = 2048,
                        stream_dtype=jnp.float32,
                        force_pallas_interpret: bool = False,
                        cv_chunk: Optional[jax.Array] = None
                        ) -> TreeStreamState:
    """Fold one stacked chunk into per-leaf sums: one ``masked_agg`` kernel
    call per leaf on TPU (the pre-flat engine, kept for parity).

    ``mask``: the :class:`EngineSpec` (preferred) or the legacy mask tree
    (deprecated).  ``stream_dtype`` mirrors the flat fold's streaming
    precision: inputs are rounded to it before the f32 accumulation, so a
    flat-vs-tree comparison at bf16 compares like with like.  ``cv_chunk``
    (SCAFFOLD) folds through the same flat cv path as the flat engine —
    see :func:`_fold_cv`."""
    if isinstance(mask, EngineSpec):
        spec = mask
    else:
        spec = _legacy_spec(
            "tree_streaming_fold(..., mask, algorithm=...)", engine="tree",
            algorithm=algorithm, mask=mask, block_n=block_n,
            stream_dtype=stream_dtype)
    w_in, w_out = _chunk_weights(is_simple, valid, spec.algorithm)
    chunk32 = jax.tree.map(
        lambda x: x.astype(spec.stream_dtype).astype(jnp.float32), chunk)
    part = agg_ops.masked_agg_tree(
        chunk32, spec.mask, w_in, w_out, block_n=spec.block_n,
        force_pallas_interpret=force_pallas_interpret)
    acc = jax.tree.map(jnp.add, state.acc, part)
    acc_out = state.acc_out
    if acc_out is not None:
        acc_out = jax.tree.map(
            lambda a, x: a + _gated_wsum_leaf(x, w_out), acc_out, chunk32)
    cv_acc = state.cv_acc
    if cv_chunk is not None:
        if cv_acc is None:
            raise ValueError("cv_chunk passed but the stream state has no "
                             "cv accumulator (init with a SCAFFOLD spec)")
        flat_mask = spec.flat_mask
        if flat_mask is None:
            flat_mask = flatten.pack_mask(spec.layout, spec.mask)
        cv_acc = _fold_cv(cv_acc, cv_chunk, flat_mask, w_in, w_out,
                          block_n=spec.block_n,
                          force_pallas_interpret=force_pallas_interpret)
    return TreeStreamState(acc, acc_out, state.tot_in + jnp.sum(w_in),
                           state.tot_out + jnp.sum(w_out), cv_acc)


def tree_streaming_finalize(state: TreeStreamState, mask,
                            template: Tree = None, *, algorithm: str = None
                            ) -> Tuple[Tree, Optional[Tree]]:
    """Normalize the per-leaf sums into server models (tree engine).
    ``mask``: the :class:`EngineSpec` (preferred) or the legacy mask
    tree (deprecated)."""
    if isinstance(mask, EngineSpec):
        spec = mask
    else:
        spec = _legacy_spec(
            "tree_streaming_finalize(state, mask, template, "
            "algorithm=...)", engine="tree", algorithm=algorithm, mask=mask)
    mask, algorithm = spec.mask, spec.algorithm
    def safe_div(tree, tot):
        inv = _safe_inv(tot)
        return jax.tree.map(lambda a: a * inv, tree)

    mean_in = safe_div(state.acc, state.tot_in)
    mean_out = safe_div(state.acc, state.tot_out)
    cast = lambda tree: jax.tree.map(
        lambda a, t: a.astype(t.dtype), tree, template)
    combined = cast(masking.where_mask(mask, mean_in, mean_out))
    if algorithm == "decouple":
        new_complex = cast(safe_div(state.acc_out, state.tot_out))
        return new_complex, combined
    return combined, None
