"""Server aggregation — FedHeN Alg. 1 ln. 16-22, plus NoSide and Decouple.

All three operate on a *stacked cohort*: client models share the complex
treedef with a leading cohort axis ``Z``.  Simple clients' complex-only
slices are carried untouched (they are weighted out by the masks), so one
stacked representation serves every algorithm.

The hot path — a weighted masked mean over the cohort axis — is exactly the
``masked_agg`` Pallas kernel's contract; the XLA path here is its reference
semantics (and what the dry-run lowers, since Pallas cannot lower on the CPU
backend).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import masking

Tree = Any


def _wmean(stacked: Tree, weights: jax.Array) -> Tree:
    """Weighted mean over leading cohort axis.  weights: (Z,) already
    normalized (sums to 1 over the intended group)."""
    def leaf(x):
        w = weights.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
        # gate before multiplying: a NaN device with weight 0 must not
        # poison the sum (paper's NaN-device exclusion)
        xf = jnp.where(w > 0, x.astype(jnp.float32), 0.0)
        return jnp.sum(xf * w, axis=0).astype(x.dtype)
    return jax.tree.map(leaf, stacked)


def _norm_weights(raw: jax.Array) -> jax.Array:
    total = jnp.sum(raw)
    return jnp.where(total > 0, raw / jnp.maximum(total, 1e-12),
                     jnp.zeros_like(raw))


def fedhen_server_update(cohort: Tree, is_simple: jax.Array,
                         valid: jax.Array, mask: Tree) -> Tree:
    """FedHeN / NoSide server step (they share it — paper Appendix A).

    cohort: stacked client models (Z, ...) in complex structure.
    is_simple: (Z,) bool; valid: (Z,) bool (NaN-device exclusion).
    mask: index-set-M mask tree.

    Returns the new complex server model; the simple server model is its
    M-slice by construction (invariant tested in tests/test_aggregate.py).
    """
    valid_f = valid.astype(jnp.float32)
    w_all = _norm_weights(valid_f)                          # ln. 18: 1/|Z|
    w_complex = _norm_weights(valid_f * (~is_simple))       # ln. 22: 1/|Z_c|
    mean_all = _wmean(cohort, w_all)
    mean_complex = _wmean(cohort, w_complex)
    # ln. 18-20: M slice <- mean over ALL devices; ln. 22: M' <- complex mean
    return masking.where_mask(mask, mean_all, mean_complex)


def decouple_server_update(cohort: Tree, is_simple: jax.Array,
                           valid: jax.Array, mask: Tree) -> Tree:
    """Decouple (Alg. 3): two independent FedAvg runs in one stacked tree.

    M slice <- mean over simple devices only; M' <- mean over complex only.
    (The simple server model lives in the M slice; the complex server model's
    M slice is tracked separately by the caller — see ``ServerState``.)
    """
    valid_f = valid.astype(jnp.float32)
    w_simple = _norm_weights(valid_f * is_simple)
    w_complex = _norm_weights(valid_f * (~is_simple))
    mean_simple = _wmean(cohort, w_simple)
    mean_complex = _wmean(cohort, w_complex)
    return masking.where_mask(mask, mean_simple, mean_complex), mean_complex


def masked_cohort_mean(cohort: Tree, weights_m: jax.Array,
                       weights_rest: jax.Array, mask: Tree) -> Tree:
    """General primitive: different cohort weights inside/outside M.

    This is the op the ``masked_agg`` kernel implements on TPU.
    """
    mean_m = _wmean(cohort, weights_m)
    mean_rest = _wmean(cohort, weights_rest)
    return masking.where_mask(mask, mean_m, mean_rest)
