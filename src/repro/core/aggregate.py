"""Server aggregation — FedHeN Alg. 1 ln. 16-22, plus NoSide and Decouple.

All three operate on a *stacked cohort*: client models share the complex
treedef with a leading cohort axis ``Z``.  Simple clients' complex-only
slices are carried untouched (they are weighted out by the masks), so one
stacked representation serves every algorithm.

Three entry points:

* One-shot (``fedhen_server_update`` / ``decouple_server_update``): the
  whole cohort is stacked and reduced at once.  Reference semantics — the
  parity oracle every streaming engine is tested against.
* Flat streaming (``streaming_init`` / ``streaming_fold`` /
  ``streaming_finalize``) — THE production fold.  ``StreamState`` carries
  one flat f32 accumulator vector (plus one more for decouple): each
  trained chunk is packed into a single contiguous ``(Z, n_flat)`` buffer
  by the trainer's static ``core.flatten.FlatLayout`` and folded with ONE
  accumulating ``masked_agg`` launch (``input_output_aliases`` updates the
  running sum in place on TPU), against one precomputed flat mask
  bitvector.  Chunks may stream in bf16; accumulation is always f32.
  Under a wire format (``core/comm.py``) the fold consumes the *encoded
  uploads* — int8 payloads fold through the dequantizing accumulate
  variant, never materializing an f32 copy of the chunk.
  Unpacking back to the parameter tree happens once, at finalize.

  **Flat layout contract**: the layout's offsets are static per (treedef,
  leaf shapes, align, block_n) — built once per trainer and valid for
  every round.  Per-element results match the tree path exactly up to
  float summation order across kernel tile boundaries (the cohort axis is
  reduced in the same order per lane).
* Tree streaming (``tree_streaming_init`` / ``tree_streaming_fold`` /
  ``tree_streaming_finalize``): the PR 2 per-leaf engine (one
  ``masked_agg`` launch per leaf), kept as the streaming parity reference
  and selectable via ``FedConfig.agg_engine="tree"``.

Both streaming engines fold chunks into running *unnormalized* masked sums
plus two scalar weight totals; the division happens once at finalize, so
server memory is O(chunk) and the result matches the one-shot path up to
float summation order.

**Weight contract.**  ``valid`` is a per-client coefficient, not just a
bool: a bool marks plain validity (NaN exclusion, padding), while a float
carries validity *times* any per-client coefficient — the asynchronous
engine (``core/async_rounds.py``) multiplies its staleness decay
``1/(1+s)^a`` into it, so staleness weighting rides the exact same masked
weight path as NaN/padding exclusion and needs no second code path.  A
weight of 0 gates the client's values before the multiply on every path
(a NaN device at weight 0 can never poison the sums), and all-1 float
weights are bit-identical to bool validity.

The hot path — a weighted masked sum over the cohort axis — is exactly the
``masked_agg`` Pallas kernel's contract; the folds dispatch to it on TPU
via ``kernels/masked_agg/ops.py``, with the XLA reference as the CPU
fallback (what the dry-run lowers, since Pallas cannot lower on the CPU
backend).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import comm, flatten, masking
from repro.kernels.masked_agg import ops as agg_ops

Tree = Any

ALGORITHMS = ("fedhen", "noside", "decouple")


def _gated_wsum_leaf(x: jax.Array, weights: jax.Array) -> jax.Array:
    """f32 weighted sum of one stacked leaf over the cohort axis.

    Gates before multiplying: a NaN device with weight 0 must not poison
    the sum (paper's NaN-device exclusion)."""
    w = weights.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
    xf = jnp.where(w > 0, x.astype(jnp.float32), 0.0)
    return jnp.sum(xf * w, axis=0)


def _wmean(stacked: Tree, weights: jax.Array) -> Tree:
    """Weighted mean over leading cohort axis.  weights: (Z,) already
    normalized (sums to 1 over the intended group)."""
    return jax.tree.map(
        lambda x: _gated_wsum_leaf(x, weights).astype(x.dtype), stacked)


def _norm_weights(raw: jax.Array) -> jax.Array:
    total = jnp.sum(raw)
    return jnp.where(total > 0, raw / jnp.maximum(total, 1e-12),
                     jnp.zeros_like(raw))


def fedhen_server_update(cohort: Tree, is_simple: jax.Array,
                         valid: jax.Array, mask: Tree) -> Tree:
    """FedHeN / NoSide server step (they share it — paper Appendix A).

    cohort: stacked client models (Z, ...) in complex structure.
    is_simple: (Z,) bool; valid: (Z,) bool (NaN-device exclusion).
    mask: index-set-M mask tree.

    Returns the new complex server model; the simple server model is its
    M-slice by construction (invariant tested in tests/test_aggregate.py).
    """
    valid_f = valid.astype(jnp.float32)
    w_all = _norm_weights(valid_f)                          # ln. 18: 1/|Z|
    w_complex = _norm_weights(valid_f * (~is_simple))       # ln. 22: 1/|Z_c|
    mean_all = _wmean(cohort, w_all)
    mean_complex = _wmean(cohort, w_complex)
    # ln. 18-20: M slice <- mean over ALL devices; ln. 22: M' <- complex mean
    return masking.where_mask(mask, mean_all, mean_complex)


def decouple_server_update(cohort: Tree, is_simple: jax.Array,
                           valid: jax.Array, mask: Tree) -> Tree:
    """Decouple (Alg. 3): two independent FedAvg runs in one stacked tree.

    M slice <- mean over simple devices only; M' <- mean over complex only.
    (The simple server model lives in the M slice; the complex server model's
    M slice is tracked separately by the caller — see ``ServerState``.)
    """
    valid_f = valid.astype(jnp.float32)
    w_simple = _norm_weights(valid_f * is_simple)
    w_complex = _norm_weights(valid_f * (~is_simple))
    mean_simple = _wmean(cohort, w_simple)
    mean_complex = _wmean(cohort, w_complex)
    return masking.where_mask(mask, mean_simple, mean_complex), mean_complex


def masked_cohort_mean(cohort: Tree, weights_m: jax.Array,
                       weights_rest: jax.Array, mask: Tree) -> Tree:
    """General primitive: different cohort weights inside/outside M.

    This is the op the ``masked_agg`` kernel implements on TPU.
    """
    mean_m = _wmean(cohort, weights_m)
    mean_rest = _wmean(cohort, weights_rest)
    return masking.where_mask(mask, mean_m, mean_rest)


# ---------------------------------------------------------------------------
# Shared streaming helpers
# ---------------------------------------------------------------------------

def _chunk_weights(is_simple: jax.Array, valid: jax.Array,
                   algorithm: str) -> Tuple[jax.Array, jax.Array]:
    """Raw (unnormalized) per-client weights of one chunk.

    ``valid`` may be bool (plain validity) or float (validity x any
    per-client coefficient, e.g. the async engine's staleness decay) —
    see the module's weight contract.  ``w_in`` weights the inside-M
    accumulator: every valid device for fedhen/noside (Alg. 1 ln. 18),
    simple devices only for decouple.  ``w_out`` weights outside M:
    complex devices only (ln. 22), for all three algorithms.
    """
    if algorithm not in ALGORITHMS:
        raise ValueError(algorithm)
    valid_f = valid.astype(jnp.float32)
    w_in = valid_f * is_simple if algorithm == "decouple" else valid_f
    w_out = valid_f * (~is_simple)
    return w_in, w_out


def _safe_inv(tot: jax.Array) -> jax.Array:
    """1/tot with the zero-weight-group guard (0 -> 0, never NaN)."""
    return jnp.where(tot > 0, 1.0 / jnp.maximum(tot, 1e-12), 0.0)


# ---------------------------------------------------------------------------
# Flat streaming aggregation (the production fold)
# ---------------------------------------------------------------------------

class StreamState(NamedTuple):
    """Running sums of a chunked server aggregation (a jit/scan carry).

    ``acc`` is ONE flat f32 vector of *unnormalized* masked sums over the
    trainer's ``FlatLayout``: inside M each element accumulates
    ``sum_z w_in[z] * x[z]``, outside M ``sum_z w_out[z] * x[z]`` — exactly
    one accumulating ``masked_agg`` kernel pass per chunk, updated in place.
    ``acc_out`` (decouple only, else ``None``) additionally carries the
    *whole-vector* ``w_out`` sums, because decouple's new complex model is
    the complex-group mean everywhere, including inside M.  ``tot_in`` /
    ``tot_out`` are the scalar weight totals the finalize divides by.
    """
    acc: jax.Array
    acc_out: Optional[jax.Array]
    tot_in: jax.Array
    tot_out: jax.Array


def _layout_for(tree: Tree, layout, block_n: int, *, stacked: bool = False):
    if layout is not None:
        return layout
    return flatten.layout_of(tree, total_multiple=block_n, stacked=stacked)


def streaming_init(params_like: Tree, algorithm: str, *,
                   layout: Optional[flatten.FlatLayout] = None,
                   block_n: int = 2048) -> StreamState:
    """Zero flat accumulators for one round of streaming aggregation.

    Args:
      params_like: ONE (unstacked) complex model tree — only shapes are
        read, to size the flat accumulator.
      algorithm: one of :data:`ALGORITHMS` (decouple allocates the second
        accumulator).
      layout / block_n: must match the subsequent folds (the trainer
        passes its one static layout everywhere).

    Returns: a :class:`StreamState` of f32 zeros (``(n_flat,)`` acc(s) +
    two scalar weight totals)."""
    if algorithm not in ALGORITHMS:
        raise ValueError(algorithm)
    layout = _layout_for(params_like, layout, block_n)
    zeros = jnp.zeros((layout.n_flat,), jnp.float32)
    acc_out = zeros if algorithm == "decouple" else None
    return StreamState(zeros, acc_out, jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32))


def streaming_fold(state: StreamState, chunk: Tree, is_simple: jax.Array,
                   valid: jax.Array, mask: Tree, *, algorithm: str,
                   layout: Optional[flatten.FlatLayout] = None,
                   flat_mask: Optional[jax.Array] = None,
                   block_n: int = 2048,
                   stream_dtype=jnp.float32,
                   wire: Optional[comm.WireSpec] = None,
                   force_pallas_interpret: bool = False) -> StreamState:
    """Fold one stacked chunk of client models into the flat sums.

    Args:
      state: the running :class:`StreamState` (from ``streaming_init`` or
        a previous fold).
      chunk: stacked client models, leaves ``(Z, *shape)``.
      is_simple: ``(Z,)`` bool — population membership per client.
      valid: ``(Z,)`` bool validity, or f32 per-client weights (validity x
        staleness coefficient — the async engine's path; see the module
        weight contract).
      mask: index-set-M mask tree (ignored when ``flat_mask`` is given on
        the kernel path).
      algorithm: one of :data:`ALGORITHMS`.
      layout / flat_mask / block_n / stream_dtype / wire: the trainer's
        static fold configuration — must match across init/fold/finalize.

    Returns: the updated state (same shapes; ``acc`` stays f32).

    On the kernel path (TPU, or interpret mode in tests) the chunk is
    packed into one ``(Z, n_flat)`` buffer (``stream_dtype``; bf16 halves
    fold HBM traffic, accumulation stays f32) and reduced with ONE
    ``masked_agg`` launch — two for decouple, whose second accumulator uses
    ``w_out`` on both mask branches.  The CPU fallback keeps the same flat
    f32 accumulator but folds leaf by leaf into its slices (static slot
    offsets), row-streaming the cohort axis — no packed ``(Z, n_flat)``
    scratch buffer and no reduce op materializes, matching the kernel's
    summation order exactly.  Invalid (NaN / padding) devices carry weight
    0 and are gated before the multiply on both paths, so they can never
    poison the accumulators.

    ``wire`` switches the fold to the communication path (core/comm.py):
    the uploads are what the fold consumes.  A bf16 wire overrides
    ``stream_dtype``; an int8 wire quantizes the packed chunk (symmetric
    per-group, ``wire.quant_block`` elements per f32 scale — the kernel
    path packs the chunk to f32 first, the client-side encode, so the
    fold's peak temp matches the unquantized path) and folds it with the
    *dequantizing* accumulate — ``masked_agg_acc_deq`` on the kernel path,
    its XLA ref per leaf slice on CPU — so the *server side* never
    materializes a dequantized f32 copy of the uploads.  Quantization
    grouping is identical on both paths (groups never cross slots because
    ``quant_block`` divides the lane alignment).
    """
    w_in, w_out = _chunk_weights(is_simple, valid, algorithm)
    layout = _layout_for(chunk, layout, block_n, stacked=True)
    quantized = wire is not None and wire.is_quantized
    if wire is not None and not wire.is_identity and not quantized:
        stream_dtype = wire.payload_dtype      # bf16 wire == bf16 stream
    if force_pallas_interpret or agg_ops.use_pallas():
        if flat_mask is None:
            flat_mask = flatten.pack_mask(layout, mask)
        if quantized:
            xz = flatten.pack_stacked(layout, chunk, dtype=jnp.float32)
            q, scales = comm.quantize(xz, wire.quant_block)
            deq = functools.partial(
                agg_ops.masked_agg_acc_deq_pallas, q=q, scales=scales,
                mask=flat_mask, quant_block=wire.quant_block,
                block_n=block_n, interpret=force_pallas_interpret)
            acc = deq(state.acc, w_m=w_in, w_rest=w_out)
            acc_out = state.acc_out
            if acc_out is not None:            # decouple reuses the upload
                acc_out = deq(acc_out, w_m=w_out, w_rest=w_out)
        else:
            xz = flatten.pack_stacked(layout, chunk, dtype=stream_dtype)
            acc = agg_ops.masked_agg_acc_pallas(
                state.acc, xz, flat_mask, w_in, w_out, block_n=block_n,
                interpret=force_pallas_interpret)
            acc_out = state.acc_out
            if acc_out is not None:
                acc_out = agg_ops.masked_agg_acc_pallas(
                    acc_out, xz, flat_mask, w_out, w_out, block_n=block_n,
                    interpret=force_pallas_interpret)
    elif quantized:
        acc = _fold_leaves_into_flat_deq(state.acc, chunk, mask, layout,
                                         w_in, w_out, wire.quant_block)
        acc_out = state.acc_out
        if acc_out is not None:
            acc_out = _fold_leaves_into_flat_deq(
                acc_out, chunk, mask, layout, w_out, w_out,
                wire.quant_block)
    else:
        acc = _fold_leaves_into_flat(state.acc, chunk, mask, layout,
                                     w_in, w_out, stream_dtype)
        acc_out = state.acc_out
        if acc_out is not None:
            acc_out = _fold_leaves_into_flat(acc_out, chunk, mask, layout,
                                             w_out, w_out, stream_dtype)
    return StreamState(acc, acc_out, state.tot_in + jnp.sum(w_in),
                       state.tot_out + jnp.sum(w_out))


def _fold_leaves_into_flat(acc: jax.Array, chunk: Tree, mask: Tree,
                           layout: flatten.FlatLayout, w_m: jax.Array,
                           w_rest: jax.Array, stream_dtype) -> jax.Array:
    """CPU lowering of the flat fold: per-leaf gated sums accumulated into
    the flat accumulator's static slices (in-place dynamic-update-slices),
    without materializing the packed ``(Z, n_flat)`` buffer."""
    for x, m, slot in zip(jax.tree.leaves(chunk), jax.tree.leaves(mask),
                          layout.slots):
        z = x.shape[0]
        body = x.reshape(z, -1).astype(stream_dtype)
        m_flat = jnp.broadcast_to(jnp.asarray(m), x.shape[1:]).reshape(-1)
        seg = jax.lax.dynamic_slice_in_dim(acc, slot.offset, slot.size)
        seg = agg_ops.masked_agg_acc_ref(seg, body, m_flat, w_m, w_rest)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, seg, slot.offset, 0)
    return acc


def _fold_leaves_into_flat_deq(acc: jax.Array, chunk: Tree, mask: Tree,
                               layout: flatten.FlatLayout, w_m: jax.Array,
                               w_rest: jax.Array, quant_block: int
                               ) -> jax.Array:
    """CPU lowering of the quantized fold: each leaf slice is quantized to
    the wire format (padded to the slot's aligned extent so scale groups
    match the packed-buffer path element for element) and folded with the
    dequantizing ref — XLA fuses quantize -> dequant -> FMA per leaf, so
    no f32 copy of the whole chunk materializes."""
    for x, m, slot in zip(jax.tree.leaves(chunk), jax.tree.leaves(mask),
                          layout.slots):
        z = x.shape[0]
        body = x.reshape(z, -1).astype(jnp.float32)
        m_flat = jnp.broadcast_to(jnp.asarray(m), x.shape[1:]).reshape(-1)
        if slot.padded != slot.size:
            body = jnp.pad(body, ((0, 0), (0, slot.padded - slot.size)))
            m_flat = jnp.pad(m_flat, (0, slot.padded - slot.size))
        q, scales = comm.quantize(body, quant_block)
        seg = jax.lax.dynamic_slice_in_dim(acc, slot.offset, slot.padded)
        seg = agg_ops.masked_agg_acc_deq_ref(seg, q, scales, m_flat,
                                             w_m, w_rest,
                                             quant_block=quant_block)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, seg, slot.offset, 0)
    return acc


def streaming_finalize(state: StreamState, mask: Tree, template: Tree, *,
                       algorithm: str,
                       layout: Optional[flatten.FlatLayout] = None,
                       flat_mask: Optional[jax.Array] = None,
                       block_n: int = 2048) -> Tuple[Tree, Optional[Tree]]:
    """Normalize the flat sums, unpack to trees, cast to ``template`` dtypes.

    Args:
      state: the fully folded :class:`StreamState`.
      mask: index-set-M mask tree (``flat_mask`` preferred when given).
      template: tree providing the output leaf dtypes (shapes come from
        the layout; ``ShapeDtypeStruct`` leaves are fine).
      algorithm / layout / flat_mask / block_n: the same static fold
        configuration used by init/fold.

    Returns: ``(new_complex, new_simple_host)``; the host is ``None`` except
    for decouple (matching ``ServerState``).  A group with zero total weight
    yields zeros, like ``_norm_weights`` in the one-shot path.
    """
    layout = _layout_for(template, layout, block_n)
    if flat_mask is None:
        flat_mask = flatten.pack_mask(layout, mask)
    inv_in, inv_out = _safe_inv(state.tot_in), _safe_inv(state.tot_out)
    cast = lambda tree: jax.tree.map(
        lambda a, t: a.astype(t.dtype), tree, template)
    combined_flat = state.acc * jnp.where(flat_mask, inv_in, inv_out)
    combined = cast(flatten.unpack(layout, combined_flat, cast=False))
    if algorithm == "decouple":
        new_complex = cast(flatten.unpack(layout, state.acc_out * inv_out,
                                          cast=False))
        return new_complex, combined
    return combined, None


def make_engine(engine: str, *, algorithm: str, mask: Tree,
                layout: Optional[flatten.FlatLayout] = None,
                flat_mask: Optional[jax.Array] = None,
                block_n: int = 2048, stream_dtype=jnp.float32,
                wire: Optional[comm.WireSpec] = None
                ) -> Tuple[Callable, Callable, Callable]:
    """The ``(init, fold, finalize)`` triple for a fold engine.

    The single dispatch point every consumer (the trainer's round, the
    launch-side round step, benchmarks) binds its engine through, so the
    flat/tree plumbing cannot drift between call sites:

    * ``init(params_like) -> state``
    * ``fold(state, chunk, is_simple, valid) -> state``
    * ``finalize(state, template=...) -> (new_complex, simple_host)``

    ``wire`` routes the fold through the communication path (the uploads
    are what the server folds): bf16 wires ride the stream dtype, int8
    wires use the dequantizing accumulate — flat engine only (the tree
    engine predates the wire layer; FedConfig enforces the pairing).
    """
    if engine == "flat":
        init = functools.partial(streaming_init, algorithm=algorithm,
                                 layout=layout, block_n=block_n)
        fold = functools.partial(streaming_fold, mask=mask,
                                 algorithm=algorithm, layout=layout,
                                 flat_mask=flat_mask, block_n=block_n,
                                 stream_dtype=stream_dtype, wire=wire)
        finalize = functools.partial(streaming_finalize, mask=mask,
                                     algorithm=algorithm, layout=layout,
                                     flat_mask=flat_mask, block_n=block_n)
    elif engine == "tree":
        if wire is not None and wire.is_quantized:
            raise ValueError("int8 wire requires the flat engine "
                             "(dequantizing fold is a flat-buffer op)")
        if wire is not None and not wire.is_identity:
            stream_dtype = wire.payload_dtype
        init = functools.partial(tree_streaming_init, algorithm=algorithm)
        fold = functools.partial(tree_streaming_fold, mask=mask,
                                 algorithm=algorithm, block_n=block_n,
                                 stream_dtype=stream_dtype)
        finalize = functools.partial(tree_streaming_finalize, mask=mask,
                                     algorithm=algorithm)
    else:
        raise ValueError(f"unknown agg engine {engine!r}")
    return init, fold, finalize


def engine_attrs(engine: str, *, algorithm: str, block_n: int,
                 stream_dtype=jnp.float32,
                 wire: Optional[comm.WireSpec] = None) -> dict:
    """Static description of a configured fold engine, as plain scalars.

    What the telemetry ``run_config`` ledger records about the
    aggregation path — computed next to :func:`make_engine`'s dispatch so
    the recorded configuration cannot drift from the one that runs.
    """
    if engine not in ("flat", "tree"):
        raise ValueError(f"unknown agg engine {engine!r}")
    attrs = {
        "agg_engine": engine,
        "algorithm": algorithm,
        "agg_block_n": int(block_n),
        "agg_stream_dtype": str(jnp.dtype(stream_dtype)),
    }
    if wire is not None:
        attrs.update({
            "wire_dtype": str(wire.payload_dtype),
            "wire_quantized": bool(wire.is_quantized),
            "wire_quant_block": int(wire.quant_block)
            if wire.is_quantized else 0,
        })
    return attrs


# ---------------------------------------------------------------------------
# Tree streaming aggregation (PR 2 per-leaf engine — parity reference)
# ---------------------------------------------------------------------------

class TreeStreamState(NamedTuple):
    """Per-leaf analogue of ``StreamState``: ``acc``/``acc_out`` are f32
    *trees* shaped like one complex model (one ``masked_agg`` launch per
    leaf at fold time)."""
    acc: Tree
    acc_out: Optional[Tree]
    tot_in: jax.Array
    tot_out: jax.Array


def tree_streaming_init(params_like: Tree, algorithm: str) -> TreeStreamState:
    """Zero accumulators shaped like one (unstacked) complex model."""
    if algorithm not in ALGORITHMS:
        raise ValueError(algorithm)
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                         params_like)
    acc_out = zeros if algorithm == "decouple" else None
    return TreeStreamState(zeros, acc_out, jnp.zeros((), jnp.float32),
                           jnp.zeros((), jnp.float32))


def tree_streaming_fold(state: TreeStreamState, chunk: Tree,
                        is_simple: jax.Array, valid: jax.Array, mask: Tree,
                        *, algorithm: str, block_n: int = 2048,
                        stream_dtype=jnp.float32,
                        force_pallas_interpret: bool = False
                        ) -> TreeStreamState:
    """Fold one stacked chunk into per-leaf sums: one ``masked_agg`` kernel
    call per leaf on TPU (the pre-flat engine, kept for parity).

    ``stream_dtype`` mirrors the flat fold's streaming precision: inputs
    are rounded to it before the f32 accumulation, so a flat-vs-tree
    comparison at bf16 compares like with like."""
    w_in, w_out = _chunk_weights(is_simple, valid, algorithm)
    chunk32 = jax.tree.map(
        lambda x: x.astype(stream_dtype).astype(jnp.float32), chunk)
    part = agg_ops.masked_agg_tree(
        chunk32, mask, w_in, w_out, block_n=block_n,
        force_pallas_interpret=force_pallas_interpret)
    acc = jax.tree.map(jnp.add, state.acc, part)
    acc_out = state.acc_out
    if acc_out is not None:
        acc_out = jax.tree.map(
            lambda a, x: a + _gated_wsum_leaf(x, w_out), acc_out, chunk32)
    return TreeStreamState(acc, acc_out, state.tot_in + jnp.sum(w_in),
                           state.tot_out + jnp.sum(w_out))


def tree_streaming_finalize(state: TreeStreamState, mask: Tree,
                            template: Tree, *, algorithm: str
                            ) -> Tuple[Tree, Optional[Tree]]:
    """Normalize the per-leaf sums into server models (tree engine)."""
    def safe_div(tree, tot):
        inv = _safe_inv(tot)
        return jax.tree.map(lambda a: a * inv, tree)

    mean_in = safe_div(state.acc, state.tot_in)
    mean_out = safe_div(state.acc, state.tot_out)
    cast = lambda tree: jax.tree.map(
        lambda a, t: a.astype(t.dtype), tree, template)
    combined = cast(masking.where_mask(mask, mean_in, mean_out))
    if algorithm == "decouple":
        new_complex = cast(safe_div(state.acc_out, state.tot_out))
        return new_complex, combined
    return combined, None
