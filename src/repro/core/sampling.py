"""Host-side cohort sampling: the paper's uniform 10% draw, at any scale.

FedHeN samples participants *uniformly* — each round activates
``participation * n_devices`` clients drawn without replacement from the
whole population, whatever their architecture (paper §3).  The original
trainer approximated that with *stratified* per-population draws (k_s
simple + k_c complex every round — the expectation of the uniform draw,
chosen so jit shapes stay static).  This module supplies both modes
behind one object, and fixes two structural problems at once:

* **Purity.**  A :class:`CohortSampler` draw is a pure function of
  ``(seed, round_index)`` (each round gets its own
  ``np.random.SeedSequence([seed, round])`` stream).  The old trainer
  consumed a single sequential ``default_rng(seed)`` stream that was
  never checkpointed, so a resumed run silently replayed round 0's
  cohort sequence at round R.  A pure sampler needs no residual state:
  restoring the round counter restores the cohort sequence exactly
  (``state_dict`` carries only the identity facts the checkpoint
  validates against).

* **Scale.**  Draws cost O(cohort), not O(population): ids are drawn by
  vectorized rejection sampling (uniqueness via order-preserving
  dedupe), so a 10^6-client registry samples as fast as a 10^2 one —
  the benchmark gate in ``benchmarks/client_scale.py``.

**Uniform super-cohort mode** (``uniform=True``) recovers the paper's
exact protocol under static shapes: one draw of
``k_super = ceil(participation * n_devices)`` clients over the whole
population, routed into fixed per-architecture slot blocks of capacity
``min(k_super, population size)``.  The realized per-arch composition is
random, so unused slots are *padded* by wrapping already-drawn ids with
``real=False`` — the existing weight-0 validity path zero-weights them
in the fold and the loss normalizes by the realized count, so padding
can never bias the aggregate (exactly the chunk-padding contract in
``core/federated.py``).  At ``participation=1.0`` the two modes draw the
same (sorted, canonical) cohort, which is what the uniform-vs-stratified
bit-parity test pins.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

# SeedSequence entropy words must be non-negative ints < 2**64
_SEED_MASK = (1 << 64) - 1


def round_rng(seed: int, round_index: int) -> np.random.Generator:
    """The round's private RNG stream: pure in ``(seed, round_index)``.

    Streams of different rounds are statistically independent
    (SeedSequence hashes the entropy tuple), and no cross-round state
    exists to checkpoint — the resume bugfix is this function."""
    if round_index < 0:
        raise ValueError(f"round_index must be >= 0, got {round_index}")
    return np.random.default_rng(
        np.random.SeedSequence([seed & _SEED_MASK, round_index]))


def draw_without_replacement(rng: np.random.Generator, n: int,
                             k: int) -> np.ndarray:
    """``k`` distinct ids uniform over ``[0, n)``, sorted, in O(k) host
    time for sparse draws (k << n).

    Dense draws (k within 4x of n) fall back to a partial Fisher-Yates
    (``Generator.choice`` without replacement) — O(n), but O(n) = O(4k)
    there.  Sparse draws use batched rejection sampling: draw a batch of
    candidates, keep the first-seen occurrence of each (order-preserving
    dedupe — taking the first ``k`` of a *sorted* unique would bias
    toward small ids), and repeat on the shortfall.  Sequential
    rejection of repeats is exactly uniform sampling without
    replacement, so the result is unbiased (chi-square-tested).
    """
    if not 0 <= k <= n:
        raise ValueError(f"need 0 <= k <= n, got k={k}, n={n}")
    if k == n:
        return np.arange(n, dtype=np.int64)
    if 4 * k >= n:
        return np.sort(rng.choice(n, size=k, replace=False).astype(np.int64))
    chosen = np.empty((0,), dtype=np.int64)
    while chosen.size < k:
        need = k - chosen.size
        draw = rng.integers(0, n, size=2 * need + 8, dtype=np.int64)
        draw = draw[~np.isin(draw, chosen)]
        # order-preserving unique: first occurrence in draw order
        _, first = np.unique(draw, return_index=True)
        fresh = draw[np.sort(first)][:need]
        chosen = np.concatenate([chosen, fresh])
    return np.sort(chosen)


def _pad_to(ids: np.ndarray, capacity: int, fallback: int) -> np.ndarray:
    """Pad ``ids`` up to ``capacity`` slots by wrapping the drawn ids
    (``fallback`` when the draw is empty).  Pad slots carry real client
    data but fold at weight 0 — they exist only to keep shapes static."""
    if ids.size >= capacity:
        return ids[:capacity]
    if ids.size == 0:
        return np.full((capacity,), fallback, dtype=np.int64)
    reps = -(-capacity // ids.size)
    return np.tile(ids, reps)[:capacity]


@dataclasses.dataclass(frozen=True)
class CohortPlan:
    """One round's resolved cohort: absolute client ids routed into the
    two populations' static slot blocks, plus the per-slot reality masks
    the weight-0 validity path consumes.

    ``simple_ids`` / ``complex_ids`` have the sampler's static
    capacities; ``*_real`` marks slots holding a distinct sampled client
    (pad slots wrap a real id and must fold at weight 0)."""
    round_index: int
    simple_ids: np.ndarray
    complex_ids: np.ndarray
    simple_real: np.ndarray
    complex_real: np.ndarray

    @property
    def n_real_simple(self) -> int:
        return int(self.simple_real.sum())

    @property
    def n_real_complex(self) -> int:
        return int(self.complex_real.sum())

    @property
    def all_real(self) -> bool:
        return bool(self.simple_real.all() and self.complex_real.all())

    def real_ids(self) -> np.ndarray:
        """The round's distinct sampled clients (both populations)."""
        return np.concatenate([self.simple_ids[self.simple_real],
                               self.complex_ids[self.complex_real]])


class CohortSampler:
    """Draws one :class:`CohortPlan` per round, pure in (seed, round).

    ``uniform=False`` (stratified, the pre-existing approximation):
    ``k_s = max(round(p * n_simple), 1)`` simple ids plus
    ``k_c = max(round(p * n_complex), 1)`` complex ids, drawn
    independently per population — every slot real, every round.  The
    capacities are exactly the old trainer's, so the stratified round
    program is unchanged.

    ``uniform=True`` (the paper's protocol): ONE draw of
    ``k_super = max(ceil(p * n_devices), 1)`` ids over the whole
    population, split by architecture into slot blocks of capacity
    ``cap_simple = min(k_super, n_simple)`` /
    ``cap_complex = min(k_super, n_complex)``; unfilled slots wrap drawn
    ids with ``real=False``.  Ids are canonically sorted per population
    in both modes (the aggregation is weight-symmetric, so order is
    free — sorting makes the two modes comparable and the gather
    cache-friendly).
    """

    def __init__(self, *, n_devices: int, n_simple: int,
                 participation: float, seed: int, uniform: bool = False):
        if not 0 < n_simple < n_devices:
            raise ValueError(f"need 0 < n_simple < n_devices, got "
                             f"{n_simple} / {n_devices}")
        if not 0.0 < participation <= 1.0:
            raise ValueError(f"participation must be in (0, 1], got "
                             f"{participation}")
        self.n_devices = int(n_devices)
        self.n_simple = int(n_simple)
        self.n_complex = self.n_devices - self.n_simple
        self.participation = float(participation)
        self.seed = int(seed)
        self.uniform = bool(uniform)
        if uniform:
            self.k_super = max(int(np.ceil(participation * n_devices)), 1)
            self.cap_simple = min(self.k_super, self.n_simple)
            self.cap_complex = min(self.k_super, self.n_complex)
        else:
            self.k_super = 0
            self.cap_simple = max(int(round(participation * n_simple)), 1)
            self.cap_complex = max(int(round(participation
                                             * self.n_complex)), 1)

    def plan(self, round_index: int) -> CohortPlan:
        """The round's cohort — same ``(seed, round_index)``, same plan,
        regardless of call order or process restarts."""
        rng = round_rng(self.seed, round_index)
        if not self.uniform:
            simple = draw_without_replacement(rng, self.n_simple,
                                              self.cap_simple)
            complex_ = self.n_simple + draw_without_replacement(
                rng, self.n_complex, self.cap_complex)
            ones_s = np.ones((self.cap_simple,), dtype=bool)
            ones_c = np.ones((self.cap_complex,), dtype=bool)
            return CohortPlan(round_index, simple, complex_, ones_s, ones_c)
        ids = draw_without_replacement(rng, self.n_devices, self.k_super)
        simple = ids[ids < self.n_simple]
        complex_ = ids[ids >= self.n_simple]
        real_s = np.arange(self.cap_simple) < simple.size
        real_c = np.arange(self.cap_complex) < complex_.size
        return CohortPlan(
            round_index,
            _pad_to(simple, self.cap_simple, fallback=0),
            _pad_to(complex_, self.cap_complex, fallback=self.n_simple),
            real_s, real_c)

    # -- checkpoint integration ---------------------------------------------

    def state_dict(self) -> Dict:
        """The sampler's identity facts for checkpoint meta.  A pure
        sampler has no mutable state — these exist so a resume can
        VALIDATE that the restored run re-creates the same cohort
        sequence (same seed, mode, and geometry)."""
        return {"seed": self.seed, "uniform": self.uniform,
                "participation": self.participation,
                "n_devices": self.n_devices, "n_simple": self.n_simple}

    def validate_state(self, state: Optional[Dict]) -> None:
        """Raise if a checkpoint's sampler facts disagree with this
        sampler (a silent mismatch would change the cohort sequence
        mid-run — the exact bug class the pure sampler retires)."""
        if not state:
            return     # pre-sampler checkpoint: nothing to validate
        mine = self.state_dict()
        diffs = {k: (state[k], mine[k]) for k in mine
                 if k in state and state[k] != mine[k]}
        if diffs:
            raise ValueError(f"checkpoint sampler state mismatch: {diffs}")
