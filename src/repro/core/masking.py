"""Index set M (FedHeN Assumption 2.1) as broadcastable mask pytrees.

A *mask tree* has the same treedef as the parameter tree; each leaf is a
boolean array broadcastable against the corresponding parameter leaf:

* fully-included / fully-excluded leaves -> scalar ``True`` / ``False``
* period-stacked transformer leaves (leading axis = n_periods) -> shape
  ``(n_periods, 1, 1, ...)`` with ``True`` for periods < exit_period.

This representation makes every FedHeN tree operation a single broadcasted
``where``/multiply — which is also what the ``masked_agg`` Pallas kernel
implements for the server hot path.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Tree = Any


def _const_mask(tree: Tree, value: bool) -> Tree:
    return jax.tree.map(lambda _: jnp.asarray(value), tree)


def transformer_subnet_mask(params: Tree, cfg: ModelConfig) -> Tree:
    """M for the decoder zoo: embedding + frontend projector + blocks[:K]
    + exit head (exit_norm [+ tied unembedding via the embedding])."""
    mask: Dict[str, Tree] = {}
    for name, sub in params.items():
        if name == "periods":
            kp = cfg.exit_period
            stacks = []
            for stacked in sub:
                def leaf_mask(x):
                    m = jnp.arange(x.shape[0]) < kp
                    return m.reshape((x.shape[0],) + (1,) * (x.ndim - 1))
                stacks.append(jax.tree.map(leaf_mask, stacked))
            mask[name] = tuple(stacks)
        elif name == "rem":
            # remainder layers sit at the tail -> never in the prefix subnet
            mask[name] = _const_mask(sub, False)
        elif name in ("embed", "frontend_proj", "exit_norm"):
            mask[name] = _const_mask(sub, True)
        else:  # final_norm, unembed (untied)
            mask[name] = _const_mask(sub, False)
    return mask


def resnet_subnet_mask(params: Tree) -> Tree:
    from repro.models import resnet
    mask = {}
    for name, sub in params.items():
        keep = name in ("stem", "stage1", "stage2", "exit_head")
        mask[name] = _const_mask(sub, keep)
    return mask


# ---------------------------------------------------------------------------
# Tree ops over masks
# ---------------------------------------------------------------------------

def where_mask(mask: Tree, a: Tree, b: Tree) -> Tree:
    """leafwise: mask ? a : b."""
    return jax.tree.map(lambda m, x, y: jnp.where(m, x, y), mask, a, b)


def apply_mask(mask: Tree, tree: Tree) -> Tree:
    """Zero out the complement of M (used to isolate [w]_M)."""
    return jax.tree.map(lambda m, x: jnp.where(m, x, jnp.zeros_like(x)),
                        mask, tree)


def mask_size(mask: Tree, params: Tree) -> int:
    """Number of scalar parameters inside M."""
    total = 0
    for m, x in zip(jax.tree.leaves(mask), jax.tree.leaves(params)):
        total += int(jnp.sum(jnp.broadcast_to(m, x.shape)))
    return total


def extract_simple(params: Tree, cfg: ModelConfig) -> Tree:
    """Materialize the simple model's own (smaller) parameter tree.

    The result is directly consumable by ``transformer.forward_simple`` —
    period stacks are truncated to ``exit_period``; complex-only subtrees
    are dropped.
    """
    kp = cfg.exit_period
    out: Dict[str, Tree] = {}
    for name, sub in params.items():
        if name == "periods":
            out[name] = tuple(jax.tree.map(lambda x: x[:kp], s) for s in sub)
        elif name in ("embed", "frontend_proj", "exit_norm"):
            out[name] = sub
        # rem / final_norm / unembed are complex-only
    return out


def embed_simple(simple: Tree, complex_params: Tree, cfg: ModelConfig) -> Tree:
    """Write a simple tree back into the complex one ([w_c]_M := w_s)."""
    kp = cfg.exit_period
    out = dict(complex_params)
    for name, sub in simple.items():
        if name == "periods":
            merged = []
            for s_stk, c_stk in zip(sub, complex_params["periods"]):
                merged.append(jax.tree.map(
                    lambda s, c: jnp.concatenate([s.astype(c.dtype), c[kp:]],
                                                 axis=0),
                    s_stk, c_stk))
            out[name] = tuple(merged)
        else:
            out[name] = sub
    return out


def tree_isfinite(tree: Tree) -> jax.Array:
    """Scalar bool: every leaf fully finite (paper's NaN-device check)."""
    flags = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)
             if jnp.issubdtype(x.dtype, jnp.floating)]
    return jnp.all(jnp.stack(flags)) if flags else jnp.asarray(True)
