"""Per-client flat-vector state store: ``(N_clients, n_flat)`` rows.

``ClientStateMatrix`` (client_state.py) holds per-client *scalars*; this
module holds per-client *vectors* — one packed ``FlatLayout`` row per
client, the shape SCAFFOLD control variates, error-feedback residuals
and per-client momenta all share (``FederatedTrainer.cv_store`` and
``FederatedTrainer.ef_store`` are both instances of this class).  The
contract mirrors the scalar matrix's round-jit seam exactly:

* ``gather(ids)`` hands the round jit the O(cohort) ``(k, n_flat)``
  block of sampled rows (a device array, ready to chunk through the
  ``lax.scan`` stream alongside the cohort data);
* the round returns updated rows, ``scatter(ids, rows)`` writes them
  back.

Per-round cost is O(cohort x n_flat) regardless of the population size
— the O(cohort) host-cost guarantee ``benchmarks/client_scale.py``
gates extends to the vector store (``benchmarks/variance_reduction.py``
records the footprint + gather/scatter overhead).

**Backends** (``FedConfig.state_store_backend``):

* ``"device"`` — one jnp array; gather/scatter are jnp takes/scatters.
  Right for small N where the whole store fits comfortably in device
  memory next to the model.
* ``"host"``   — one numpy array; gather is fancy indexing + a device
  put of the O(cohort) block, scatter a fancy-indexed write.  Device
  memory stays O(cohort).
* ``"mmap"``   — ``np.memmap`` over an unlinked tempfile: host RSS
  stays O(touched pages), the population-scale answer (10^6 clients x
  1 MB rows = 1 TB never materializes).
* ``"auto"``   — ``device`` when the footprint is under
  ``DEVICE_LIMIT_BYTES``, ``host`` under ``HOST_LIMIT_BYTES``, else
  ``mmap``.

Pad slots: cohort plans may pad slot blocks with *wrapped real ids* at
weight 0 — callers must mask those out before ``scatter`` (write only
``plan.*_real`` slots) or a pad slot would clobber the real client's
row it wraps.  ``FederatedTrainer._apply_cv_update`` and
``_apply_ef_update`` do exactly this.
"""

from __future__ import annotations

import os
import tempfile
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

BACKENDS = ("auto", "device", "host", "mmap")

# auto thresholds: keep the store off-device once it rivals a model's
# footprint, and out of host RAM once it rivals the machine's
DEVICE_LIMIT_BYTES = 64 * 1024 * 1024
HOST_LIMIT_BYTES = 4 * 1024 * 1024 * 1024


def resolve_backend(backend: str, nbytes: int) -> str:
    """Map ``"auto"`` to a concrete backend by store footprint."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown state-store backend {backend!r} "
                         f"(expected one of {BACKENDS})")
    if backend != "auto":
        return backend
    if nbytes <= DEVICE_LIMIT_BYTES:
        return "device"
    if nbytes <= HOST_LIMIT_BYTES:
        return "host"
    return "mmap"


class FlatStateStore:
    """``(N_clients, n_flat)`` float32 rows with a gather/scatter seam.

    ``gather`` always returns a ``jax.Array`` — the round jit's input —
    and ``scatter`` always accepts host or device rows.  Cumulative
    ``gathered_bytes`` / ``scattered_bytes`` counters feed the
    ``state_store`` telemetry ledger.
    """

    def __init__(self, n_clients: int, n_flat: int, *,
                 backend: str = "auto", dtype=np.float32):
        if n_clients <= 0:
            raise ValueError(f"n_clients must be > 0, got {n_clients}")
        if n_flat <= 0:
            raise ValueError(f"n_flat must be > 0, got {n_flat}")
        self.n_clients = int(n_clients)
        self.n_flat = int(n_flat)
        self.dtype = np.dtype(dtype)
        nbytes = self.n_clients * self.n_flat * self.dtype.itemsize
        self.backend = resolve_backend(backend, nbytes)
        self.gathered_bytes = 0
        self.scattered_bytes = 0
        self._mmap_path: Optional[str] = None
        shape = (self.n_clients, self.n_flat)
        if self.backend == "device":
            self._rows = jnp.zeros(shape, self.dtype)
        elif self.backend == "host":
            self._rows = np.zeros(shape, self.dtype)
        else:
            fd, path = tempfile.mkstemp(prefix="flat_state_", suffix=".bin")
            os.close(fd)
            self._mmap_path = path
            self._rows = np.memmap(path, dtype=self.dtype, mode="w+",
                                   shape=shape)

    # -- geometry -------------------------------------------------------------

    @property
    def nbytes(self) -> int:
        """Logical footprint (mmap: file size, not resident pages)."""
        return self.n_clients * self.n_flat * self.dtype.itemsize

    # -- round-jit seam (O(cohort) per call) ----------------------------------

    def gather(self, ids) -> jax.Array:
        """The sampled rows ``(k, n_flat)`` as a device array."""
        ids = np.asarray(ids, dtype=np.int64)
        self.gathered_bytes += int(ids.size) * self.n_flat * \
            self.dtype.itemsize
        if self.backend == "device":
            return jnp.take(self._rows, jnp.asarray(ids), axis=0)
        return jnp.asarray(self._rows[ids])

    def scatter(self, ids, rows) -> None:
        """Write updated rows back (unique REAL ids only — callers mask
        out weight-0 pad slots, which wrap real ids by construction)."""
        ids = np.asarray(ids, dtype=np.int64)
        self.scattered_bytes += int(ids.size) * self.n_flat * \
            self.dtype.itemsize
        if self.backend == "device":
            self._rows = self._rows.at[jnp.asarray(ids)].set(
                jnp.asarray(rows, self.dtype))
        else:
            self._rows[ids] = np.asarray(rows, self.dtype)

    # -- checkpoint integration ----------------------------------------------

    def to_array(self) -> np.ndarray:
        """The full store as a host array (checkpoint payload)."""
        return np.asarray(self._rows)

    def load(self, array: np.ndarray) -> None:
        """Restore from a checkpointed payload (shape-checked)."""
        array = np.asarray(array, dtype=self.dtype)
        if array.shape != (self.n_clients, self.n_flat):
            raise ValueError(
                f"state-store shape mismatch: checkpoint "
                f"{array.shape}, store {(self.n_clients, self.n_flat)}")
        if self.backend == "device":
            self._rows = jnp.asarray(array)
        else:
            self._rows[...] = array

    # -- lifecycle ------------------------------------------------------------

    def close(self) -> None:
        """Drop the mmap backing file (no-op on other backends)."""
        if self._mmap_path is not None:
            self._rows = np.zeros((0, self.n_flat), self.dtype)
            try:
                os.unlink(self._mmap_path)
            except OSError:
                pass
            self._mmap_path = None

    def __del__(self):  # pragma: no cover - GC timing
        try:
            self.close()
        except Exception:
            pass
