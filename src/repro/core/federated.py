"""Federated runtime: local client training, server state, round functions.

Implements the paper's three algorithms over any adapter:

* ``fedhen``   — Alg. 1 + Alg. 2 (side objective on complex devices)
* ``noside``   — Alg. 4 (HeteroFL-style: same server step, no side objective)
* ``decouple`` — Alg. 3 (two independent FedAvg runs)

Local training (Alg. 2): E epochs of minibatch SGD, eta, global-norm clip 10,
per-device NaN exclusion (Appendix A).  A whole cohort trains inside one jit
as ``vmap`` over clients of a ``scan`` over SGD steps — on the production
mesh the cohort axis is sharded over ``data``/``pod`` (see launch/), making
the server aggregation an all-reduce: the communication the paper saves.

Cohort composition is stratified (k_s simple + k_c complex per round, the
expectation of the paper's uniform 10% sampling) so shapes stay static;
``sample_uniform=True`` recovers uniform sampling via validity-weight
padding.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import aggregate, masking
from repro.optim.sgd import sgd_update

Tree = Any
Batch = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Local client optimization (Alg. 2)
# ---------------------------------------------------------------------------

def make_client_trainer(loss_fn: Callable[[Tree, Batch], jax.Array],
                        fed: FedConfig):
    """Returns train(params, data, rng) -> (params', mean_loss).

    data: dict of arrays with leading dim N_i (the client's local dataset).
    Runs E epochs of shuffled minibatch SGD with global-norm clipping.
    """

    def train(params: Tree, data: Batch, rng: jax.Array):
        n = jax.tree.leaves(data)[0].shape[0]
        steps = max(n // fed.batch_size, 1)
        server_params = params  # the received server model (FedProx anchor)

        def full_loss(p, batch):
            loss = loss_fn(p, batch)
            if fed.prox_mu:
                sq = sum(jnp.sum(jnp.square(a.astype(jnp.float32) -
                                            b.astype(jnp.float32)))
                         for a, b in zip(jax.tree.leaves(p),
                                         jax.tree.leaves(server_params)))
                loss = loss + 0.5 * fed.prox_mu * sq
            return loss

        def epoch(params, key):
            perm = jax.random.permutation(key, n)
            idxs = perm[:steps * fed.batch_size].reshape(steps,
                                                         fed.batch_size)

            def step(params, idx):
                batch = jax.tree.map(lambda x: jnp.take(x, idx, axis=0), data)
                loss, grads = jax.value_and_grad(full_loss)(params, batch)
                return sgd_update(params, grads, fed.lr, fed.clip_norm), loss

            return jax.lax.scan(step, params, idxs)

        keys = jax.random.split(rng, fed.local_epochs)
        params, losses = jax.lax.scan(epoch, params, keys)
        return params, jnp.mean(losses)

    return train


# ---------------------------------------------------------------------------
# Server state
# ---------------------------------------------------------------------------

@dataclass
class ServerState:
    """``complex`` is the server complex model; for fedhen/noside the server
    simple model IS its M slice (Alg. 1 ln. 20 invariant).  Decouple keeps an
    independent ``simple_host`` (complex-structured; only its M slice is
    meaningful)."""
    complex: Tree
    simple_host: Optional[Tree] = None
    round: int = 0


# ---------------------------------------------------------------------------
# Round functions
# ---------------------------------------------------------------------------

class FederatedTrainer:
    """Drives T rounds of any of the three algorithms (paper protocol)."""

    def __init__(self, adapter, fed: FedConfig,
                 client_data: List[Batch], *,
                 rng: Optional[jax.Array] = None):
        if fed.algorithm not in ("fedhen", "noside", "decouple"):
            raise ValueError(fed.algorithm)
        self.adapter = adapter
        self.fed = fed
        self.client_data = client_data
        self.rng = np.random.default_rng(fed.seed)
        key = rng if rng is not None else jax.random.PRNGKey(fed.seed)
        self.server = ServerState(complex=adapter.init(key))
        if fed.algorithm == "decouple":
            self.server.simple_host = jax.tree.map(jnp.copy,
                                                   self.server.complex)
        self.mask = adapter.subnet_mask(self.server.complex)
        self.k_simple = max(int(round(fed.participation * fed.n_simple)), 1)
        n_complex = fed.n_devices - fed.n_simple
        self.k_complex = max(int(round(fed.participation * n_complex)), 1)
        self.bytes_per_round = self._bytes_per_round()
        self.total_bytes = 0.0
        self._round_fn = jax.jit(self._make_round_fn())

    # -- communication accounting ------------------------------------------

    def _bytes_per_round(self) -> float:
        params = self.server.complex
        total = sum(x.size * x.dtype.itemsize
                    for x in jax.tree.leaves(params))
        simple = 0
        for m, x in zip(jax.tree.leaves(self.mask),
                        jax.tree.leaves(params)):
            simple += int(np.sum(np.broadcast_to(np.asarray(m), x.shape))
                          ) * x.dtype.itemsize
        # down + up for each active device
        return 2.0 * (self.k_simple * simple + self.k_complex * total)

    # -- the jitted round ----------------------------------------------------

    def _make_round_fn(self):
        adapter, fed, mask = self.adapter, self.fed, self.mask
        algo = fed.algorithm
        train_simple = make_client_trainer(adapter.loss_simple, fed)
        complex_loss = (adapter.loss_side if algo == "fedhen"
                        else adapter.loss_complex)
        train_complex = make_client_trainer(complex_loss, fed)

        def round_fn(complex_params: Tree, simple_host: Optional[Tree],
                     data_s: Batch, data_c: Batch, rng: jax.Array):
            ks, kc = self.k_simple, self.k_complex
            rs, rc = jax.random.split(rng)

            def tile(tree, k):
                return jax.tree.map(
                    lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), tree)

            src_simple = simple_host if algo == "decouple" else complex_params
            cohort_s, loss_s = jax.vmap(train_simple)(
                tile(src_simple, ks), data_s, jax.random.split(rs, ks))
            cohort_c, loss_c = jax.vmap(train_complex)(
                tile(complex_params, kc), data_c, jax.random.split(rc, kc))

            cohort = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                  cohort_s, cohort_c)
            is_simple = jnp.arange(ks + kc) < ks
            valid = jax.vmap(masking.tree_isfinite)(cohort)
            if not fed.skip_nan_devices:
                valid = jnp.ones_like(valid)

            if algo in ("fedhen", "noside"):
                new_complex = aggregate.fedhen_server_update(
                    cohort, is_simple, valid, mask)
                new_simple_host = None
            else:
                new_simple_host, new_complex = aggregate.decouple_server_update(
                    cohort, is_simple, valid, mask)
            metrics = {"loss_simple": jnp.mean(loss_s),
                       "loss_complex": jnp.mean(loss_c),
                       "n_valid": jnp.sum(valid)}
            return new_complex, new_simple_host, metrics

        return round_fn

    # -- sampling + gather (host side; this is the "data loading" tier) -----

    def _sample_cohort(self):
        fed = self.fed
        simple_ids = self.rng.choice(fed.n_simple, self.k_simple,
                                     replace=False)
        complex_ids = fed.n_simple + self.rng.choice(
            fed.n_devices - fed.n_simple, self.k_complex, replace=False)
        return simple_ids, complex_ids

    def _gather(self, ids) -> Batch:
        datasets = [self.client_data[i] for i in ids]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *datasets)

    # -- public API ----------------------------------------------------------

    def run_round(self) -> Dict[str, float]:
        simple_ids, complex_ids = self._sample_cohort()
        data_s = self._gather(simple_ids)
        data_c = self._gather(complex_ids)
        key = jax.random.PRNGKey(self.fed.seed * 100003 + self.server.round)
        new_complex, new_simple_host, metrics = self._round_fn(
            self.server.complex, self.server.simple_host, data_s, data_c, key)
        self.server = ServerState(complex=new_complex,
                                  simple_host=new_simple_host,
                                  round=self.server.round + 1)
        self.total_bytes += self.bytes_per_round
        return {k: float(v) for k, v in metrics.items()}

    def evaluate(self, test_batch: Batch) -> Dict[str, float]:
        """Server-model metrics.  For decouple, the simple accuracy comes
        from the simple host; otherwise from the complex model's M slice
        (which IS the server simple model)."""
        m = {k: float(v) for k, v in
             self.adapter.evaluate(self.server.complex, test_batch).items()}
        if self.fed.algorithm == "decouple":
            ms = self.adapter.evaluate(self.server.simple_host, test_batch)
            m["acc_simple"] = float(ms["acc_simple"])
        m["mbytes"] = self.total_bytes / 1e6
        return m

    def run(self, rounds: int, *, eval_every: int = 0,
            test_batch: Optional[Batch] = None,
            log: Optional[Callable[[str], None]] = None) -> List[Dict]:
        history = []
        for r in range(rounds):
            metrics = self.run_round()
            if eval_every and test_batch is not None and \
                    (r + 1) % eval_every == 0:
                metrics.update(self.evaluate(test_batch))
            metrics["round"] = self.server.round
            history.append(metrics)
            if log and (eval_every and (r + 1) % eval_every == 0):
                log(f"round {self.server.round}: " + ", ".join(
                    f"{k}={v:.4f}" for k, v in metrics.items()
                    if k != "round"))
        return history


def rounds_to_target(history: List[Dict], key: str, target: float) -> int:
    """Paper's evaluation metric: first round reaching the target accuracy."""
    for h in history:
        if key in h and h[key] >= target:
            return h["round"]
    return -1
