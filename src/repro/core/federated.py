"""Federated runtime: local client training, server state, round functions.

Implements the paper's three algorithms over any adapter:

* ``fedhen``   — Alg. 1 + Alg. 2 (side objective on complex devices)
* ``noside``   — Alg. 4 (HeteroFL-style: same server step, no side objective)
* ``decouple`` — Alg. 3 (two independent FedAvg runs)

Local training (Alg. 2): E epochs of minibatch SGD, eta, global-norm clip 10,
per-device NaN exclusion (Appendix A).

**Streaming contract.**  A round is one jit (inputs donated): each
population (simple, then complex) is split into chunks of
``FedConfig.cohort_chunk`` clients, and ``lax.scan`` runs the vmap'd client
trainer chunk by chunk, folding each trained chunk into running masked
aggregation sums (``aggregate.streaming_fold``, the ``masked_agg`` kernel's
contract) that are normalized once at the end of the round
(``aggregate.streaming_finalize``).  Device memory is therefore O(chunk),
not O(k) — cohorts of hundreds of clients stream through a fixed-size
working set.  ``cohort_chunk=0`` trains each population in a single chunk
(the old whole-cohort vmap); ``cohort_chunk="auto"`` derives the chunk from
the flat layout's per-client byte footprint against
``FedConfig.agg_memory_budget_mb`` (``flatten.auto_cohort_chunk`` — the
resolved value is ``FederatedTrainer.cohort_chunk``).  Populations the
chunk size does not divide are padded with zero-validity clients (wrapped
data, weight 0), so padding can never change the aggregate; per-client RNG
keys are derived by ``fold_in(population_key, client_index)``, so the
round's result is invariant to the chunking up to float summation order.
On the production mesh the chunk axis is sharded over ``data``/``pod``
(see launch/), making the per-chunk fold an all-reduce: the communication
the paper saves.

**Flat layout contract.**  With ``FedConfig.agg_engine="flat"`` (default)
the trainer builds ONE static ``core.flatten.FlatLayout`` for the complex
treedef at ``__init__`` (offsets are a pure function of treedef + leaf
shapes + ``agg_block_n``, so the layout is valid for every round and
checkpoint restore) and precomputes the index-set-M mask as one flat
bitvector.  Each trained chunk is packed into a single contiguous
``(Z, n_flat)`` buffer — in ``agg_stream_dtype`` (bf16 halves fold read
traffic; accumulation is always f32) — and the whole fold is ONE
accumulating ``masked_agg`` launch updating the flat running sum in place,
instead of one launch per leaf.  ``agg_engine="tree"`` keeps the per-leaf
PR 2 fold as the parity engine; the two differ only by float summation
order across kernel tile boundaries.

**Wire contract.**  ``FedConfig.comm_dtype`` selects the round's wire
format (``core/comm.py``): the server broadcast is encoded/decoded through
it before clients train (so the round sees the real quantization error),
and client uploads are folded through it — the int8 wire via the
dequantizing ``masked_agg`` accumulate, so the server never materializes
an f32 copy of the uploads.  Per-round byte accounting is *measured* from
the encoder's real output sizes (payload + scale sidecar, download and
upload separately), replacing the old analytic estimate (kept as
``analytic_bytes_per_round`` — the consistency oracle).

**Wire v2 (compressed uploads).**  When the wire ``uses_deltas``
(``topk_frac < 1``, ``stochastic_rounding`` or ``error_feedback``),
clients upload the encoded DELTA vs the decoded broadcast they trained
on instead of full params: each chunk packs ``x`` (its broadcast) and
``y`` (its trained result), encodes ``d = y - x`` — plus the client's
gathered error-feedback residual row when EF is on, whose update
``r' = (d + r) - decode(encode(d + r))`` keeps what the lossy encode
dropped for the next participation — and the server folds
``(sum_z w_z) * x`` densely plus every encoded delta row
(``aggregate.SparseChunk``; top-k payloads through the scatter-fold
kernel).  Residual rows live in a second ``FlatStateStore``
(``FederatedTrainer.ef_store``, gathered/scattered per round exactly
like SCAFFOLD's control variates; row norms feed the scalar matrix's
``ef_scale`` column).  With every v2 knob at its default the upload
path is the pre-existing program, bit for bit (test-pinned).

**Async contract.**  With ``FedConfig.async_lag > 0`` the trainer
delegates ``run_round`` to ``core/async_rounds.AsyncRoundEngine``: chunk
``t`` of a round trains on the version-tagged server params published at
fold ``t - async_lag`` of the global fold stream (the first ``async_lag``
chunks overlap the previous round's fold and carry a stale broadcast),
and stale uploads fold with the polynomial staleness decay
``1/(1+s)^async_decay`` multiplied into the same validity-weight path the
NaN/padding exclusion uses.  ``async_lag=0`` IS this module's synchronous
engine, bit-for-bit (test-enforced).  Download accounting becomes
version-aware under async (``comm.VersionCache``): reused stale
broadcasts are not re-billed, so ``total_bytes_down`` is measured per
round instead of a static per-round constant.

Cohort composition is stratified (k_s simple + k_c complex per round, the
expectation of the paper's uniform 10% sampling) so shapes stay static;
``sample_uniform=True`` recovers uniform sampling via validity-weight
padding.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import aggregate, client_state, comm, flatten, masking
from repro.core import sampling, state_store
from repro.obs import telemetry as obslib
from repro.optim.sgd import sgd_update

Tree = Any
Batch = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Local client optimization (Alg. 2)
# ---------------------------------------------------------------------------

def make_client_trainer(loss_fn: Callable[[Tree, Batch], jax.Array],
                        fed: FedConfig, *,
                        cv_layout: Optional[flatten.FlatLayout] = None):
    """Returns train(params, data, rng[, corr_flat]) -> (params', mean_loss).

    data: dict of arrays with leading dim N_i (the client's local dataset).
    Runs E epochs of shuffled minibatch SGD with global-norm clipping.

    ``cv_layout`` (SCAFFOLD): when set, ``train`` takes a fourth argument
    — the client's packed gradient correction ``corr = c - c_i`` (already
    masked to the population's trainable slice by the caller) — unpacked
    through this layout once and ADDED to every minibatch gradient before
    the clipped SGD update (Karimireddy et al. 2020 option II: the clip,
    like the step, acts on the corrected gradient).
    """

    def train(params: Tree, data: Batch, rng: jax.Array,
              corr_flat: Optional[jax.Array] = None):
        n = jax.tree.leaves(data)[0].shape[0]
        steps = max(n // fed.batch_size, 1)
        server_params = params  # the received server model (FedProx anchor)
        corr = (flatten.unpack(cv_layout, corr_flat, cast=False)
                if cv_layout is not None else None)

        def full_loss(p, batch):
            loss = loss_fn(p, batch)
            if fed.prox_mu:
                sq = sum(jnp.sum(jnp.square(a.astype(jnp.float32) -
                                            b.astype(jnp.float32)))
                         for a, b in zip(jax.tree.leaves(p),
                                         jax.tree.leaves(server_params)))
                loss = loss + 0.5 * fed.prox_mu * sq
            return loss

        def epoch(params, key):
            perm = jax.random.permutation(key, n)
            idxs = perm[:steps * fed.batch_size].reshape(steps,
                                                         fed.batch_size)

            def step(params, idx):
                batch = jax.tree.map(lambda x: jnp.take(x, idx, axis=0), data)
                loss, grads = jax.value_and_grad(full_loss)(params, batch)
                if corr is not None:
                    grads = jax.tree.map(
                        lambda g, c: g + c.astype(g.dtype), grads, corr)
                return sgd_update(params, grads, fed.lr, fed.clip_norm), loss

            return jax.lax.scan(step, params, idxs)

        keys = jax.random.split(rng, fed.local_epochs)
        params, losses = jax.lax.scan(epoch, params, keys)
        return params, jnp.mean(losses)

    return train


def local_step_count(data: Batch, fed: FedConfig) -> int:
    """Static SGD step count K one client runs on ``data`` — the divisor
    of SCAFFOLD's option-II delta ``(x - y) / (K * lr)``.  ``data`` is
    the STACKED population batch ``(k, N_i, ...)``; mirrors
    ``make_client_trainer``'s ``steps * local_epochs`` exactly."""
    n = jax.tree.leaves(data)[0].shape[1]
    return max(n // fed.batch_size, 1) * fed.local_epochs


class ScaffoldCtx(NamedTuple):
    """Per-population SCAFFOLD context threaded through one chunk stream.

    ``rows``: the cohort's gathered ``(k, n_flat)`` control variates
    ``c_i`` (``FlatStateStore.gather``).  ``c_global``: the server's
    ``(n_flat,)`` control variate ``c``.  ``pop_mask``: flat bool mask of
    the slice this population trains (simple clients own only M — their
    correction and delta live on M alone); ``None`` = whole vector.
    ``layout``: the trainer's FlatLayout (packs ``x`` and ``y``).
    ``inv_k_lr``: the static scalar ``1 / (K * lr)``.
    """
    rows: jax.Array
    c_global: jax.Array
    pop_mask: Optional[jax.Array]
    layout: Any
    inv_k_lr: float


# fold_in tag deriving a client's wire-encode key from its training key:
# the stochastic-rounding bit stream must be independent of the SGD
# stream, and deriving from the same per-client base key keeps the
# encode invariant to chunk placement (like the training RNG)
_WIRE_KEY_TAG = 0x57495245          # "WIRE"


class WireUploadCtx(NamedTuple):
    """Per-population wire-v2 upload context threaded through one chunk
    stream (delta-mode encode; active iff ``WireSpec.uses_deltas``).

    ``spec``: the round's wire.  ``layout``: the trainer's FlatLayout
    (packs the broadcast ``x`` and trained result ``y``; the upload is
    the encoded delta ``y - x``).  ``k_top``: static top-k payload
    length for this population — ``comm.topk_count`` of its TRUE
    element count (simple clients' deltas are identically zero outside
    M, so their budget is |M|).  ``ef_rows``: the cohort's gathered
    ``(k, n_flat)`` error-feedback residuals
    (``FlatStateStore.gather``); ``None`` when ``error_feedback`` is
    off."""
    spec: comm.WireSpec
    layout: Any
    k_top: int
    ef_rows: Optional[jax.Array]


# ---------------------------------------------------------------------------
# The chunk-stream scan (shared by the sync round and the async engine)
# ---------------------------------------------------------------------------

def chunk_geometry(k: int, cohort_chunk: int) -> Tuple[int, int]:
    """(chunk, n_chunks) of one population's scan: ``chunk <= k``, the
    population padded up to a chunk multiple with zero-validity clients."""
    chunk = k if cohort_chunk <= 0 else min(cohort_chunk, k)
    return chunk, -(-k // chunk)


def stream_population(state, get_src, train_fn, data, key, agg_fold, *,
                      k: int, chunk: int, n_chunks: int,
                      is_simple_flag: bool, skip_nan: bool,
                      version_idx=None, staleness_w=None,
                      real_mask=None, scaffold: Optional[ScaffoldCtx] = None,
                      upload: Optional[WireUploadCtx] = None):
    """Scan over one population's chunks: train + fold into running sums.

    The ONE chunk-stream implementation — the synchronous round and the
    asynchronous engine (``core/async_rounds.py``) both call it, so the
    two engines cannot drift (the async lag=0 bit-parity gate covers
    exactly the extras below).

    Args:
      state: the running aggregation state (``agg_fold``'s carry).
      get_src: ``get_src(version_idx_or_None) -> params tree`` — the
        broadcast one chunk trains on.  The sync round ignores the
        argument (one fresh broadcast); the async engine dynamic-indexes
        its version stack with it.
      train_fn / data / key / agg_fold: the population's client trainer,
        stacked client datasets (leading dim ``k``), population RNG key
        (per-client keys are ``fold_in(key, i)``), and the engine's fold.
      k / chunk / n_chunks: the population's static chunk geometry
        (:func:`chunk_geometry`).  ``k`` is padded up to
        ``n_chunks * chunk`` with zero-validity clients (wrapped data) so
        shapes stay static; padding never reaches the aggregate or the
        loss metric.
      is_simple_flag / skip_nan: population membership constant and the
        NaN-device exclusion toggle.
      version_idx / staleness_w: the async extras — per-chunk
        ``(n_chunks,)`` broadcast version index (handed to ``get_src``)
        and staleness coefficient (multiplied into validity as f32, the
        shared masked-weight path).  ``None``/``None`` keeps validity
        bool: the synchronous engine's exact program.
      real_mask: optional ``(k,)`` bool — which of the ``k`` slots hold a
        distinct sampled client (uniform super-cohort mode,
        ``core/sampling.py``: unfilled slots wrap drawn ids and must fold
        at weight 0).  ``None`` (stratified mode) keeps every slot real —
        the exact pre-existing program, traced with no mask input.  The
        mean loss normalizes by the realized client count.
      scaffold: optional :class:`ScaffoldCtx`.  When set, each chunk (a)
        corrects every client's local gradients by ``c - c_i`` (unpacked
        inside the client trainer), (b) computes the option-II delta
        ``dc = (x - y)/(K*lr) - c`` from the packed broadcast/result
        vectors, (c) folds ``dc`` into the engine's second flat
        accumulator with the SAME per-client weights as the params, and
        (d) stacks the updated rows ``c_i + dc`` (invalid clients keep
        their old row) as scan outputs.  ``None`` traces the literal
        pre-existing program — ``variance_reduction="none"`` stays
        bit-identical.
      upload: optional :class:`WireUploadCtx` (wire v2).  When set, each
        chunk uploads the encoded DELTA ``d = y - x`` vs the broadcast it
        trained on instead of dense params: (a) the client's gathered EF
        residual (if any) is added before the encode, (b) the encode is
        top-k and/or stochastic per the spec (per-client encode keys are
        ``fold_in(client_key, _WIRE_KEY_TAG)``), (c) the fold consumes an
        :class:`aggregate.SparseChunk` — base ``x`` densely at the summed
        weights plus each encoded delta row, so the dense uploads never
        materialize — and (d) the new residuals
        ``r' = (d + r) - decode(encoded)`` ride out as scan outputs
        (invalid/NaN clients keep their old row).  ``None`` traces the
        literal pre-existing upload path.

    Returns: ``(state, mean_loss, n_valid, cv_rows, ef_rows)`` —
    ``cv_rows`` is the ``(k, n_flat)`` updated control variates (``None``
    without ``scaffold``), ``ef_rows`` the updated error-feedback
    residuals (``None`` without EF).  Pad rows are sliced off both, but
    the HOST still must scatter only real slots — pad slots wrap real
    clients' ids.
    """
    k_pad = n_chunks * chunk
    wrap = jnp.arange(k_pad) % k
    if k_pad != k:
        data = jax.tree.map(lambda x: jnp.take(x, wrap, axis=0), data)
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(k_pad))
    real = jnp.arange(k_pad) < k
    denom = jnp.asarray(k, jnp.float32)
    if real_mask is not None:
        real = real & jnp.pad(jnp.asarray(real_mask, bool),
                              (0, k_pad - k))
        denom = jnp.maximum(jnp.sum(real.astype(jnp.float32)), 1.0)

    to_chunks = lambda x: x.reshape((n_chunks, chunk) + x.shape[1:])
    is_async = version_idx is not None
    xs = (jax.tree.map(to_chunks, data), to_chunks(keys), to_chunks(real))
    if is_async:
        xs = xs + (version_idx, staleness_w)
    if scaffold is not None:
        rows = scaffold.rows
        if k_pad != k:
            rows = jnp.take(rows, wrap, axis=0)
        xs = xs + (to_chunks(rows),)
    cv_pos = len(xs) - 1
    ef_on = upload is not None and upload.ef_rows is not None
    if ef_on:
        ef = upload.ef_rows
        if k_pad != k:
            ef = jnp.take(ef, wrap, axis=0)
        xs = xs + (to_chunks(ef),)
    ef_pos = len(xs) - 1
    is_simple = jnp.full((chunk,), is_simple_flag)

    def tile(tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (chunk,) + x.shape), tree)

    def _mask_pop(v):
        """Zero a (Z, n_flat) cv vector outside the population's slice."""
        if scaffold.pop_mask is None:
            return v
        return jnp.where(scaffold.pop_mask[None], v, 0.0)

    def fold_chunk(carry, xs):
        state, loss_sum, valid_sum = carry
        if is_async:
            data_i, keys_i, real_i, idx_i, w_i = xs[:5]
        else:
            data_i, keys_i, real_i = xs[:3]
            idx_i = None
        src = get_src(idx_i)
        if scaffold is None:
            trained, losses = jax.vmap(train_fn)(
                tile(src), data_i, keys_i)
        else:
            cv_i = xs[cv_pos]
            corr = _mask_pop(scaffold.c_global[None] - cv_i)
            trained, losses = jax.vmap(train_fn)(
                tile(src), data_i, keys_i, corr)
        valid = real_i
        if skip_nan:
            valid = valid & jax.vmap(masking.tree_isfinite)(trained)
        fold_valid = (valid.astype(jnp.float32) * w_i if is_async
                      else valid)
        # x is the decoded broadcast this chunk trained on (async: its
        # selected stale version), y the trained result — shared by the
        # SCAFFOLD delta and the wire-v2 delta encode
        x_flat = y_flat = None
        if scaffold is not None or upload is not None:
            pack_layout = (scaffold.layout if scaffold is not None
                           else upload.layout)
            x_flat = flatten.pack(pack_layout, src)
            y_flat = flatten.pack_stacked(pack_layout, trained)
        rows_out = ef_out = None
        fold_kw = {}
        if scaffold is not None:
            # option II: dc = (x - y)/(K*lr) - c on the trained slice
            dc = _mask_pop((x_flat[None] - y_flat) * scaffold.inv_k_lr
                           - scaffold.c_global[None])
            fold_kw["cv_chunk"] = dc
            # NaN clients fold at weight 0 (dc gated in the kernel) AND
            # keep their previous row — a NaN row must never persist
            rows_out = jnp.where(valid[:, None], cv_i + dc, cv_i)
        if upload is None:
            state = agg_fold(state, trained, is_simple, fold_valid,
                             **fold_kw)
        else:
            spec_w = upload.spec
            d = (y_flat.astype(jnp.float32)
                 - x_flat.astype(jnp.float32)[None])
            ef_i = xs[ef_pos] if ef_on else None
            d_in = d + ef_i if ef_on else d
            enc_keys = jax.vmap(
                lambda kk: jax.random.fold_in(kk, _WIRE_KEY_TAG))(keys_i)
            if spec_w.is_sparse:
                buf = jax.vmap(lambda v, kk: comm.sparse_encode(
                    spec_w, v, upload.k_top, key=kk))(d_in, enc_keys)
                sp = aggregate.SparseChunk(x_flat.astype(jnp.float32),
                                           buf.payload, buf.scales,
                                           buf.indices)
                if ef_on:
                    dec = jax.vmap(lambda b: comm.sparse_decode_values(
                        spec_w, b))(buf)
                    r_new = jax.vmap(
                        lambda v, ix, dv: v.at[ix].add(-dv))(
                            d_in, buf.indices, dec)
            else:
                buf = jax.vmap(lambda v, kk: comm.encode(
                    spec_w, v, key=kk))(d_in, enc_keys)
                sp = aggregate.SparseChunk(x_flat.astype(jnp.float32),
                                           buf.payload, buf.scales, None)
                if ef_on:
                    r_new = d_in - jax.vmap(
                        lambda b: comm.decode(spec_w, b))(buf)
            if ef_on:
                # r' = (d + r) - decode(encode(d + r)); NaN clients keep
                # their residual row, like cv rows
                ef_out = jnp.where(valid[:, None], r_new, ef_i)
            state = agg_fold(state, None, is_simple, fold_valid,
                             sparse_chunk=sp, **fold_kw)
        loss_sum = loss_sum + jnp.sum(jnp.where(real_i, losses, 0.0))
        valid_sum = valid_sum + jnp.sum(valid)
        return (state, loss_sum, valid_sum), (rows_out, ef_out)

    zero = jnp.zeros((), jnp.float32)
    (state, loss_sum, valid_sum), ys = jax.lax.scan(
        fold_chunk, (state, zero, zero), xs)
    rows_ys, ef_ys = ys
    cv_rows = ef_rows = None
    if scaffold is not None:
        cv_rows = rows_ys.reshape(k_pad, -1)[:k]
    if ef_on:
        ef_rows = ef_ys.reshape(k_pad, -1)[:k]
    return state, loss_sum / denom, valid_sum, cv_rows, ef_rows


# ---------------------------------------------------------------------------
# Server state
# ---------------------------------------------------------------------------

@dataclass
class ServerState:
    """``complex`` is the server complex model; for fedhen/noside the server
    simple model IS its M slice (Alg. 1 ln. 20 invariant).  Decouple keeps an
    independent ``simple_host`` (complex-structured; only its M slice is
    meaningful)."""
    complex: Tree
    simple_host: Optional[Tree] = None
    round: int = 0


# ---------------------------------------------------------------------------
# Telemetry plumbing (shared by the sync trainer and the async engine)
# ---------------------------------------------------------------------------

class RoundDispatch:
    """Calls a round jit under telemetry spans.

    With telemetry disabled this is a transparent passthrough to the jit
    wrapper — the seed code path, zero extra work.  Enabled, the first
    call is split into explicit ``trace_lower`` and ``compile`` spans via
    AOT (``jit.lower(...).compile()``), the compiled program's roofline
    ledger (``roofline/hlo_walk.py`` over the lowered HLO, plus XLA's own
    cost analysis through the version-compat shim) is emitted once, and
    the cached executable serves every subsequent round under a blocking
    ``execute`` span.  The AOT path compiles the SAME lowering the jit
    wrapper would, so round results are bit-identical either way
    (test-enforced by the no-op-sink parity test).
    """

    def __init__(self, obs: obslib.Telemetry, jit_fn):
        self.obs = obs
        self.jit_fn = jit_fn
        self.compiled = None

    def _emit_roofline(self):
        from repro.roofline import hlo_walk
        counters = hlo_walk.analyze(self.compiled.as_text())
        values = {"flops": counters["flops"],
                  "hbm_bytes": counters["hbm_bytes"],
                  "collective_bytes": counters["total_collective_bytes"]}
        try:
            ca = hlo_walk.xla_cost_analysis(self.compiled)
            if ca and "flops" in ca:
                values["xla_flops"] = float(ca["flops"])
        except Exception:
            pass  # cost_analysis is advisory; some backends refuse it
        self.obs.ledger("roofline", values)

    def __call__(self, *args):
        obs = self.obs
        if not obs.enabled:
            return self.jit_fn(*args)
        if self.compiled is None:
            with obs.span("trace_lower"):
                lowered = self.jit_fn.lower(*args)
            with obs.span("compile"):
                self.compiled = lowered.compile()
            self._emit_roofline()
        with obs.span("execute"):
            return jax.block_until_ready(self.compiled(*args))


def emit_round_phases(obs: obslib.Telemetry, *, populations,
                      bytes_down: float, wire: str) -> None:
    """Emit one round's logical phase spans:
    ``broadcast -> train-chunk[t] -> fold -> finalize``.

    These are *point* spans (``dur_s=None``): the round is one fused jit,
    so the phases are real program structure with real attributes but
    their wall time lives in the enclosing ``execute`` span — see
    ``obs/telemetry.py``.  ``populations`` is a sequence of
    ``(name, k, chunk, n_chunks, staleness)`` where ``staleness`` is
    ``None`` for the synchronous engine or the per-chunk staleness
    schedule (in rounds) for the async engine; chunk indices ``t`` run
    over the round's global fold stream (simple chunks first, then
    complex — the scan order).
    """
    if not obs.enabled:
        return
    obs.point_span("broadcast", wire=wire, bytes_down=bytes_down)
    t = 0
    n_folds = 0
    for name, k, chunk, n_chunks, staleness in populations:
        for i in range(n_chunks):
            attrs = {"population": name, "chunk_size": chunk,
                     "clients": max(min(chunk, k - i * chunk), 0)}
            if staleness is not None:
                attrs["staleness"] = int(staleness[i])
            obs.point_span(f"train-chunk[{t}]", **attrs)
            t += 1
        n_folds += n_chunks
    obs.point_span("fold", n_folds=n_folds)
    obs.point_span("finalize")


# ---------------------------------------------------------------------------
# Round functions
# ---------------------------------------------------------------------------

class FederatedTrainer:
    """Drives T rounds of any of the three algorithms (paper protocol)."""

    def __init__(self, adapter, fed: FedConfig,
                 client_data: List[Batch], *,
                 rng: Optional[jax.Array] = None,
                 telemetry: Optional[obslib.Telemetry] = None):
        fed.validate()   # every config-rejection rule, one entry point
        self.adapter = adapter
        self.fed = fed
        # observability (repro/obs): None -> the disabled NOOP singleton,
        # whose every emit short-circuits — the default, un-instrumented
        # path (overhead CI-gated by benchmarks/obs_overhead.py)
        self.obs = obslib.coalesce(telemetry)
        self.client_data = client_data
        # cohort sampler (core/sampling.py): pure in (seed, round) — no
        # sequential host RNG stream to checkpoint, so resume re-creates
        # the uninterrupted run's cohort sequence exactly
        self.sampler = sampling.CohortSampler(
            n_devices=fed.n_devices, n_simple=fed.n_simple,
            participation=fed.participation, seed=fed.seed,
            uniform=fed.sample_uniform)
        # sharded per-client state (core/client_state.py): participation,
        # last round, version tags — ONE flat host matrix, O(cohort)/round
        self.client_state = client_state.ClientStateMatrix(fed.n_devices)
        key = rng if rng is not None else jax.random.PRNGKey(fed.seed)
        self.server = ServerState(complex=adapter.init(key))
        if fed.algorithm == "decouple":
            self.server.simple_host = jax.tree.map(jnp.copy,
                                                   self.server.complex)
        self.mask = adapter.subnet_mask(self.server.complex)
        # static per-population slot capacities (jit shapes): stratified
        # keeps the old max(round(p * pop), 1); uniform splits the
        # super-cohort into min(k_super, pop)-slot blocks
        self.k_simple = self.sampler.cap_simple
        self.k_complex = self.sampler.cap_complex
        # flat aggregation layout: built ONCE — offsets are static per
        # (treedef, leaf shapes, agg_block_n), valid for every round
        self.layout = flatten.build_layout(self.server.complex,
                                           total_multiple=fed.agg_block_n)
        self.flat_mask = flatten.pack_mask(self.layout, self.mask)
        # communication wire format (core/comm.py): the broadcast is
        # decoded from it on clients, uploads are folded through it, and
        # the byte accounting below measures its real encoded sizes
        self.wire = comm.WireSpec(fed.comm_dtype, fed.quant_block,
                                  topk_frac=fed.topk_frac,
                                  stochastic=fed.stochastic_rounding,
                                  error_feedback=fed.error_feedback)
        # THE engine configuration: one frozen spec built from the config,
        # bound with the trace-time flat_mask inside the round fn
        self.engine_spec = aggregate.EngineSpec.from_config(
            fed, mask=self.mask, layout=self.layout, wire=self.wire)
        # SCAFFOLD state (tentpole consumer of core/state_store.py):
        # per-client control variates c_i as one (N, n_flat) store row
        # each, plus the server's c — both zero-initialized (round 1 is
        # then bit-identical to variance_reduction="none", test-enforced)
        self.cv_store: Optional[state_store.FlatStateStore] = None
        self.cv_global: Optional[jax.Array] = None
        if fed.variance_reduction == "scaffold":
            self.cv_store = state_store.FlatStateStore(
                fed.n_devices, self.layout.n_flat,
                backend=fed.state_store_backend)
            self.cv_global = jnp.zeros((self.layout.n_flat,), jnp.float32)
        # wire-v2 error-feedback residuals: the second FlatStateStore
        # consumer — one packed row per client accumulating what the
        # lossy upload encode dropped, re-uploaded next participation
        self.ef_store: Optional[state_store.FlatStateStore] = None
        if fed.error_feedback:
            self.ef_store = state_store.FlatStateStore(
                fed.n_devices, self.layout.n_flat,
                backend=fed.state_store_backend)
        # static top-k payload lengths, per population (simple clients'
        # deltas are identically zero outside M, so their k budgets |M|)
        self.k_top_simple = self.k_top_complex = 0
        if self.wire.uses_deltas:
            n_m = int(np.sum(np.asarray(self.flat_mask)))
            self.k_top_simple = comm.topk_count(self.wire, n_m)
            self.k_top_complex = comm.topk_count(self.wire,
                                                 self.layout.n_params)
        self.cohort_chunk = self._resolve_cohort_chunk()
        (self.bytes_down_per_round,
         self.bytes_up_per_round) = self._measured_comm_bytes()
        self.bytes_per_round = (self.bytes_down_per_round
                                + self.bytes_up_per_round)
        self.total_bytes = 0.0
        self.total_bytes_down = 0.0
        self.total_bytes_up = 0.0
        # donate the server state buffers into the round (they are replaced
        # wholesale each round); CPU has no donation support, skip the noise
        donate = (0, 1) if jax.default_backend() != "cpu" else ()
        self._round_fn = jax.jit(self._make_round_fn(),
                                 donate_argnums=donate)
        self._dispatch = RoundDispatch(self.obs, self._round_fn)
        # bounded-lag async engine (core/async_rounds.py): owns the
        # version stack + staleness schedule; run_round delegates to it.
        # Imported lazily — async_rounds imports this module at top level.
        self.async_engine = None
        if fed.async_lag > 0:
            from repro.core import async_rounds
            self.async_engine = async_rounds.AsyncRoundEngine(self)
        if self.obs.enabled:
            self._emit_run_config()

    # -- chunk-size autotuning (ROADMAP item) --------------------------------

    def _resolve_cohort_chunk(self) -> int:
        """``cohort_chunk="auto"`` -> largest chunk whose per-client packed
        footprint fits ``agg_memory_budget_mb`` (else the configured int)."""
        fed = self.fed
        if fed.cohort_chunk == "auto":
            stream_dtype, qb = self._effective_stream()
            return flatten.auto_cohort_chunk(
                self.layout,
                budget_bytes=fed.agg_memory_budget_mb * 2**20,
                k=max(self.k_simple, self.k_complex),
                stream_dtype=stream_dtype, quant_block=qb)
        return int(fed.cohort_chunk)

    def _effective_stream(self):
        """(dtype, quant_block) the fold's stream buffer actually uses:
        the wire payload when a wire is configured, else the plain
        streaming dtype."""
        if self.wire.is_quantized:
            return jnp.dtype(jnp.int8), self.wire.quant_block
        if not self.wire.is_identity:
            return self.wire.payload_dtype, 0
        return jnp.dtype(self.fed.agg_stream_dtype), 0

    def stream_bytes_per_client(self) -> int:
        """One client's packed stream-buffer footprint at the effective
        wire/stream dtype (incl. the int8 scale sidecar) — what
        ``cohort_chunk="auto"`` budgets per client."""
        stream_dtype, qb = self._effective_stream()
        return self.layout.stream_bytes(stream_dtype, quant_block=qb)

    # -- communication accounting ------------------------------------------

    def _measured_comm_bytes(self) -> Tuple[float, float]:
        """(download, upload) bytes per round, MEASURED from the wire
        encoder's real output buffers (payload + scale sidecar) for the
        true element counts: complex devices exchange the whole model,
        simple devices only the index set M.  Alignment padding is a local
        layout artifact (static offsets on both ends) and is never billed.

        Also pins ``per_simple_bytes`` / ``per_complex_bytes`` — ONE
        client's one-way wire cost per population — the single source the
        async engine's version-aware billing reuses, so the two
        accountings cannot desynchronize.

        SCAFFOLD adds a control-variate exchange each way (``c`` down,
        ``dc`` up) of the client's trained element count, billed at f32
        (``per_simple_cv_bytes`` / ``per_complex_cv_bytes``): the cv
        vectors move raw, not through the wire encoder — honest
        accounting, and the measured cost of turning the knob on.
        """
        n_m = int(np.sum(np.asarray(self.flat_mask)))   # |M| true elements
        self.per_complex_bytes = comm.wire_bytes(self.wire,
                                                 self.layout.n_params)
        self.per_simple_bytes = comm.wire_bytes(self.wire, n_m)
        # the upload direction carries the wire-v2 delta payload: under
        # top-k it is the compacted index+value buffer, measured from the
        # encoder's real output shapes like the dense path (identical to
        # the download numbers when no v2 knob is on)
        self.per_complex_bytes_up = comm.wire_bytes_up(self.wire,
                                                       self.layout.n_params)
        self.per_simple_bytes_up = comm.wire_bytes_up(self.wire, n_m)
        cv = self.cv_store is not None
        self.per_simple_cv_bytes = 4.0 * n_m if cv else 0.0
        self.per_complex_cv_bytes = 4.0 * self.layout.n_params if cv else 0.0
        down = float(
            self.k_simple * (self.per_simple_bytes
                             + self.per_simple_cv_bytes)
            + self.k_complex * (self.per_complex_bytes
                                + self.per_complex_cv_bytes))
        up = float(
            self.k_simple * (self.per_simple_bytes_up
                             + self.per_simple_cv_bytes)
            + self.k_complex * (self.per_complex_bytes_up
                                + self.per_complex_cv_bytes))
        return down, up

    def _round_bytes(self, plan: sampling.CohortPlan) -> Tuple[float, float]:
        """(download, upload) bytes of ONE round under ``plan``.  With
        every slot real (stratified mode, and full uniform rounds) this is
        the static per-round constant; uniform rounds with pad slots bill
        only the realized clients — a pad slot moves no bytes."""
        if plan.all_real:
            return self.bytes_down_per_round, self.bytes_up_per_round
        down = float(
            plan.n_real_simple * (self.per_simple_bytes
                                  + self.per_simple_cv_bytes)
            + plan.n_real_complex * (self.per_complex_bytes
                                     + self.per_complex_cv_bytes))
        up = float(
            plan.n_real_simple * (self.per_simple_bytes_up
                                  + self.per_simple_cv_bytes)
            + plan.n_real_complex * (self.per_complex_bytes_up
                                     + self.per_complex_cv_bytes))
        return down, up

    def analytic_bytes_per_round(self) -> float:
        """The pre-wire estimate (param counts x param itemsize, down+up)
        — kept as the consistency oracle for the measured numbers."""
        params = self.server.complex
        total = sum(x.size * x.dtype.itemsize
                    for x in jax.tree.leaves(params))
        simple = 0
        for m, x in zip(jax.tree.leaves(self.mask),
                        jax.tree.leaves(params)):
            simple += int(np.sum(np.broadcast_to(np.asarray(m), x.shape))
                          ) * x.dtype.itemsize
        # down + up for each active device
        return 2.0 * (self.k_simple * simple + self.k_complex * total)

    # -- telemetry (repro/obs) ----------------------------------------------

    def _geometry(self) -> Tuple[Tuple[int, int], Tuple[int, int]]:
        """((chunk_s, n_chunks_s), (chunk_c, n_chunks_c)) — the static
        per-population chunk geometry of one round."""
        return (chunk_geometry(self.k_simple, self.cohort_chunk),
                chunk_geometry(self.k_complex, self.cohort_chunk))

    def _emit_run_config(self) -> None:
        """One ``run_config`` ledger at construction: the static facts a
        run report leads with (cohort geometry, engine, wire, per-round
        wire cost)."""
        fed = self.fed
        (chunk_s, n_s), (chunk_c, n_c) = self._geometry()
        values = {
            "engine": "async" if self.async_engine is not None else "sync",
            "n_devices": fed.n_devices, "n_simple": fed.n_simple,
            "k_simple": self.k_simple, "k_complex": self.k_complex,
            "participation": fed.participation,
            "sample_uniform": fed.sample_uniform,
            "client_state_bytes": self.client_state.nbytes,
            "cohort_chunk": self.cohort_chunk,
            "n_chunks_simple": n_s, "n_chunks_complex": n_c,
            "comm_dtype": fed.comm_dtype,
            "async_lag": fed.async_lag,
            "n_params": self.layout.n_params,
            "bytes_down_per_round": self.bytes_down_per_round,
            "bytes_up_per_round": self.bytes_up_per_round,
        }
        if self.cv_store is not None:
            values.update({
                "state_store_backend": self.cv_store.backend,
                "state_store_bytes": self.cv_store.nbytes,
            })
        if self.ef_store is not None:
            values.update({
                "ef_store_backend": self.ef_store.backend,
                "ef_store_bytes": self.ef_store.nbytes,
            })
        values.update(aggregate.engine_attrs(self.engine_spec))
        self.obs.ledger("run_config", values)

    def _emit_round_health(self, metrics: Dict[str, float], *,
                           down: Optional[float] = None,
                           up: Optional[float] = None,
                           k_real: Optional[int] = None) -> None:
        """Per-round client-health counters + the comm/client-state ledgers.

        The counters surface what the validity-weight path folds away
        silently: devices excluded for NaNs this round and the weight-0
        padding slots — both the chunk geometry's and (uniform mode) the
        super-cohort's unfilled arch slots.  The comm ledger repeats the
        trainer's OWN accounting fields (cumulative totals included) so a
        run log is exactly reconcilable against ``total_bytes*`` — the
        async engine passes its version-aware ``down``/``up`` here, the
        synchronous round uses ``_round_bytes``.  ``k_real`` is the
        realized (non-pad) client count; ``None`` means every slot real.
        """
        (chunk_s, n_s), (chunk_c, n_c) = self._geometry()
        k = self.k_simple + self.k_complex
        if k_real is None:
            k_real = k
        obs = self.obs
        obs.counter("nan_excluded_devices", k_real - int(metrics["n_valid"]))
        obs.counter("padding_weight0_clients",
                    (n_s * chunk_s - self.k_simple)
                    + (n_c * chunk_c - self.k_complex)
                    + (k - k_real))
        obs.ledger("comm_bytes", {
            "down": self.bytes_down_per_round if down is None else down,
            "up": self.bytes_up_per_round if up is None else up,
            "cum_down": self.total_bytes_down,
            "cum_up": self.total_bytes_up,
            "cum_total": self.total_bytes,
        })
        obs.ledger("client_state", {
            "state_bytes": self.client_state.nbytes,
            "tracked_clients": self.client_state.tracked_clients(),
        })
        if self.cv_store is not None:
            obs.ledger("state_store", {
                "store_bytes": self.cv_store.nbytes,
                "cum_gathered_bytes": self.cv_store.gathered_bytes,
                "cum_scattered_bytes": self.cv_store.scattered_bytes,
            })
        if self.ef_store is not None:
            obs.ledger("ef_store", {
                "store_bytes": self.ef_store.nbytes,
                "cum_gathered_bytes": self.ef_store.gathered_bytes,
                "cum_scattered_bytes": self.ef_store.scattered_bytes,
            })
        obs.ledger("participation_hist",
                   self.client_state.participation_histogram())

    # -- the jitted round (streaming cohort engine) --------------------------

    def _make_round_fn(self):
        adapter, fed, mask = self.adapter, self.fed, self.mask
        algo = fed.algorithm
        scaffold_on = fed.variance_reduction == "scaffold"
        cv_layout = self.layout if scaffold_on else None
        train_simple = make_client_trainer(adapter.loss_simple, fed,
                                           cv_layout=cv_layout)
        complex_loss = (adapter.loss_side if algo == "fedhen"
                        else adapter.loss_complex)
        train_complex = make_client_trainer(complex_loss, fed,
                                            cv_layout=cv_layout)

        layout = self.layout
        wire = self.wire
        spec = self.engine_spec

        def make_agg(flat_mask):
            """Engine dispatch.  ``flat_mask`` is a round *argument* (not a
            closed-over constant) so the precomputed bitvector lives in
            argument memory, shared across rounds, instead of being baked
            into the executable's temp allocation."""
            return aggregate.make_engine(spec.bind(flat_mask=flat_mask))

        chunk_s, n_chunks_s = chunk_geometry(self.k_simple,
                                             self.cohort_chunk)
        chunk_c, n_chunks_c = chunk_geometry(self.k_complex,
                                             self.cohort_chunk)

        delta_mode = wire.uses_deltas
        ef_on = fed.error_feedback
        k_top_s, k_top_c = self.k_top_simple, self.k_top_complex

        def round_fn(complex_params: Tree, simple_host: Optional[Tree],
                     data_s: Batch, data_c: Batch, rng: jax.Array,
                     flat_mask: Optional[jax.Array],
                     real_s: Optional[jax.Array] = None,
                     real_c: Optional[jax.Array] = None,
                     cv_global: Optional[jax.Array] = None,
                     cv_s: Optional[jax.Array] = None,
                     cv_c: Optional[jax.Array] = None,
                     ef_s: Optional[jax.Array] = None,
                     ef_c: Optional[jax.Array] = None):
            # real_s / real_c: per-slot reality masks (uniform
            # super-cohort mode only — stratified rounds never pass them,
            # keeping the traced program literally the pre-existing one).
            # cv_global / cv_s / cv_c: SCAFFOLD's server control variate
            # and the cohort's gathered store rows (scaffold only — the
            # "none" trace takes none of them and stays bit-identical).
            # ef_s / ef_c: the cohort's gathered error-feedback residual
            # rows (wire v2 with error_feedback only — same discipline).
            agg_init, agg_fold, agg_finalize = make_agg(flat_mask)
            rs, rc = jax.random.split(rng)
            # the server -> client broadcast crosses the wire: clients
            # train on the DECODED copy, so the round carries the real
            # quantization error (identity for the f32 wire)
            bc_complex = comm.broadcast_roundtrip(wire, layout,
                                                  complex_params)
            src_simple = (comm.broadcast_roundtrip(wire, layout,
                                                   simple_host)
                          if algo == "decouple" else bc_complex)
            sc_s = sc_c = None
            if scaffold_on:
                # simple clients train (and correct) only the M slice:
                # their c_i lives on M alone.  flat_mask is a round arg
                # whenever scaffold is on (_flat_mask_arg).
                sc_s = ScaffoldCtx(
                    rows=cv_s, c_global=cv_global, pop_mask=flat_mask,
                    layout=layout,
                    inv_k_lr=1.0 / (local_step_count(data_s, fed)
                                    * fed.lr))
                sc_c = ScaffoldCtx(
                    rows=cv_c, c_global=cv_global, pop_mask=None,
                    layout=layout,
                    inv_k_lr=1.0 / (local_step_count(data_c, fed)
                                    * fed.lr))
            up_s = up_c = None
            if delta_mode:
                up_s = WireUploadCtx(wire, layout, k_top_s, ef_s)
                up_c = WireUploadCtx(wire, layout, k_top_c, ef_c)
            state = agg_init(complex_params)
            state, loss_s, valid_s, rows_s, efrows_s = stream_population(
                state, lambda _: src_simple, train_simple, data_s, rs,
                agg_fold, k=self.k_simple, chunk=chunk_s,
                n_chunks=n_chunks_s, is_simple_flag=True,
                skip_nan=fed.skip_nan_devices, real_mask=real_s,
                scaffold=sc_s, upload=up_s)
            state, loss_c, valid_c, rows_c, efrows_c = stream_population(
                state, lambda _: bc_complex, train_complex, data_c, rc,
                agg_fold, k=self.k_complex, chunk=chunk_c,
                n_chunks=n_chunks_c, is_simple_flag=False,
                skip_nan=fed.skip_nan_devices, real_mask=real_c,
                scaffold=sc_c, upload=up_c)
            cv_out = None
            if scaffold_on:
                # server control variate: c += (1/N) * sum_i dc_i — the
                # RAW second accumulator (group weighting already rode
                # w_in/w_out through the fold), over ALL N devices
                # (non-participants contribute 0), per Karimireddy eq. 5
                new_cv_global = (cv_global
                                 + state.cv_acc / float(fed.n_devices))
                cv_out = (new_cv_global, rows_s, rows_c)
            ef_out = (efrows_s, efrows_c) if ef_on else None
            new_complex, new_simple_host = agg_finalize(
                state, template=complex_params)
            metrics = {"loss_simple": loss_s,
                       "loss_complex": loss_c,
                       "n_valid": valid_s + valid_c}
            return new_complex, new_simple_host, metrics, cv_out, ef_out

        return round_fn

    # -- sampling + gather (host side; this is the "data loading" tier) -----

    def _sample_plan(self) -> sampling.CohortPlan:
        """This round's cohort — pure in ``(fed.seed, server.round)``, so
        a checkpoint restore that recovers the round counter recovers the
        cohort sequence (no sampler RNG state exists to lose)."""
        return self.sampler.plan(self.server.round)

    def _sample_cohort(self):
        """(simple_ids, complex_ids) of this round's plan — the slot-block
        view (pad slots included in uniform mode)."""
        plan = self._sample_plan()
        return plan.simple_ids, plan.complex_ids

    def _gather(self, ids) -> Batch:
        datasets = [self.client_data[i] for i in ids]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *datasets)

    # -- public API ----------------------------------------------------------

    def _flat_mask_arg(self) -> Optional[jax.Array]:
        """The precomputed flat bitvector, passed into the round jit as an
        argument (a resident buffer shared by every round) rather than
        closed over as an executable constant.  SCAFFOLD needs it on every
        engine (the cv fold and the simple population's slice mask are
        flat ops even under the tree engine)."""
        if self.fed.agg_engine == "flat" or self.cv_store is not None:
            return self.flat_mask
        return None

    def _cv_args(self, plan: sampling.CohortPlan) -> tuple:
        """The SCAFFOLD round arguments: ``(c_global, rows_s, rows_c)``
        gathered O(cohort) from the state store — empty when off (the
        traced round then literally has no cv inputs)."""
        if self.cv_store is None:
            return ()
        return (self.cv_global,
                self.cv_store.gather(plan.simple_ids),
                self.cv_store.gather(plan.complex_ids))

    def _ef_args(self, plan: sampling.CohortPlan) -> tuple:
        """The error-feedback round arguments: ``(rows_s, rows_c)``
        residuals gathered O(cohort) from the EF store — empty when off
        (the traced round then literally has no ef inputs)."""
        if self.ef_store is None:
            return ()
        return (self.ef_store.gather(plan.simple_ids),
                self.ef_store.gather(plan.complex_ids))

    def _round_args(self, plan: sampling.CohortPlan, data_s: Batch,
                    data_c: Batch, key: jax.Array) -> tuple:
        args = (self.server.complex, self.server.simple_host, data_s,
                data_c, key, self._flat_mask_arg())
        cv = self._cv_args(plan)
        ef = self._ef_args(plan)
        if self.fed.sample_uniform:
            args += (jnp.asarray(plan.simple_real),
                     jnp.asarray(plan.complex_real))
        elif cv or ef:
            args += (None, None)     # skip the real-mask slots positionally
        if ef and not cv:
            cv = (None, None, None)  # skip the cv slots positionally
        return args + cv + ef

    def _apply_cv_update(self, plan: sampling.CohortPlan, cv_out) -> None:
        """Commit one round's SCAFFOLD outputs: the new server control
        variate, and the updated rows scattered back for REAL slots only
        (pad slots wrap real clients' ids — writing them would clobber
        rows the wrapped client just updated at full weight).  Also tracks
        each updated row's norm in the scalar matrix's ``cv_scale``
        column (telemetry: control-variate drift over rounds)."""
        new_cv_global, rows_s, rows_c = cv_out
        self.cv_global = new_cv_global
        for ids, real, rows in (
                (plan.simple_ids, plan.simple_real, rows_s),
                (plan.complex_ids, plan.complex_real, rows_c)):
            real = np.asarray(real, bool)
            if not real.any():
                continue
            ids = np.asarray(ids, np.int64)[real]
            rows = np.asarray(rows)[real]
            self.cv_store.scatter(ids, rows)
            self.client_state.set_cv_scale(
                ids, np.linalg.norm(rows.astype(np.float64), axis=1))

    def _apply_ef_update(self, plan: sampling.CohortPlan, ef_out) -> None:
        """Commit one round's error-feedback residuals: updated rows
        scattered back for REAL slots only (the same pad-slot rule as
        ``_apply_cv_update`` — pad slots wrap real clients' ids), row
        norms tracked in the scalar matrix's ``ef_scale`` column
        (telemetry: how much compression error each client carries)."""
        rows_s, rows_c = ef_out
        for ids, real, rows in (
                (plan.simple_ids, plan.simple_real, rows_s),
                (plan.complex_ids, plan.complex_real, rows_c)):
            real = np.asarray(real, bool)
            if not real.any():
                continue
            ids = np.asarray(ids, np.int64)[real]
            rows = np.asarray(rows)[real]
            self.ef_store.scatter(ids, rows)
            self.client_state.set_ef_scale(
                ids, np.linalg.norm(rows.astype(np.float64), axis=1))

    def lower_round(self):
        """AOT-lower the jitted round with this trainer's shapes.

        Used by benchmarks/tests to inspect the compiled round (peak memory,
        HLO) without running it.  Consumes one cohort sample from the
        host-side sampler.
        """
        if self.async_engine is not None:
            return self.async_engine.lower_round()
        plan = self._sample_plan()
        key = jax.random.PRNGKey(self.fed.seed * 100003 + self.server.round)
        args = self._round_args(plan, self._gather(plan.simple_ids),
                                self._gather(plan.complex_ids), key)
        return self._round_fn.lower(*args)

    def run_round(self) -> Dict[str, float]:
        if self.async_engine is not None:
            return self.async_engine.run_round()
        obs = self.obs
        obs.set_round(self.server.round)
        with obs.span("round", engine="sync"):
            with obs.span("sample_gather"):
                plan = self._sample_plan()
                data_s = self._gather(plan.simple_ids)
                data_c = self._gather(plan.complex_ids)
            key = jax.random.PRNGKey(
                self.fed.seed * 100003 + self.server.round)
            args = self._round_args(plan, data_s, data_c, key)
            (new_complex, new_simple_host, metrics,
             cv_out, ef_out) = self._dispatch(*args)
            if cv_out is not None:
                self._apply_cv_update(plan, cv_out)
            if ef_out is not None:
                self._apply_ef_update(plan, ef_out)
            self.client_state.record_round(plan.real_ids(),
                                           plan.round_index)
            self.server = ServerState(complex=new_complex,
                                      simple_host=new_simple_host,
                                      round=self.server.round + 1)
            down, up = self._round_bytes(plan)
            self.total_bytes += down + up
            self.total_bytes_down += down
            self.total_bytes_up += up
            metrics = {k: float(v) for k, v in metrics.items()}
            if obs.enabled:
                (chunk_s, n_s), (chunk_c, n_c) = self._geometry()
                emit_round_phases(obs, populations=[
                    ("simple", self.k_simple, chunk_s, n_s, None),
                    ("complex", self.k_complex, chunk_c, n_c, None)],
                    bytes_down=down, wire=self.fed.comm_dtype)
                self._emit_round_health(
                    metrics, down=down, up=up,
                    k_real=plan.n_real_simple + plan.n_real_complex)
        return metrics

    def evaluate(self, test_batch: Batch) -> Dict[str, float]:
        """Server-model metrics.  For decouple, the simple accuracy comes
        from the simple host; otherwise from the complex model's M slice
        (which IS the server simple model)."""
        m = {k: float(v) for k, v in
             self.adapter.evaluate(self.server.complex, test_batch).items()}
        if self.fed.algorithm == "decouple":
            ms = self.adapter.evaluate(self.server.simple_host, test_batch)
            m["acc_simple"] = float(ms["acc_simple"])
        m["mbytes"] = self.total_bytes / 1e6
        m["mbytes_down"] = self.total_bytes_down / 1e6
        m["mbytes_up"] = self.total_bytes_up / 1e6
        return m

    def run(self, rounds: int, *, eval_every: int = 0,
            test_batch: Optional[Batch] = None,
            log: Optional[Callable[[str], None]] = None) -> List[Dict]:
        history = []
        obs = self.obs
        for r in range(rounds):
            metrics = self.run_round()
            if eval_every and test_batch is not None and \
                    (r + 1) % eval_every == 0:
                ev = self.evaluate(test_batch)
                metrics.update(ev)
                # eval ledger is stamped with the COMPLETED round count
                # (the log line's "round N") — rounds-to-target reads it
                obs.set_round(self.server.round)
                obs.ledger("eval", ev)
            metrics["round"] = self.server.round
            history.append(metrics)
            if (log or obs.enabled) and \
                    (eval_every and (r + 1) % eval_every == 0):
                line = f"round {self.server.round}: " + ", ".join(
                    f"{k}={v:.4f}" for k, v in metrics.items()
                    if k != "round")
                # the legacy line, routed through the event stream: a
                # StdoutSink prints exactly this string, so the printed
                # format is bit-identical to the pre-telemetry driver
                obs.log(line)
                if log is not None:
                    log(line)
        return history


def rounds_to_target(history: List[Dict], key: str, target: float) -> int:
    """Paper's evaluation metric: first round reaching the target.

    Direction is inferred from the metric name (``obs.report``'s rule —
    the one inference, shared): accuracy-like metrics are reached
    at-or-above the target, loss-like metrics at-or-below."""
    from repro.obs.report import higher_is_better
    maximize = higher_is_better(key)
    for h in history:
        if key in h and (h[key] >= target if maximize
                         else h[key] <= target):
            return h["round"]
    return -1
