"""Divisibility-aware sharding policy.

Two halves:

* **Activations** — model code annotates tensors with logical axis names
  (``policy.constrain(x, ("batch", "seq", "heads", None))``); MeshPolicy
  resolves each name through LOGICAL_RULES, dropping any assignment that
  does not divide the dimension or would reuse a mesh axis twice.  On a
  single device (smoke tests) the default no-op Policy is used instead.

* **Parameters / caches** — ``param_specs`` and ``cache_specs`` walk the
  pytrees and classify leaves by their key-path (wq/wk/wv/wo, mlp up/down,
  MoE experts, recurrent states, KV caches...), producing a PartitionSpec
  tree for ``jax.jit(in_shardings=...)``.

Per-arch quirks are driven by the config (``attn_shard``):
``replicate`` (heads don't divide the 16-way model axis: recurrentgemma
10H, gemma2/gemma3 8H), ``head_dim`` (llava 56H/8kv: shard the 128-wide
head dim; pjit input shardings cannot pad), and the beyond-paper perf
variants ``seq2d`` / ``dp2d`` / ``seq2d_fsdp`` (EXPERIMENTS.md §Perf).
``shard_experts_2d`` (kimi-k2): expert weights sharded over model AND
data, ZeRO-style, to fit 1T params.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import Policy

Tree = Any


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


class MeshPolicy(Policy):
    """Activation-constraint resolver for a (pod,) data, model mesh."""

    def __init__(self, mesh: Mesh, cfg: ModelConfig):
        self.mesh = mesh
        self.cfg = cfg
        data = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        self.data_axes = data
        heads_rule = "model"
        if cfg.attn_shard in ("replicate", "head_dim", "seq2d",
                              "seq2d_fsdp", "dp2d"):
            heads_rule = None
        # seq2d ("2D token sharding"): tokens shard over data x model and
        # weights replicate — the fix for archs whose heads don't divide
        # the model axis (see EXPERIMENTS.md §Perf H2).  dp2d goes further
        # when global_batch >= chips: batch shards over BOTH axes and
        # attention is fully local (H2 iteration 3).
        self.seq2d = cfg.attn_shard in ("seq2d", "seq2d_fsdp")
        self.dp2d = cfg.attn_shard == "dp2d"
        self.rules = {
            "batch": data + ("model",) if self.dp2d else data,
            "seq": "model" if self.seq2d else None,
            "seq_chunks": "model" if self.seq2d else None,
            "heads": heads_rule,
            "kv_heads": heads_rule,
            "head_dim": "model" if cfg.attn_shard == "head_dim" else None,
            "ffn": None if (self.seq2d or self.dp2d) else "model",
            "experts": "model",
            "expert_ffn": "model",
            "vocab": None if self.dp2d else "model",
            "rnn": "model",
            # xLSTM: sharding the inner head dim causes SPMD resharding
            # storms through the chunked reshapes (measured 1.7 TB/chip of
            # collectives); baseline replicates the mixer over `model`.
            "mlstm_dh": None,
            # decode KV caches: shard the key/value sequence over `model`
            # when the kv heads cannot use it (context-parallel decode)
            "kv_seq": "model",
            # federated cohort chunk axis (one client per data slice): the
            # streaming round engine scans over chunks and each chunk's
            # client axis shards over data/pod, so the per-chunk masked
            # aggregation fold lowers to the round's all-reduce
            "cohort": data,
        }
        # resolution priority when two logical names want the same mesh axis
        self.priority = {"kv_seq": 1, "seq": 1}  # vocab/heads first

    def spec(self, x_shape: Sequence[int],
             axes: Sequence[Optional[str]]) -> P:
        used = set()
        out: list = [None] * len(tuple(axes))
        order = sorted(range(len(out)),
                       key=lambda i: self.priority.get(tuple(axes)[i], 0)
                       if tuple(axes)[i] else 9)
        axes_t = tuple(axes)
        for i in order:
            name = axes_t[i]
            dim = x_shape[i]
            assign = self.rules.get(name) if name else None
            if assign is None:
                continue
            assign_t = (assign,) if isinstance(assign, str) else tuple(assign)
            # longest usable prefix: lets dp2d's ("data","model") batch rule
            # fall back to plain data parallelism when batch < chips
            while assign_t:
                if (not any(a in used for a in assign_t)
                        and _axis_size(self.mesh, assign_t) > 1
                        and dim % _axis_size(self.mesh, assign_t) == 0):
                    out[i] = (assign_t if len(assign_t) > 1
                              else assign_t[0])
                    used.update(assign_t)
                    break
                assign_t = assign_t[:-1]
        return P(*out)

    def constrain(self, x: jax.Array, axes: Sequence[Optional[str]]):
        spec = self.spec(x.shape, axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _path_keys(path) -> Tuple[str, ...]:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(f"#{p.idx}")
    return tuple(keys)


def _div(mesh: Mesh, dim: int, axis) -> bool:
    return dim % _axis_size(mesh, axis) == 0


def _leaf_param_spec(keys: Tuple[str, ...], shape: Tuple[int, ...],
                     cfg: ModelConfig, mesh: Mesh, stacked: bool) -> P:
    """Spec for one parameter leaf; ``stacked`` means a leading period axis."""
    body = shape[1:] if stacked else shape
    name = keys[-1]
    parent = keys[-2] if len(keys) > 1 else ""
    spec: Tuple = (None,) * len(body)
    m = "model"
    ms = _axis_size(mesh, m)

    def ok(i, axis=m):
        return _div(mesh, body[i], axis)

    in_mixer = "mixer" in keys
    in_experts = "experts" in keys
    in_embed = "embed" in keys

    # SSM (xLSTM) mixers stay replicated at baseline — see MeshPolicy note
    if in_mixer and cfg.arch_type == "ssm":
        return P(*((None,) + spec if stacked else spec))

    # seq2d/dp2d (H2): weights replicate, tokens shard 2D.  seq2d keeps
    # the embedding vocab-sharded (seq chunks can use it); dp2d replicates
    # it too — batch holds the model axis, so a vocab-sharded table would
    # be re-gathered per CE chunk (measured 150 GiB/step, H2 iter 3).
    if cfg.attn_shard == "seq2d" and not in_embed:
        return P(*((None,) + spec if stacked else spec))
    if cfg.attn_shard == "dp2d":
        return P(*((None,) + spec if stacked else spec))
    # seq2d_fsdp (H1, llava-class): tokens shard 2D like seq2d, and the
    # weights shard over `data` (ZeRO-3: all-gathered per layer use) since
    # a 34B model cannot replicate into 16 GiB chips.
    if cfg.attn_shard == "seq2d_fsdp" and not in_embed:
        fs = [None] * len(body)
        for i, dim in enumerate(body):
            if _div(mesh, dim, "data") and dim >= 64:
                fs[i] = "data"
                break
        fs = tuple(fs)
        return P(*((None,) + fs if stacked else fs))

    if in_embed and name in ("table",):
        if ok(0):
            spec = (m, None)
    elif in_embed and name == "tables":
        if ok(1):
            spec = (None, m, None)
    elif name == "w" and parent == "unembed":
        if ok(1):
            spec = (None, m)
    elif in_experts and name in ("gate", "up"):        # (E, D, F)
        if cfg.shard_experts_2d and ok(0) and _div(mesh, body[2], "data"):
            spec = (m, None, "data")
        elif ok(0):
            spec = (m, None, None)
        elif ok(2):
            spec = (None, None, m)
    elif in_experts and name == "down":                # (E, F, D)
        if cfg.shard_experts_2d and ok(0) and _div(mesh, body[1], "data"):
            spec = (m, "data", None)
        elif ok(0):
            spec = (m, None, None)
        elif ok(1):
            spec = (None, m, None)
    elif name == "router":
        spec = (None, None)
    elif in_mixer and name == "wq":                    # (D, H, Dh)
        if cfg.attn_shard == "head_dim" and ok(2):
            spec = (None, None, m)
        elif ok(1) and cfg.attn_shard != "replicate":
            spec = (None, m, None)
    elif in_mixer and name in ("wk", "wv"):            # (D, Kh, Dh)
        if cfg.attn_shard == "head_dim" and ok(2):
            spec = (None, None, m)
        elif ok(1) and cfg.attn_shard not in ("replicate",):
            spec = (None, m, None)
    elif in_mixer and name == "wo":                    # (H, Dh, D)
        if cfg.attn_shard == "head_dim" and ok(1):
            spec = (None, m, None)
        elif ok(0) and cfg.attn_shard != "replicate":
            spec = (m, None, None)
    elif in_mixer and name in ("w_in", "w_gate", "w_up"):   # (D, Dr/Di)
        if ok(1):
            spec = (None, m)
    elif in_mixer and name in ("w_out", "w_down"):     # (Dr/Di, D)
        if ok(0):
            spec = (m, None)
    elif in_mixer and name == "conv":                  # (tw, Dr/Di)
        if ok(1):
            spec = (None, m)
    elif in_mixer and name in ("w_r", "b_r", "w_i", "b_i", "lam"):  # (Dr,)
        if ok(0):
            spec = (m,)
    elif in_mixer and name in ("wq", "wk", "wv") and len(body) == 3:
        pass  # handled above (attention); mlstm variant below
    elif in_mixer and len(body) == 3 and name in ("r",):
        spec = (None, None, None, None)[:len(body)]
    elif "mlp" in keys or "shared" in keys:
        if name in ("gate", "up") and ok(1):           # (D, F)
            spec = (None, m)
        elif name == "down" and ok(0):                 # (F, D)
            spec = (m, None)
    elif name == "w" and parent == "frontend_proj":
        spec = (None, None)

    # mLSTM block-diagonal qkv: (NH, DH, DH) -> shard output DH
    if in_mixer and name in ("wq", "wk", "wv") and len(body) == 3 \
            and body[0] == cfg.n_heads and body[1] == body[2]:
        spec = (None, None, m) if _div(mesh, body[2], m) else (None,) * 3

    if stacked:
        spec = (None,) + tuple(spec)
    return P(*spec)


def param_specs(params: Tree, cfg: ModelConfig, mesh: Mesh) -> Tree:
    """PartitionSpec tree matching ``params`` (works on ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        keys = _path_keys(path)
        stacked = "periods" in keys
        specs.append(_leaf_param_spec(keys, tuple(leaf.shape), cfg, mesh,
                                      stacked))
    return jax.tree_util.tree_unflatten(treedef, specs)


def cohort_specs(params: Tree, cfg: ModelConfig, mesh: Mesh) -> Tree:
    """NamedSharding tree for a *stacked cohort* of client models.

    The leading client axis shards over ``data``/``pod`` (one client per
    data slice); each client's parameters keep their model-parallel layout
    from :func:`param_specs` within.  The streaming round engine reshapes
    to ``(n_chunks, chunk, ...)`` inside the jit, so each scan step is one
    data-parallel cohort chunk of this layout.
    """
    data = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, P(data, *tuple(s))),
        param_specs(params, cfg, mesh),
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Cache specs (decode)
# ---------------------------------------------------------------------------

def _leaf_cache_spec(keys: Tuple[str, ...], shape: Tuple[int, ...],
                     cfg: ModelConfig, mesh: Mesh, stacked: bool,
                     data_axes) -> P:
    body = shape[1:] if stacked else shape
    name = keys[-1]
    m = "model"
    batch = body[0]
    batch_ok = _div(mesh, batch, data_axes)
    spec = [data_axes if batch_ok else None] + [None] * (len(body) - 1)

    if name in ("k", "v") and len(body) == 4:          # (B, S, Kh, Dh)
        if not batch_ok and _div(mesh, body[1], data_axes):
            spec[1] = data_axes                        # context-parallel cache
        if cfg.attn_shard == "head_dim" and _div(mesh, body[3], m):
            spec[3] = m
        elif _div(mesh, body[2], m) and cfg.attn_shard != "replicate":
            spec[2] = m
        elif spec[1] is None and _div(mesh, body[1], m):
            spec[1] = m                                # kv-seq over model
    elif name == "C" and len(body) == 4:               # (B, NH, DH, DH)
        if _div(mesh, body[2], m):
            spec[2] = m                                # value index
    elif name in ("y",) and len(body) == 2:            # rglru (B, Dr)
        if _div(mesh, body[1], m):
            spec[1] = m
    elif name == "conv" and len(body) == 3:            # (B, tw-1, Dr/Di)
        if _div(mesh, body[2], m):
            spec[2] = m
    elif name == "n" and len(body) == 3:               # mlstm (B, NH, DH)
        if _div(mesh, body[2], m):
            spec[2] = m

    if stacked:
        spec = [None] + spec
    return P(*spec)


def cache_specs(cache: Tree, cfg: ModelConfig, mesh: Mesh) -> Tree:
    data = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = []
    for path, leaf in flat:
        keys = _path_keys(path)
        stacked = "periods" in keys
        specs.append(_leaf_cache_spec(keys, tuple(leaf.shape), cfg, mesh,
                                      stacked, data))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Input (batch) specs
# ---------------------------------------------------------------------------

def batch_specs(batch: Tree, mesh: Mesh, policy=None) -> Tree:
    data = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if policy is not None and getattr(policy, "dp2d", False):
        data = data + ("model",)

    def leaf(x):
        if x.ndim == 0:
            return P()
        if _div(mesh, x.shape[0], data):
            return P(data, *([None] * (x.ndim - 1)))
        return P(*([None] * x.ndim))

    return jax.tree.map(leaf, batch)


def to_named(tree_of_specs: Tree, mesh: Mesh) -> Tree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P))


def bytes_per_chip(tree: Tree, specs: Tree, mesh: Mesh) -> int:
    """Per-device bytes of a sharded tree (ceil for uneven shards)."""
    import math
    total = 0
    for leaf, spec in zip(jax.tree.leaves(tree),
                          jax.tree.leaves(specs,
                                          is_leaf=lambda x: isinstance(x, P))):
        per = leaf.dtype.itemsize
        for dim, axes in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            per *= math.ceil(dim / _axis_size(mesh, axes))
        total += per
    return total
