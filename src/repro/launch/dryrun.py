import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) combination:
``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` on the production
mesh — 16x16 (single pod, 256 chips) and 2x16x16 (2 pods, 512 chips) —
then record ``memory_analysis()`` (fits?), ``cost_analysis()`` (FLOPs /
bytes for the roofline) and the collective schedule parsed from the
compiled HLO.

The two XLA_FLAGS lines above MUST stay the first statements in this file:
jax locks the device count at first init.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh single,multi --out results/dryrun
"""

import argparse
import json
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.core.adapters import LMAdapter
from repro.launch import sharding, steps
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.roofline import analysis


def _abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: tfm.init_params(k, cfg), jax.random.PRNGKey(0))


def _abstract_cache(cfg: ModelConfig, batch: int, seq_len: int,
                    window_override):
    return jax.eval_shape(
        lambda: tfm.init_cache(cfg, batch, seq_len,
                               window_override=window_override))


def lower_one(arch: str, shape: InputShape, *, multi_pod: bool,
              cfg_override: Optional[ModelConfig] = None,
              verbose: bool = True):
    """Lower + compile one (arch, shape, mesh) combo; return the record."""
    cfg = cfg_override or configs.get_config(arch)
    longctx = configs.needs_longctx_variant(cfg, shape)
    window_override = cfg.longctx_window if longctx else None

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    policy = sharding.MeshPolicy(mesh, cfg)
    in_specs = configs.input_specs(cfg, shape)
    params_abs = _abstract_params(cfg)
    p_specs = sharding.to_named(sharding.param_specs(cfg=cfg, mesh=mesh,
                                                     params=params_abs), mesh)
    b_specs = sharding.to_named(sharding.batch_specs(in_specs, mesh, policy), mesh)

    step = steps.step_for_shape(cfg, shape, policy,
                                window_override=window_override)

    t0 = time.time()
    with mesh:
        if shape.kind == "decode":
            cache_abs = _abstract_cache(cfg, shape.global_batch,
                                        shape.seq_len, window_override)
            c_specs = sharding.to_named(
                sharding.cache_specs(cache_abs, cfg, mesh), mesh)
            pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
            fn = jax.jit(step,
                         in_shardings=(p_specs, c_specs, b_specs, None),
                         out_shardings=(None, c_specs),
                         donate_argnums=(1,))
            lowered = fn.lower(params_abs, cache_abs, in_specs, pos_abs)
        elif shape.kind == "train":
            fn = jax.jit(step, in_shardings=(p_specs, b_specs),
                         out_shardings=(p_specs, None),
                         donate_argnums=(0,))
            lowered = fn.lower(params_abs, in_specs)
        else:  # prefill
            cache_abs = _abstract_cache(cfg, shape.global_batch,
                                        shape.seq_len, window_override)
            pc_specs = sharding.to_named(
                sharding.cache_specs(cache_abs, cfg, mesh), mesh)
            fn = jax.jit(step, in_shardings=(p_specs, b_specs),
                         out_shardings=(None, pc_specs))
            lowered = fn.lower(params_abs, in_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    p_bytes = sharding.bytes_per_chip(
        params_abs, sharding.param_specs(params_abs, cfg, mesh), mesh)
    c_bytes = 0
    if shape.kind == "decode":
        c_bytes = sharding.bytes_per_chip(
            cache_abs, sharding.cache_specs(cache_abs, cfg, mesh), mesh)
    elif shape.kind == "prefill":
        cache_abs = _abstract_cache(cfg, shape.global_batch, shape.seq_len,
                                    window_override)
        c_bytes = sharding.bytes_per_chip(
            cache_abs, sharding.cache_specs(cache_abs, cfg, mesh), mesh)
    rec = analysis.make_record(
        arch=cfg.name, shape=shape, mesh_name="2x16x16" if multi_pod
        else "16x16", chips=chips, cost=cost, mem=mem, hlo_text=hlo, cfg=cfg,
        longctx_variant=longctx, param_bytes_chip=p_bytes,
        cache_bytes_chip=c_bytes)
    d = rec.to_dict()
    d["t_lower_s"] = round(t_lower, 1)
    d["t_compile_s"] = round(t_compile, 1)
    if verbose:
        peak_gb = rec.peak_memory_per_chip / 2 ** 30
        print(f"[dryrun] {cfg.name} x {shape.name} x {d['mesh']}: OK  "
              f"flops/chip={rec.flops_per_chip:.3e}  "
              f"peak={peak_gb:.2f}GiB  "
              f"coll={rec.coll_bytes_per_chip / 2**20:.1f}MiB  "
              f"bottleneck={rec.bottleneck}  "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)",
              flush=True)
    return d


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="comma list or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    help="single,multi")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--stop-on-error", action="store_true")
    ap.add_argument("--override", default="",
                    help="comma list of cfg overrides, e.g. "
                         "attn_shard=seq2d,mlstm_chunk=512 (perf variants)")
    args = ap.parse_args(argv)

    overrides = {}
    moe_overrides = {}
    for kv in args.override.split(","):
        if not kv:
            continue
        k, v = kv.split("=")
        v = int(v) if v.lstrip("-").isdigit() else v
        if k.startswith("moe_"):
            moe_overrides[k[4:]] = v
        else:
            overrides[k] = v

    archs = list(configs.ARCH_NAMES) if args.arch == "all" \
        else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" \
        else args.shape.split(",")
    meshes = args.mesh.split(",")

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape_name in shapes:
            shape = INPUT_SHAPES[shape_name]
            for mesh_name in meshes:
                tag = f"{arch}_{shape_name}_{mesh_name}"
                out_path = os.path.join(args.out, tag + ".json")
                if os.path.exists(out_path):
                    print(f"[dryrun] {tag}: cached, skipping", flush=True)
                    continue
                try:
                    cfg_override = None
                    if overrides or moe_overrides:
                        cfg_override = configs.get_config(arch) \
                            .with_overrides(**overrides)
                        if moe_overrides and cfg_override.moe:
                            import dataclasses as _dc
                            cfg_override = cfg_override.with_overrides(
                                moe=_dc.replace(cfg_override.moe,
                                                **moe_overrides))
                    rec = lower_one(arch, shape,
                                    multi_pod=(mesh_name == "multi"),
                                    cfg_override=cfg_override)
                    with open(out_path, "w") as f:
                        json.dump(rec, f, indent=1)
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"[dryrun] {tag}: FAILED {e!r}", flush=True)
                    traceback.print_exc()
                    if args.stop_on_error:
                        return 1
    print(f"[dryrun] done; {len(failures)} failures", flush=True)
    for tag, err in failures:
        print(f"  FAIL {tag}: {err}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
