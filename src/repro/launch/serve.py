"""Serving driver: batched prefill + decode with optional FedHeN early exit.

The FedHeN side objective trains the exit head jointly with the full model,
so at serving time the same checkpoint yields two operating points:
* full-depth decode (quality), and
* early-exit decode (the simple sub-network: ~simple/complex FLOPs ratio),
plus a **confidence-based adaptive mode** (Kaya et al.-style): emit the
exit head's token when its max probability clears a threshold, otherwise
run the remaining layers.  (On the batched path we compute both heads and
report how often the exit head would have sufficed.)

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 32 --gen 16 --adaptive-threshold 0.6
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.checkpoint import restore_tree
from repro.models import transformer as tfm


def generate(params, cfg, prompts: jax.Array, gen: int, *,
             adaptive_threshold: float = 0.0, temperature: float = 0.0,
             rng=None):
    """prompts: (B, S[, NC]).  Returns (tokens, stats)."""
    b, s = prompts.shape[0], prompts.shape[1]
    total = s + gen
    logits, cache = tfm.prefill(params, cfg, prompts, cache_len=total)
    last = logits[:, -1]

    step = jax.jit(lambda c, t, p: tfm.decode_step(
        params, c, cfg, t, p, with_exit_head=True))

    out = [prompts]
    exit_agree = 0
    exit_confident = 0

    def pick(lg, key):
        if temperature > 0:
            return jax.random.categorical(key, lg / temperature, axis=-1)
        return jnp.argmax(lg, axis=-1)

    rng = rng if rng is not None else jax.random.PRNGKey(0)
    if cfg.n_codebooks > 1:
        tok = pick(last, rng)[:, None]                  # (B, 1, NC)
    else:
        tok = pick(last, rng)[:, None]                  # (B, 1)
    out.append(tok)

    for i in range(gen - 1):
        rng, key = jax.random.split(rng)
        logits, cache, exit_logits = step(cache, tok, jnp.int32(s + i))
        full_tok = pick(logits[:, -1], key)
        exit_tok = pick(exit_logits[:, -1], key)
        if adaptive_threshold > 0:
            probs = jax.nn.softmax(exit_logits[:, -1].astype(jnp.float32),
                                   axis=-1)
            conf = jnp.max(probs, axis=-1)
            confident = conf >= adaptive_threshold
            chosen = jnp.where(confident[..., None] if full_tok.ndim > 1
                               else confident, exit_tok, full_tok)
            exit_confident += int(jnp.sum(confident))
        else:
            chosen = full_tok
        exit_agree += int(jnp.sum(exit_tok == full_tok))
        tok = chosen[:, None]
        out.append(tok)

    tokens = jnp.concatenate(out, axis=1)
    n = b * max(gen - 1, 1) * (cfg.n_codebooks if cfg.n_codebooks > 1 else 1)
    stats = {"exit_agreement": exit_agree / n,
             "exit_confident_frac": exit_confident / max(b * (gen - 1), 1)}
    return tokens, stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--adaptive-threshold", type=float, default=0.0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    params = tfm.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.checkpoint:
        params, _ = restore_tree(args.checkpoint, params)

    shape = ((args.batch, args.prompt_len, cfg.n_codebooks)
             if cfg.n_codebooks > 1 else (args.batch, args.prompt_len))
    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1), shape,
                                 0, cfg.vocab_size)

    t0 = time.time()
    tokens, stats = generate(params, cfg, prompts, args.gen,
                             adaptive_threshold=args.adaptive_threshold,
                             temperature=args.temperature)
    dt = time.time() - t0
    n_new = args.batch * args.gen
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"generated {n_new} tokens in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s on CPU)")
    print(f"exit-head agreement with full model: "
          f"{stats['exit_agreement']:.2%}")
    if args.adaptive_threshold > 0:
        print(f"tokens the exit head was confident on: "
              f"{stats['exit_confident_frac']:.2%} "
              f"(these skip {cfg.n_layers - cfg.resolved_exit_layer} of "
              f"{cfg.n_layers} layers)")
    print("sample tokens:", np.asarray(tokens[0, :24]).tolist())
    return stats


if __name__ == "__main__":
    main()
