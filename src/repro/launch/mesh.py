"""Production mesh construction (TPU v5e target).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before the first
jax call, and everything else (smoke tests, benches) sees 1 device.
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes) -> jax.sharding.Mesh:
    # jax >= 0.5 wants explicit axis_types; 0.4.x has no AxisType at all
    axis_type = getattr(jax.sharding, "AxisType", None)
    kw = {} if axis_type is None else \
        {"axis_types": (axis_type.Auto,) * len(axes)}
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (16, 16) = 256 chips as ("data", "model").
    Multi-pod: (2, 16, 16) = 512 chips as ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2) -> jax.sharding.Mesh:
    """Small mesh for CPU-host sharding tests (requires enough host
    devices, e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    return _make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh: jax.sharding.Mesh):
    """The axes the batch/cohort dimension shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh: jax.sharding.Mesh) -> int:
    return mesh.shape.get("model", 1)
