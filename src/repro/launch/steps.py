"""Production step functions — what the dry-run lowers and the drivers run.

* ``train_step`` — the FedHeN complex-device step: one side-objective SGD
  step (final CE + early-exit CE, clip 10, eta).  This is the per-device
  inner step of Alg. 2 ``ClientTrainingSideObj`` at production scale; the
  cohort/round structure wraps it in core/federated.py.
* ``baseline_train_step`` — same without the side objective (NoSide /
  Decouple inner step) — used to measure the side objective's marginal cost.
* ``fed_round_step`` — one complete FedHeN round over a stacked cohort,
  streamed in ``cohort_chunk``-sized chunks (``lax.scan``) through the
  masked-aggregation fold; the chunk's client axis is policy-constrained to
  the ``data``/``pod`` mesh axes (the ``cohort`` logical rule), so the fold
  lowers to the round's all-reduce while memory stays O(chunk).
* ``prefill_step`` — logits + decode cache for a prompt batch.
* ``serve_step`` — ONE token against a seq_len cache (decode shapes).
"""

from __future__ import annotations

import functools
import warnings
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.core import aggregate, async_rounds, comm, flatten, masking
from repro.core.adapters import LMAdapter
from repro.models import transformer as tfm
from repro.models.common import NO_POLICY, Policy
from repro.obs import telemetry as obslib
from repro.optim.sgd import sgd_update

Tree = Any


def make_train_step(cfg: ModelConfig, policy: Policy = NO_POLICY, *,
                    lr: float = 0.1, clip_norm: float = 10.0,
                    side_objective: bool = True, remat: bool = True):
    adapter = LMAdapter(cfg, policy=policy, remat=remat)
    loss_fn = adapter.loss_side if side_objective else adapter.loss_complex

    def train_step(params: Tree, batch: Dict[str, jax.Array]):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params = sgd_update(params, grads, lr, clip_norm)
        return new_params, {"loss": loss}

    return train_step


def make_fed_round_step(cfg: ModelConfig, policy: Policy = NO_POLICY, *,
                        local_steps: int, lr: float = 0.1,
                        clip_norm: float = 10.0, cohort_chunk: int = 0,
                        engine: Optional[aggregate.EngineSpec] = None,
                        staleness_scheme: str = "poly",
                        staleness_decay: float = 0.5,
                        telemetry: Optional[obslib.Telemetry] = None,
                        agg_engine: Optional[str] = None,
                        agg_block_n: Optional[int] = None,
                        comm_dtype: Optional[str] = None,
                        quant_block: Optional[int] = None):
    """One FedHeN round over a stacked cohort, streaming in chunks.

    Returns ``round_step(cohort, data, is_simple, flat_mask=None,
    staleness=None, real=None) -> (new_complex, loss)`` with ``cohort``
    stacked client params (K, ...),
    ``data`` of shape (K, B, local_steps, S+1) and ``is_simple`` (K,).
    ``cohort_chunk`` must divide K (0 = one chunk); the engine scans chunk
    by chunk, folding each trained chunk into running masked sums — the
    launch-side mirror of core/federated.py's round, operating on an
    externally sharded cohort instead of tiling server params.
    ``engine`` is an :class:`repro.core.aggregate.EngineSpec` carrying
    the whole aggregation configuration — engine kind (``"flat"`` packs
    each trained chunk through the static ``core.flatten`` layout and
    folds the whole model with one accumulating ``masked_agg`` launch per
    chunk, ``block_n`` tiles; ``"tree"`` keeps the per-leaf parity fold),
    the upload wire (``spec.wire``, core/comm.py: the externally sharded
    cohort arrives already broadcast, so only the client->server
    direction crosses this step — the fold consumes the encoded uploads,
    int8 via the dequantizing masked_agg accumulate), and the stream
    dtype.  The spec's mask/layout/flat_mask fields are bound HERE at
    trace time (they depend on the cohort template), so pass a spec
    without them — ``EngineSpec(engine="tree", wire=...)`` — or ``None``
    for the all-defaults flat/f32 engine.  The legacy loose kwargs
    (``agg_engine``/``agg_block_n``/``comm_dtype``/``quant_block``) still
    work but warn: they are folded into an equivalent spec.

    Pass the precomputed flat bitvector (``flatten.pack_mask`` over the
    same layout) as ``flat_mask`` so it enters the jit as a replicated
    argument; if left ``None`` it is derived inside the trace, which XLA
    constant-folds into a params-sized ``pred`` literal baked into the
    executable (measured on the reduced config) — fine for tests, wrong
    at production scale.  The dry-run passes it explicitly.

    ``staleness`` is the async driver's seam (core/async_rounds.py owns
    the versioning; a sharded launch driver passes the result here): a
    ``(K,)`` array of per-client broadcast staleness in rounds (0 =
    fresh).  Each upload's validity weight is multiplied by
    ``staleness_weight(s, scheme=staleness_scheme,
    decay=staleness_decay)`` on the same masked-weight path NaN exclusion
    uses; ``None`` (and all-zero staleness) is exactly the synchronous
    fold.

    ``real`` is the uniform super-cohort sampler's seam
    (``core/sampling.py`` draws the plan; a launch driver passes
    ``plan.simple_real``/``plan.complex_real`` concatenated in slot
    order): a ``(K,)`` bool marking slots that hold a distinct sampled
    client.  Pad slots (``False``) fold at weight 0 through the same
    path and are excluded from the loss mean; ``None`` (stratified
    cohorts) means every slot is real — the unchanged program.

    ``telemetry`` (repro/obs; default: disabled) records ONE
    ``round_step_build`` ledger with the step's static configuration —
    the launch-side counterpart of the trainer's ``run_config`` event.
    The returned ``round_step`` itself stays pure and jit-friendly:
    callers jit it, so per-execution spans belong to the caller's host
    loop, not inside the traced function.
    """
    adapter = LMAdapter(cfg, policy=policy, remat=True)
    legacy = {"agg_engine": agg_engine, "agg_block_n": agg_block_n,
              "comm_dtype": comm_dtype, "quant_block": quant_block}
    if any(v is not None for v in legacy.values()):
        if engine is not None:
            raise ValueError(
                "pass either engine= (an EngineSpec) or the legacy "
                f"agg kwargs, not both (got both engine and "
                f"{[k for k, v in legacy.items() if v is not None]})")
        warnings.warn(
            "make_fed_round_step(agg_engine=..., comm_dtype=...) loose "
            "kwargs are deprecated; pass engine=EngineSpec(...)",
            DeprecationWarning, stacklevel=2)
        engine = aggregate.EngineSpec(
            engine=agg_engine or "flat", algorithm="fedhen",
            block_n=2048 if agg_block_n is None else agg_block_n,
            wire=comm.WireSpec(comm_dtype or "float32",
                               128 if quant_block is None else quant_block))
    spec = engine if engine is not None else aggregate.EngineSpec(
        algorithm="fedhen", wire=comm.WireSpec("float32", 128))
    if spec.wire is None:
        spec = spec.bind(wire=comm.WireSpec("float32", 128))
    wire = spec.wire
    obs = obslib.coalesce(telemetry)
    if obs.enabled:
        values = {"local_steps": int(local_steps), "lr": lr,
                  "clip_norm": clip_norm,
                  "cohort_chunk": int(cohort_chunk),
                  "staleness_scheme": staleness_scheme,
                  "staleness_decay": staleness_decay}
        values.update(aggregate.engine_attrs(spec))
        obs.ledger("round_step_build", values)

    def constrain_cohort(tree):
        return jax.tree.map(
            lambda x: policy.constrain(
                x, ("cohort",) + (None,) * (x.ndim - 1)), tree)

    def client_train(params, data, is_simple):
        """One client: local_steps of SGD (side objective for complex
        clients, subnet objective for simple ones — branchless select)."""
        def step(p, batch):
            loss_c, g_c = jax.value_and_grad(adapter.loss_side)(p, batch)
            loss_s, g_s = jax.value_and_grad(adapter.loss_simple)(p, batch)
            g = jax.tree.map(lambda a, b: jnp.where(is_simple, b, a),
                             g_c, g_s)
            return sgd_update(p, g, lr, clip_norm), loss_c
        for i in range(local_steps):
            batch = {"tokens": data[:, i]}
            params, loss = step(params, batch)
        return params, loss

    def round_step(cohort: Tree, data: jax.Array, is_simple: jax.Array,
                   flat_mask: Optional[jax.Array] = None,
                   staleness: Optional[jax.Array] = None,
                   real: Optional[jax.Array] = None):
        k = data.shape[0]
        chunk = k if cohort_chunk <= 0 else cohort_chunk
        if k % chunk:
            raise ValueError(
                f"cohort_chunk={chunk} does not divide cohort size {k}")
        n_chunks = k // chunk
        template = jax.tree.map(lambda x: x[0], cohort)
        mask = masking.transformer_subnet_mask(template, cfg)
        layout = None
        if spec.engine == "flat":
            layout = flatten.layout_of(template,
                                       total_multiple=spec.block_n)
            if flat_mask is None:  # trace-time fallback; see docstring
                flat_mask = flatten.pack_mask(layout, mask)
        agg_init, agg_fold, agg_finalize = aggregate.make_engine(
            spec.bind(mask=mask, layout=layout, flat_mask=flat_mask))

        if staleness is None:
            st_w = jnp.ones((k,), jnp.float32)
        else:
            st_w = async_rounds.staleness_weight(
                staleness, scheme=staleness_scheme, decay=staleness_decay)
        if real is not None:
            # super-cohort pad slots: weight 0 in the fold, out of the loss
            st_w = st_w * real.astype(jnp.float32)
        denom = (jnp.asarray(k, jnp.float32) if real is None
                 else jnp.maximum(jnp.sum(real.astype(jnp.float32)), 1.0))

        to_chunks = lambda x: x.reshape((n_chunks, chunk) + x.shape[1:])
        xs = (jax.tree.map(to_chunks, cohort), to_chunks(data),
              to_chunks(is_simple), to_chunks(st_w))
        if real is not None:
            xs = xs + (to_chunks(real),)

        def fold_chunk(carry, xs):
            state, loss_sum = carry
            if real is None:
                cohort_i, data_i, simple_i, st_w_i = xs
            else:
                cohort_i, data_i, simple_i, st_w_i, real_i = xs
            cohort_i = constrain_cohort(cohort_i)
            trained, losses = jax.vmap(client_train)(
                cohort_i, data_i.transpose(0, 2, 1, 3), simple_i)
            valid = jax.vmap(masking.tree_isfinite)(trained)
            state = agg_fold(state, trained, simple_i,
                             valid.astype(jnp.float32) * st_w_i)
            if real is not None:
                losses = jnp.where(real_i, losses, 0.0)
            return (state, loss_sum + jnp.sum(losses)), None

        state = agg_init(template)
        (state, loss_sum), _ = jax.lax.scan(
            fold_chunk, (state, jnp.zeros((), jnp.float32)), xs)
        new_complex, _ = agg_finalize(state, template=template)
        return new_complex, loss_sum / denom

    return round_step


def make_prefill_step(cfg: ModelConfig, policy: Policy = NO_POLICY, *,
                      window_override: Optional[int] = None,
                      cache_len: Optional[int] = None):
    def prefill_step(params: Tree, batch: Dict[str, jax.Array]):
        logits, cache = tfm.prefill(params, cfg, batch["tokens"],
                                    extra_embeds=batch.get("extra_embeds"),
                                    policy=policy,
                                    window_override=window_override,
                                    cache_len=cache_len)
        return logits, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, policy: Policy = NO_POLICY, *,
                    window_override: Optional[int] = None,
                    with_exit_head: bool = False):
    def serve_step(params: Tree, cache: Tree, batch: Dict[str, jax.Array],
                   pos: jax.Array):
        return tfm.decode_step(params, cache, cfg, batch["tokens"], pos,
                               policy=policy,
                               window_override=window_override,
                               with_exit_head=with_exit_head)

    return serve_step


def step_for_shape(cfg: ModelConfig, shape: InputShape,
                   policy: Policy = NO_POLICY, *,
                   window_override: Optional[int] = None,
                   side_objective: bool = True):
    """The step function a given input shape exercises."""
    if shape.kind == "train":
        return make_train_step(cfg, policy, side_objective=side_objective)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, policy,
                                 window_override=window_override)
    return make_serve_step(cfg, policy, window_override=window_override)
