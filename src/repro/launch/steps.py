"""Production step functions — what the dry-run lowers and the drivers run.

* ``train_step`` — the FedHeN complex-device step: one side-objective SGD
  step (final CE + early-exit CE, clip 10, eta).  This is the per-device
  inner step of Alg. 2 ``ClientTrainingSideObj`` at production scale; the
  cohort/round structure wraps it in core/federated.py.
* ``baseline_train_step`` — same without the side objective (NoSide /
  Decouple inner step) — used to measure the side objective's marginal cost.
* ``prefill_step`` — logits + decode cache for a prompt batch.
* ``serve_step`` — ONE token against a seq_len cache (decode shapes).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.core.adapters import LMAdapter
from repro.models import transformer as tfm
from repro.models.common import NO_POLICY, Policy
from repro.optim.sgd import sgd_update

Tree = Any


def make_train_step(cfg: ModelConfig, policy: Policy = NO_POLICY, *,
                    lr: float = 0.1, clip_norm: float = 10.0,
                    side_objective: bool = True, remat: bool = True):
    adapter = LMAdapter(cfg, policy=policy, remat=remat)
    loss_fn = adapter.loss_side if side_objective else adapter.loss_complex

    def train_step(params: Tree, batch: Dict[str, jax.Array]):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params = sgd_update(params, grads, lr, clip_norm)
        return new_params, {"loss": loss}

    return train_step


def make_prefill_step(cfg: ModelConfig, policy: Policy = NO_POLICY, *,
                      window_override: Optional[int] = None,
                      cache_len: Optional[int] = None):
    def prefill_step(params: Tree, batch: Dict[str, jax.Array]):
        logits, cache = tfm.prefill(params, cfg, batch["tokens"],
                                    extra_embeds=batch.get("extra_embeds"),
                                    policy=policy,
                                    window_override=window_override,
                                    cache_len=cache_len)
        return logits, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, policy: Policy = NO_POLICY, *,
                    window_override: Optional[int] = None,
                    with_exit_head: bool = False):
    def serve_step(params: Tree, cache: Tree, batch: Dict[str, jax.Array],
                   pos: jax.Array):
        return tfm.decode_step(params, cache, cfg, batch["tokens"], pos,
                               policy=policy,
                               window_override=window_override,
                               with_exit_head=with_exit_head)

    return serve_step


def step_for_shape(cfg: ModelConfig, shape: InputShape,
                   policy: Policy = NO_POLICY, *,
                   window_override: Optional[int] = None,
                   side_objective: bool = True):
    """The step function a given input shape exercises."""
    if shape.kind == "train":
        return make_train_step(cfg, policy, side_objective=side_objective)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, policy,
                                 window_override=window_override)
    return make_serve_step(cfg, policy, window_override=window_override)
