import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of a complete FedHeN ROUND at production scale.

This is the paper's actual communication pattern on the mesh: a cohort of
K active clients is simulated client-parallel over the ``data`` axis (one
client per data slice, model-parallel within), each runs local
side-objective SGD steps, and the masked server aggregation (Alg. 1
ln. 16-22) reduces the cohort axis — which XLA lowers to the all-reduce
over ``data``/``pod`` that *is* the federated communication round.  The
HLO collective schedule therefore shows the paper's upload/aggregate
traffic explicitly; FedHeN's fewer-rounds saving multiplies exactly this.

Usage:
    PYTHONPATH=src python -m repro.launch.fedround_dryrun \
        [arch] [local_steps] [single|multi]
"""

import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core import aggregate, masking
from repro.core.adapters import LMAdapter
from repro.launch import sharding
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.optim.sgd import sgd_update
from repro.roofline import analysis, hlo_walk


def make_round_step(cfg, policy, *, local_steps: int, lr=0.1, clip=10.0):
    adapter = LMAdapter(cfg, policy=policy, remat=True)

    def client_train(params, data, is_simple):
        """One client: local_steps of SGD (side objective for complex
        clients, subnet objective for simple ones — branchless select)."""
        def step(p, batch):
            loss_c, g_c = jax.value_and_grad(adapter.loss_side)(p, batch)
            loss_s, g_s = jax.value_and_grad(adapter.loss_simple)(p, batch)
            g = jax.tree.map(lambda a, b: jnp.where(is_simple, b, a),
                             g_c, g_s)
            return sgd_update(p, g, lr, clip), loss_c
        for i in range(local_steps):
            batch = {"tokens": data[:, i]}
            params, loss = step(params, batch)
        return params, loss

    def round_step(cohort, data, is_simple):
        """cohort: stacked client params (K, ...); data (K, B, steps, S+1);
        is_simple (K,).  Returns the new server complex model."""
        trained, losses = jax.vmap(client_train)(
            cohort, data.transpose(0, 2, 1, 3), is_simple)
        valid = jax.vmap(masking.tree_isfinite)(trained)
        mask = masking.transformer_subnet_mask(
            jax.tree.map(lambda x: x[0], cohort), cfg)
        new_complex = aggregate.fedhen_server_update(
            trained, is_simple, valid, mask)
        return new_complex, jnp.mean(losses)

    return round_step


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "gemma2-2b"
    local_steps = int(sys.argv[2]) if len(sys.argv) > 2 else 2
    multi = len(sys.argv) > 3 and sys.argv[3] == "multi"

    cfg = configs.get_config(arch)
    mesh = make_production_mesh(multi_pod=multi)
    policy = sharding.MeshPolicy(mesh, cfg)
    k_clients = mesh.shape["data"] * mesh.shape.get("pod", 1)
    seq, batch = 1024, 4

    params_abs = jax.eval_shape(lambda k: tfm.init_params(k, cfg),
                                jax.random.PRNGKey(0))
    p_specs = sharding.param_specs(params_abs, cfg, mesh)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # cohort axis over data/pod; each client's params model-sharded within
    cohort_specs = jax.tree.map(
        lambda s: NamedSharding(mesh, P(data_axes, *tuple(s))), p_specs,
        is_leaf=lambda x: isinstance(x, P))
    cohort_abs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((k_clients,) + x.shape, x.dtype),
        params_abs)
    data_abs = jax.ShapeDtypeStruct((k_clients, batch, local_steps, seq + 1),
                                    jnp.int32)
    flags_abs = jax.ShapeDtypeStruct((k_clients,), jnp.bool_)
    d_spec = NamedSharding(mesh, P(data_axes))

    step = make_round_step(cfg, policy, local_steps=local_steps)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, in_shardings=(cohort_specs, d_spec, d_spec),
                          donate_argnums=(0,)).lower(cohort_abs, data_abs,
                                                     flags_abs)
        compiled = lowered.compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    walk = hlo_walk.analyze(compiled.as_text())

    model_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(params_abs))
    print(f"\nFedHeN round dry-run: {cfg.name}, K={k_clients} clients x "
          f"{local_steps} local steps, mesh {'2x16x16' if multi else '16x16'}"
          f" (compiled in {dt:.0f}s)")
    print(f"  per-chip peak (CPU-sched upper bound): "
          f"{(mem.temp_size_in_bytes + mem.argument_size_in_bytes) / 2**30:.1f} GiB")
    print(f"  per-chip collective bytes: "
          f"{walk['total_collective_bytes'] / 2**30:.2f} GiB "
          f"({walk['collective_counts']})")
    print(f"  model size (1 client upload): {model_bytes / 2**30:.2f} GiB — "
          f"the aggregation all-reduce IS the round's communication; "
          f"FedHeN's {1.1}-{3.3}x fewer rounds multiply this.")


if __name__ == "__main__":
    main()
