import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of a complete FedHeN ROUND at production scale.

This is the paper's actual communication pattern on the mesh: a cohort of
K active clients is simulated client-parallel over the ``data`` axis (one
client per data slice, model-parallel within), each runs local
side-objective SGD steps, and the masked server aggregation (Alg. 1
ln. 16-22) reduces the cohort axis — which XLA lowers to the all-reduce
over ``data``/``pod`` that *is* the federated communication round.  The
HLO collective schedule therefore shows the paper's upload/aggregate
traffic explicitly; FedHeN's fewer-rounds saving multiplies exactly this.

With ``cohort_chunk`` (4th arg) the round streams the cohort through the
chunked engine (``steps.make_fed_round_step``): K can exceed the data-axis
size by any multiple while the per-chip working set stays O(chunk).

Usage:
    PYTHONPATH=src python -m repro.launch.fedround_dryrun \
        [arch] [local_steps] [single|multi] [cohort_chunk]
"""

import math
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core import flatten, masking
from repro.launch import sharding
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_fed_round_step
from repro.models import transformer as tfm
from repro.roofline import analysis, hlo_walk


# one block size for BOTH the step's internal layout and the externally
# built flat_mask below — they must agree for the kernel path
AGG_BLOCK_N = 2048


def make_round_step(cfg, policy, *, local_steps: int, lr=0.1, clip=10.0,
                    cohort_chunk: int = 0, agg_block_n: int = AGG_BLOCK_N):
    """The streamed FedHeN round step (see ``steps.make_fed_round_step``)."""
    from repro.core import aggregate, comm
    return make_fed_round_step(
        cfg, policy, local_steps=local_steps, lr=lr,
        clip_norm=clip, cohort_chunk=cohort_chunk,
        engine=aggregate.EngineSpec(algorithm="fedhen",
                                    block_n=agg_block_n,
                                    wire=comm.WireSpec("float32", 128)))


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "gemma2-2b"
    local_steps = int(sys.argv[2]) if len(sys.argv) > 2 else 2
    multi = len(sys.argv) > 3 and sys.argv[3] == "multi"
    cohort_chunk = int(sys.argv[4]) if len(sys.argv) > 4 else 0

    cfg = configs.get_config(arch)
    mesh = make_production_mesh(multi_pod=multi)
    policy = sharding.MeshPolicy(mesh, cfg)
    data_size = mesh.shape["data"] * mesh.shape.get("pod", 1)
    # with chunking the cohort scales past the data axis (4x), rounded up
    # so that both the chunk size (the launch-side engine errors instead of
    # padding) and the data axis (pjit input sharding) divide it
    if cohort_chunk <= 0:
        k_clients = data_size
    else:
        step = math.lcm(cohort_chunk, data_size)
        k_clients = -(-4 * data_size // step) * step
    seq, batch = 1024, 4

    params_abs = jax.eval_shape(lambda k: tfm.init_params(k, cfg),
                                jax.random.PRNGKey(0))
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # cohort axis over data/pod; each client's params model-sharded within
    cohort_specs = sharding.cohort_specs(params_abs, cfg, mesh)
    cohort_abs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((k_clients,) + x.shape, x.dtype),
        params_abs)
    data_abs = jax.ShapeDtypeStruct((k_clients, batch, local_steps, seq + 1),
                                    jnp.int32)
    flags_abs = jax.ShapeDtypeStruct((k_clients,), jnp.bool_)
    d_spec = NamedSharding(mesh, P(data_axes))

    step = make_round_step(cfg, policy, local_steps=local_steps,
                           cohort_chunk=cohort_chunk)
    # the flat fold's precomputed mask bitvector: a round ARGUMENT (one
    # replicated pred[n_flat] buffer), never a baked executable constant
    layout = flatten.layout_of(params_abs, total_multiple=AGG_BLOCK_N)
    flat_mask = flatten.pack_mask(
        layout, masking.transformer_subnet_mask(params_abs, cfg))
    t0 = time.time()
    with mesh:
        lowered = jax.jit(step,
                          in_shardings=(cohort_specs, d_spec, d_spec, None),
                          donate_argnums=(0,)).lower(cohort_abs, data_abs,
                                                     flags_abs, flat_mask)
        compiled = lowered.compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    walk = hlo_walk.analyze(compiled.as_text())

    model_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(params_abs))
    print(f"\nFedHeN round dry-run: {cfg.name}, K={k_clients} clients x "
          f"{local_steps} local steps, mesh {'2x16x16' if multi else '16x16'}"
          f"{f', chunk={cohort_chunk}' if cohort_chunk else ''}"
          f" (compiled in {dt:.0f}s)")
    print(f"  per-chip peak (CPU-sched upper bound): "
          f"{(mem.temp_size_in_bytes + mem.argument_size_in_bytes) / 2**30:.1f} GiB")
    print(f"  per-chip collective bytes: "
          f"{walk['total_collective_bytes'] / 2**30:.2f} GiB "
          f"({walk['collective_counts']})")
    print(f"  model size (1 client upload): {model_bytes / 2**30:.2f} GiB — "
          f"the aggregation all-reduce IS the round's communication; "
          f"FedHeN's {1.1}-{3.3}x fewer rounds multiply this.")


if __name__ == "__main__":
    main()
