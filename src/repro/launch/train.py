"""Federated training driver (FedHeN / NoSide / Decouple).

Runs the paper's protocol end-to-end on any zoo architecture (or the
paper's own ResNet/CIFAR setting), with round-resumable checkpointing and
communication accounting.

Examples:
    # paper setting, reduced scale (synthetic CIFAR-shaped data)
    PYTHONPATH=src python -m repro.launch.train --model resnet \
        --algorithm fedhen --rounds 50 --eval-every 10

    # federated LM fine-tuning on a reduced zoo architecture
    PYTHONPATH=src python -m repro.launch.train --model lm \
        --arch gemma2-2b --reduced --algorithm fedhen --rounds 20
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.checkpoint import restore_trainer, save_trainer
from repro.configs.base import FedConfig
from repro.core.adapters import LMAdapter, ResNetAdapter
from repro.core.federated import FederatedTrainer, rounds_to_target
from repro.data import federated as fed_data
from repro.data.synthetic import synthetic_cifar, synthetic_lm
from repro.obs import telemetry as obslib


def build_trainer(args, telemetry=None) -> tuple:
    fed = FedConfig(
        n_devices=args.clients, n_simple=args.clients // 2,
        participation=args.participation, rounds=args.rounds,
        local_epochs=args.local_epochs, lr=args.lr,
        batch_size=args.batch_size, iid=not args.non_iid,
        dirichlet_alpha=args.alpha, algorithm=args.algorithm,
        seed=args.seed, cohort_chunk=args.cohort_chunk,
        sample_uniform=args.sample_uniform,
        agg_engine=args.agg_engine, agg_block_n=args.agg_block_n,
        agg_stream_dtype=args.agg_stream_dtype,
        agg_memory_budget_mb=args.agg_memory_budget_mb,
        comm_dtype=args.comm_dtype, quant_block=args.quant_block,
        topk_frac=args.topk_frac,
        stochastic_rounding=args.stochastic_rounding,
        error_feedback=args.error_feedback,
        async_lag=args.async_lag, async_staleness=args.staleness,
        async_decay=args.staleness_decay,
        variance_reduction=args.variance_reduction,
        state_store_backend=args.state_store_backend)
    fed.validate()

    if args.model == "resnet":
        data = synthetic_cifar(args.data_points, 10, seed=args.seed)
        test = synthetic_cifar(512, 10, seed=args.seed + 999)
        test_batch = {"images": jnp.asarray(test["images"]),
                      "labels": jnp.asarray(test["labels"])}
        adapter = ResNetAdapter(10)
    else:
        cfg = (configs.get_reduced(args.arch) if args.reduced
               else configs.get_config(args.arch))
        data = synthetic_lm(args.data_points, args.seq_len, cfg.vocab_size,
                            seed=args.seed, n_codebooks=cfg.n_codebooks)
        test = synthetic_lm(64, args.seq_len, cfg.vocab_size,
                            seed=args.seed + 999,
                            n_codebooks=cfg.n_codebooks)
        test_batch = {"tokens": jnp.asarray(test["tokens"])}
        adapter = LMAdapter(cfg)

    split = (fed_data.iid_split if fed.iid else
             lambda d, n, seed: fed_data.dirichlet_split(
                 d, n, fed.dirichlet_alpha, seed))
    shards = split(data, fed.n_devices, args.seed + 1)
    shards = [{k: jnp.asarray(v) for k, v in s.items() if k != "labels"
               or args.model == "resnet"} for s in shards]
    trainer = FederatedTrainer(adapter, fed, shards, telemetry=telemetry)
    return trainer, test_batch


def _chunk_arg(v: str):
    return v if v == "auto" else int(v)


def build_parser() -> argparse.ArgumentParser:
    """The driver's full CLI.  Factored out of :func:`main` so tests can
    assert the FedConfig <-> flag mapping stays complete (every config
    field reachable from the command line or explicitly exempted)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=("resnet", "lm"), default="resnet")
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced variant of --arch (CPU-friendly)")
    ap.add_argument("--algorithm", default="fedhen",
                    choices=("fedhen", "noside", "decouple"))
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--participation", type=float, default=0.1)
    ap.add_argument("--sample-uniform", action="store_true",
                    help="the paper's exact uniform cohort sampling: one "
                         "draw of ceil(participation*clients) over the "
                         "whole population, routed into static per-arch "
                         "slots (unfilled slots fold at weight 0); "
                         "default is the stratified per-arch "
                         "approximation")
    ap.add_argument("--cohort-chunk", type=_chunk_arg, default=0,
                    help="stream the cohort in chunks of this many clients "
                         "(0 = whole cohort at once; 'auto' = derive from "
                         "--agg-memory-budget-mb and the flat layout's "
                         "per-client footprint); memory is O(chunk)")
    ap.add_argument("--agg-engine", choices=("flat", "tree"), default="flat",
                    help="aggregation fold: one fused masked_agg launch "
                         "over the flat-packed model (flat) or one per "
                         "leaf (tree, parity reference)")
    ap.add_argument("--agg-block-n", type=int, default=2048,
                    help="masked_agg kernel tile width (multiple of 128)")
    ap.add_argument("--agg-stream-dtype", default="float32",
                    choices=("float32", "bfloat16"),
                    help="dtype trained chunks stream through the fold in "
                         "(accumulation is always f32)")
    ap.add_argument("--agg-memory-budget-mb", type=float, default=512.0,
                    help="memory budget targeted by --cohort-chunk auto")
    ap.add_argument("--comm-dtype", default="float32",
                    choices=("float32", "bfloat16", "int8"),
                    help="wire format of the communication path: clients "
                         "train on the decoded broadcast and uploads are "
                         "folded through it (int8 = symmetric per-group "
                         "quantization with f32 scales, dequantized inside "
                         "the masked_agg accumulate)")
    ap.add_argument("--quant-block", type=int, default=128,
                    help="int8 wire scale-group size (elements per f32 "
                         "scale; must divide 128)")
    ap.add_argument("--topk-frac", type=float, default=1.0,
                    help="upload sparsification: each client uploads only "
                         "the top-k largest-|x| entries of its DELTA "
                         "against the broadcast it trained on (k = frac * "
                         "population size, rounded up to a lane multiple), "
                         "as index+value payloads; 1.0 = dense uploads "
                         "(the pre-existing wire, bit-identical)")
    ap.add_argument("--stochastic-rounding", action="store_true",
                    help="unbiased stochastic rounding on lossy upload "
                         "encodes (int8/bf16): E[decode(encode(x))] = x, "
                         "seeded per client per round (bit-reproducible); "
                         "broadcasts stay round-to-nearest")
    ap.add_argument("--error-feedback", action="store_true",
                    help="per-client error-feedback residuals: the wire "
                         "compression error of each upload is remembered "
                         "in a flat state-store row and added to the next "
                         "upload's delta, so compression error accumulates "
                         "into the average instead of being lost; requires "
                         "a lossy upload path (bf16/int8 wire or "
                         "--topk-frac < 1)")
    ap.add_argument("--async-lag", type=int, default=0,
                    help="bounded broadcast staleness in chunk folds: "
                         "chunk i of a round trains on the server version "
                         "published at fold i-lag (the first lag chunks "
                         "overlap the previous round's fold); 0 = fully "
                         "synchronous")
    ap.add_argument("--staleness", default="poly", choices=("poly", "none"),
                    help="staleness weighting of stale uploads: 'poly' = "
                         "FedAsync 1/(1+s)^a decay, 'none' = full weight")
    ap.add_argument("--staleness-decay", type=float, default=0.5,
                    help="exponent a of the polynomial staleness decay "
                         "1/(1+s)^a")
    ap.add_argument("--variance-reduction", default="none",
                    choices=("none", "scaffold"),
                    help="client-drift correction: 'scaffold' keeps a "
                         "per-client control variate in the flat state "
                         "store and corrects local gradients by c - c_i "
                         "(Karimireddy et al. 2020, option II); cv "
                         "exchange is billed raw f32 on top of the wire")
    ap.add_argument("--state-store-backend", default="auto",
                    choices=("auto", "device", "host", "mmap"),
                    help="where the (N_clients, n_flat) per-client state "
                         "rows live: device array, host numpy, or an "
                         "mmap-backed file; 'auto' picks by footprint")
    ap.add_argument("--local-epochs", type=int, default=5)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--batch-size", type=int, default=50)
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--data-points", type=int, default=4000)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--checkpoint-format", default="tree",
                    choices=("tree", "flat"),
                    help="'flat' saves ONE packed flat buffer per model "
                         "through the comm wire encoder (int8 wires make "
                         "it lossy — same error the broadcast carries)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--target-simple", type=float, default=0.0)
    ap.add_argument("--history-out", default="")
    ap.add_argument("--telemetry", action="store_true",
                    help="instrument the run with the repro/obs telemetry "
                         "layer (round-phase spans, client-health "
                         "counters, comm/roofline ledgers); off by "
                         "default — the trainer runs the no-op path")
    ap.add_argument("--telemetry-out", default="",
                    help="write the telemetry event stream as JSONL to "
                         "this path (implies --telemetry; render it with "
                         "tools/obs_report.py)")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    # the driver's prints always route through a telemetry stdout sink
    # (line formats are bit-identical — the sink prints log events
    # verbatim); the TRAINER is only instrumented when asked, so the
    # library default stays the no-op path
    instrument = args.telemetry or bool(args.telemetry_out)
    tel = obslib.Telemetry([obslib.StdoutSink()])
    if args.telemetry_out:
        tel.add_sink(obslib.JsonlSink(args.telemetry_out))
    say = tel.log

    trainer, test_batch = build_trainer(
        args, telemetry=tel if instrument else None)
    if args.cohort_chunk == "auto":
        per_mb = trainer.stream_bytes_per_client() / 2**20
        say(f"cohort_chunk=auto -> {trainer.cohort_chunk} "
            f"(per-client packed {per_mb:.2f} MiB at wire/stream dtype, "
            f"budget {args.agg_memory_budget_mb:.0f} MiB)")
    if args.async_lag:
        eng = trainer.async_engine
        steady = eng.schedule(10**9)
        say(f"async rounds: lag={eng.lag} folds/round="
            f"{eng.folds_per_round} versions={eng.n_versions} "
            f"staleness/chunk={list(map(int, steady[0]))} + "
            f"{list(map(int, steady[1]))} "
            f"(weights {args.staleness}, a={args.staleness_decay})")
    if args.comm_dtype != "float32" or trainer.wire.uses_deltas:
        say(f"comm wire {args.comm_dtype}: "
            f"{trainer.bytes_per_round / 1e6:.3f} MB/round measured "
            f"(down {trainer.bytes_down_per_round / 1e6:.3f} + up "
            f"{trainer.bytes_up_per_round / 1e6:.3f}; f32 analytic "
            f"{trainer.analytic_bytes_per_round() / 1e6:.3f})")
    if args.resume and args.checkpoint and os.path.exists(args.checkpoint):
        # trainer-level restore: server state + sampler validation +
        # client-state matrix.  The sampler is pure in (seed, round), so
        # restoring the round counter resumes the exact cohort sequence
        # an uninterrupted run would have drawn (test-enforced).
        restore_trainer(args.checkpoint, trainer,
                        fmt=args.checkpoint_format)
        say(f"resumed from round {trainer.server.round}")

    t0 = time.time()
    history = []
    for r in range(trainer.server.round, args.rounds):
        m = trainer.run_round()
        if args.eval_every and (r + 1) % args.eval_every == 0:
            ev = trainer.evaluate(test_batch)
            m.update(ev)
            if instrument:
                tel.set_round(r + 1)
                tel.ledger("eval", ev)
            say(f"[round {r + 1:4d}] " + "  ".join(
                f"{k}={v:.4f}" for k, v in sorted(m.items())))
        m["round"] = r + 1
        history.append(m)
        if args.checkpoint and args.checkpoint_every and \
                (r + 1) % args.checkpoint_every == 0:
            save_trainer(args.checkpoint, trainer,
                         fmt=args.checkpoint_format)

    dt = time.time() - t0
    say(f"\n{args.algorithm}: {args.rounds} rounds in {dt:.1f}s "
        f"({trainer.total_bytes / 1e6:.1f} MB communicated)")
    if args.target_simple:
        r = rounds_to_target(history, "acc_simple", args.target_simple)
        say(f"rounds to simple acc {args.target_simple}: {r}")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f, indent=1)
    tel.close()
    if args.telemetry_out:
        print(f"telemetry run log: {args.telemetry_out} "
              f"(render: python tools/obs_report.py {args.telemetry_out})")
    return history


if __name__ == "__main__":
    main()
