"""Render a telemetry JSONL run log into a human-readable summary.

The logic lives here (importable, unit-tested); ``tools/obs_report.py``
is a thin CLI wrapper.  Input is the event stream a :class:`JsonlSink`
wrote — see :mod:`repro.obs.telemetry` for the schema.  Output sections:

* **run** — the ``run_config`` ledger (algorithm, cohort geometry, wire).
* **rounds** — count, median/total wall clock per phase from the timed
  spans, and the first round's compile-vs-execute split.
* **comm** — bytes/round (down, up) and cumulative totals from the
  ``comm_bytes`` ledgers, exactly the trainer's measured accounting.
* **client health** — NaN-excluded device total, weight-0 padding slots,
  the merged staleness histogram, and version-cache hit/miss counts.
* **progress** — eval-metric trajectory from ``eval`` ledgers and, when
  a target is given, rounds-to-target — the headline FedHeN comparison
  number.  Direction is inferred from the metric name: ``acc*``/``*acc*``
  metrics count as reached at-or-above the target, everything else
  (losses) at-or-below.

Everything here is stdlib-only and tolerant of partial logs: a crashed
run renders whatever was flushed.
"""

from __future__ import annotations

import statistics
from typing import Any, Dict, List, Optional

from repro.obs.telemetry import read_jsonl


def _median(xs: List[float]) -> Optional[float]:
    return statistics.median(xs) if xs else None


def higher_is_better(metric: str) -> bool:
    """Target direction inferred from the metric name: ``acc``-bearing
    metrics maximize (reached at-or-above), everything else — losses —
    minimizes (reached at-or-below).  Shared with
    ``core.federated.rounds_to_target`` so a run report and the in-process
    history agree on what "reached" means."""
    return "acc" in metric


def summarize(events: List[Dict[str, Any]],
              target: Optional[float] = None,
              target_metric: str = "loss_complex") -> Dict[str, Any]:
    """Digest an event stream into the report's section dict."""
    spans = [e for e in events if e.get("kind") == "span"]
    counters = [e for e in events if e.get("kind") == "counter"]
    ledgers = [e for e in events if e.get("kind") == "ledger"]

    def ledger_values(name: str) -> List[Dict[str, Any]]:
        return [e.get("values", {}) for e in ledgers if e.get("name") == name]

    # -- run config (first wins; there is one per run) ----------------------
    run_cfgs = ledger_values("run_config")
    run_config = run_cfgs[0] if run_cfgs else {}

    # -- spans: wall clock per phase name -----------------------------------
    durs: Dict[str, List[float]] = {}
    for s in spans:
        if s.get("dur_s") is not None:
            durs.setdefault(s["name"], []).append(float(s["dur_s"]))
    phase_wall = {
        name: {"n": len(xs), "median_s": _median(xs), "total_s": sum(xs)}
        for name, xs in sorted(durs.items())
    }
    rounds_seen = sorted({s["round"] for s in spans
                          if s.get("name") == "round"
                          and s.get("round") is not None})
    compile_s = sum(durs.get("compile", []))
    trace_lower_s = sum(durs.get("trace_lower", []))
    execute_med = _median(durs.get("execute", []))

    # -- comm ledgers -------------------------------------------------------
    comm = ledger_values("comm_bytes")
    comm_summary: Dict[str, Any] = {}
    if comm:
        last = comm[-1]
        comm_summary = {
            "rounds_accounted": len(comm),
            "bytes_down_per_round": _median(
                [c["down"] for c in comm if "down" in c]),
            "bytes_up_per_round": _median(
                [c["up"] for c in comm if "up" in c]),
            "cum_down": last.get("cum_down"),
            "cum_up": last.get("cum_up"),
            "cum_total": last.get("cum_total"),
        }

    # -- roofline (first-round lowered program) -----------------------------
    rooflines = ledger_values("roofline")
    roofline = rooflines[0] if rooflines else {}

    # -- client health ------------------------------------------------------
    def counter_total(name: str) -> int:
        return int(sum(c.get("value", 0) for c in counters
                       if c.get("name") == name))

    staleness: Dict[str, int] = {}
    for h in ledger_values("staleness_hist"):
        for k, v in h.items():
            staleness[k] = staleness.get(k, 0) + int(v)
    # participation histogram: last wins (cumulative over the run, unlike
    # the per-round staleness histograms which sum)
    part_hists = ledger_values("participation_hist")
    states = ledger_values("client_state")
    ef_stores = ledger_values("ef_store")
    health = {
        "nan_excluded_devices": counter_total("nan_excluded_devices"),
        "padding_weight0_clients": counter_total("padding_weight0_clients"),
        "version_cache_hit": counter_total("version_cache_hit"),
        "version_cache_miss": counter_total("version_cache_miss"),
        "staleness_hist": dict(sorted(staleness.items(),
                                      key=lambda kv: int(kv[0]))),
        "participation_hist": part_hists[-1] if part_hists else {},
        "client_state_bytes": (states[-1].get("state_bytes")
                               if states else None),
        # error-feedback residual store (last ledger wins — the byte
        # counters are cumulative over the run, like client_state)
        "ef_store": ef_stores[-1] if ef_stores else {},
    }

    # -- progress / rounds-to-target ----------------------------------------
    evals = [(e.get("round"), e.get("values", {}))
             for e in ledgers if e.get("name") == "eval"]
    trajectory = [(r, v.get(target_metric)) for r, v in evals
                  if v.get(target_metric) is not None]
    maximize = higher_is_better(target_metric)
    rounds_to_target = None
    if target is not None:
        for r, v in trajectory:
            if v is not None and (v >= target if maximize
                                  else v <= target):
                rounds_to_target = r
                break

    return {
        "run_config": run_config,
        "rounds": {
            "n_rounds": len(rounds_seen) or len(comm),
            "phase_wall": phase_wall,
            "compile_s": compile_s,
            "trace_lower_s": trace_lower_s,
            "execute_median_s": execute_med,
        },
        "comm": comm_summary,
        "roofline": roofline,
        "health": health,
        "progress": {
            "metric": target_metric,
            "target": target,
            "trajectory": trajectory,
            "rounds_to_target": rounds_to_target,
            "final": trajectory[-1][1] if trajectory else None,
        },
        "n_events": len(events),
    }


def _fmt_bytes(n: Optional[float]) -> str:
    if n is None:
        return "-"
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{n:.0f} B"
        n /= 1024
    return f"{n:.1f} GiB"


def _fmt_s(x: Optional[float]) -> str:
    return "-" if x is None else f"{x:.3f}s"


def render(summary: Dict[str, Any]) -> str:
    """Format a :func:`summarize` dict as the printed report."""
    lines: List[str] = []
    add = lines.append
    add("== telemetry run report ==")
    add(f"events: {summary['n_events']}")

    cfg = summary["run_config"]
    if cfg:
        add("")
        add("-- run --")
        for k in sorted(cfg):
            add(f"  {k}: {cfg[k]}")

    r = summary["rounds"]
    add("")
    add("-- rounds --")
    add(f"  rounds: {r['n_rounds']}")
    add(f"  compile (first round): {_fmt_s(r['compile_s'])} "
        f"(trace+lower {_fmt_s(r['trace_lower_s'])})")
    add(f"  execute median: {_fmt_s(r['execute_median_s'])}")
    for name, w in r["phase_wall"].items():
        add(f"  span {name}: n={w['n']} median={_fmt_s(w['median_s'])} "
            f"total={_fmt_s(w['total_s'])}")

    c = summary["comm"]
    if c:
        add("")
        add("-- comm --")
        add(f"  bytes/round down: {_fmt_bytes(c['bytes_down_per_round'])}  "
            f"up: {_fmt_bytes(c['bytes_up_per_round'])}")
        add(f"  cumulative: down {_fmt_bytes(c['cum_down'])}  "
            f"up {_fmt_bytes(c['cum_up'])}  "
            f"total {_fmt_bytes(c['cum_total'])}")

    roof = summary["roofline"]
    if roof:
        add("")
        add("-- roofline (lowered round) --")
        for k in sorted(roof):
            add(f"  {k}: {roof[k]}")

    h = summary["health"]
    add("")
    add("-- client health --")
    add(f"  NaN-excluded devices: {h['nan_excluded_devices']}")
    add(f"  weight-0 padding slots: {h['padding_weight0_clients']}")
    add(f"  version cache: {h['version_cache_hit']} hit / "
        f"{h['version_cache_miss']} miss")
    if h["staleness_hist"]:
        hist = "  ".join(f"s={k}:{v}" for k, v in h["staleness_hist"].items())
        add(f"  staleness histogram: {hist}")
    if h.get("participation_hist"):
        hist = "  ".join(f"n={k}:{v}"
                         for k, v in h["participation_hist"].items())
        add(f"  participation histogram: {hist}")
    if h.get("client_state_bytes") is not None:
        add(f"  client-state matrix: {_fmt_bytes(h['client_state_bytes'])}")
    ef = h.get("ef_store") or {}
    if ef:
        add(f"  error-feedback store: {_fmt_bytes(ef.get('store_bytes'))} "
            f"(gathered {_fmt_bytes(ef.get('cum_gathered_bytes'))}, "
            f"scattered {_fmt_bytes(ef.get('cum_scattered_bytes'))})")

    p = summary["progress"]
    if p["trajectory"]:
        add("")
        add("-- progress --")
        add(f"  metric: {p['metric']}  final: {p['final']:.4f}")
        if p["target"] is not None:
            hit = p["rounds_to_target"]
            add(f"  target {p['target']}: "
                + (f"reached at round {hit}" if hit is not None
                   else "not reached"))
    return "\n".join(lines)


def report_path(path: str, target: Optional[float] = None,
                target_metric: str = "loss_complex") -> str:
    """Read a JSONL run log and return the rendered report."""
    return render(summarize(read_jsonl(path), target=target,
                            target_metric=target_metric))


# ---------------------------------------------------------------------------
# Run comparison (A vs B diff of two summarized logs)
# ---------------------------------------------------------------------------

def _delta(a: Optional[float], b: Optional[float]) -> Optional[float]:
    return None if a is None or b is None else float(b) - float(a)


def compare_summaries(a: Dict[str, Any],
                      b: Dict[str, Any]) -> Dict[str, Any]:
    """Diff two :func:`summarize` dicts (B relative to A).

    The sections an A/B experiment actually argues over: per-phase wall
    clock (medians), comm bytes per round + cumulative totals, and the
    progress section's rounds-to-target / final metric — each as
    ``{"a": ..., "b": ..., "delta": b - a}`` (``delta`` None when either
    side is missing).  Config keys whose values differ are listed so a
    report never silently compares apples to oranges.
    """
    cfg_a, cfg_b = a["run_config"], b["run_config"]
    config_diff = {
        k: {"a": cfg_a.get(k), "b": cfg_b.get(k)}
        for k in sorted(set(cfg_a) | set(cfg_b))
        if cfg_a.get(k) != cfg_b.get(k)
    }
    pa, pb = a["rounds"]["phase_wall"], b["rounds"]["phase_wall"]
    phases = {}
    for name in sorted(set(pa) | set(pb)):
        ma = pa.get(name, {}).get("median_s")
        mb = pb.get(name, {}).get("median_s")
        phases[name] = {"a": ma, "b": mb, "delta": _delta(ma, mb)}
    comm = {}
    for key in ("bytes_down_per_round", "bytes_up_per_round",
                "cum_total"):
        va, vb = a["comm"].get(key), b["comm"].get(key)
        comm[key] = {"a": va, "b": vb, "delta": _delta(va, vb)}
    prog_a, prog_b = a["progress"], b["progress"]
    progress = {
        "metric": prog_a["metric"],
        "rounds_to_target": {
            "a": prog_a["rounds_to_target"],
            "b": prog_b["rounds_to_target"],
            "delta": _delta(prog_a["rounds_to_target"],
                            prog_b["rounds_to_target"]),
        },
        "final": {"a": prog_a["final"], "b": prog_b["final"],
                  "delta": _delta(prog_a["final"], prog_b["final"])},
    }
    return {
        "config_diff": config_diff,
        "rounds": {"a": a["rounds"]["n_rounds"],
                   "b": b["rounds"]["n_rounds"]},
        "phases": phases,
        "comm": comm,
        "progress": progress,
    }


def _fmt_pair(row: Dict[str, Any], fmt) -> str:
    d = row["delta"]
    sign = "" if d is None or d < 0 else "+"
    return (f"A={fmt(row['a'])}  B={fmt(row['b'])}  "
            f"delta={'-' if d is None else sign + fmt(d)}")


def render_compare(cmp: Dict[str, Any]) -> str:
    """Format a :func:`compare_summaries` dict as the printed diff."""
    lines: List[str] = []
    add = lines.append
    add("== telemetry run comparison (B - A) ==")
    add(f"rounds: A={cmp['rounds']['a']}  B={cmp['rounds']['b']}")
    if cmp["config_diff"]:
        add("")
        add("-- config differences --")
        for k, row in cmp["config_diff"].items():
            add(f"  {k}: A={row['a']}  B={row['b']}")
    if cmp["phases"]:
        add("")
        add("-- phase wall clock (median) --")
        for name, row in cmp["phases"].items():
            add(f"  {name}: " + _fmt_pair(row, _fmt_s))
    add("")
    add("-- comm --")
    for key, row in cmp["comm"].items():
        add(f"  {key}: " + _fmt_pair(row, _fmt_bytes))
    p = cmp["progress"]
    add("")
    add(f"-- progress ({p['metric']}) --")
    rt = p["rounds_to_target"]
    if rt["a"] is not None or rt["b"] is not None:
        add("  rounds_to_target: "
            + _fmt_pair(rt, lambda v: "-" if v is None else f"{v:g}"))
    add("  final: "
        + _fmt_pair(p["final"], lambda v: "-" if v is None else f"{v:.4f}"))
    return "\n".join(lines)


def compare_paths(path_a: str, path_b: str,
                  target: Optional[float] = None,
                  target_metric: str = "loss_complex") -> str:
    """Read two JSONL run logs and return the rendered A/B diff."""
    sa = summarize(read_jsonl(path_a), target=target,
                   target_metric=target_metric)
    sb = summarize(read_jsonl(path_b), target=target,
                   target_metric=target_metric)
    return render_compare(compare_summaries(sa, sb))
