"""Render a telemetry JSONL run log into a human-readable summary.

The logic lives here (importable, unit-tested); ``tools/obs_report.py``
is a thin CLI wrapper.  Input is the event stream a :class:`JsonlSink`
wrote — see :mod:`repro.obs.telemetry` for the schema.  Output sections:

* **run** — the ``run_config`` ledger (algorithm, cohort geometry, wire).
* **rounds** — count, median/total wall clock per phase from the timed
  spans, and the first round's compile-vs-execute split.
* **comm** — bytes/round (down, up) and cumulative totals from the
  ``comm_bytes`` ledgers, exactly the trainer's measured accounting.
* **client health** — NaN-excluded device total, weight-0 padding slots,
  the merged staleness histogram, and version-cache hit/miss counts.
* **progress** — eval-metric trajectory from ``eval`` ledgers and, when
  a target is given, rounds-to-target — the headline FedHeN comparison
  number.  Direction is inferred from the metric name: ``acc*``/``*acc*``
  metrics count as reached at-or-above the target, everything else
  (losses) at-or-below.

Everything here is stdlib-only and tolerant of partial logs: a crashed
run renders whatever was flushed.
"""

from __future__ import annotations

import statistics
from typing import Any, Dict, List, Optional

from repro.obs.telemetry import read_jsonl


def _median(xs: List[float]) -> Optional[float]:
    return statistics.median(xs) if xs else None


def higher_is_better(metric: str) -> bool:
    """Target direction inferred from the metric name: ``acc``-bearing
    metrics maximize (reached at-or-above), everything else — losses —
    minimizes (reached at-or-below).  Shared with
    ``core.federated.rounds_to_target`` so a run report and the in-process
    history agree on what "reached" means."""
    return "acc" in metric


def summarize(events: List[Dict[str, Any]],
              target: Optional[float] = None,
              target_metric: str = "loss_complex") -> Dict[str, Any]:
    """Digest an event stream into the report's section dict."""
    spans = [e for e in events if e.get("kind") == "span"]
    counters = [e for e in events if e.get("kind") == "counter"]
    ledgers = [e for e in events if e.get("kind") == "ledger"]

    def ledger_values(name: str) -> List[Dict[str, Any]]:
        return [e.get("values", {}) for e in ledgers if e.get("name") == name]

    # -- run config (first wins; there is one per run) ----------------------
    run_cfgs = ledger_values("run_config")
    run_config = run_cfgs[0] if run_cfgs else {}

    # -- spans: wall clock per phase name -----------------------------------
    durs: Dict[str, List[float]] = {}
    for s in spans:
        if s.get("dur_s") is not None:
            durs.setdefault(s["name"], []).append(float(s["dur_s"]))
    phase_wall = {
        name: {"n": len(xs), "median_s": _median(xs), "total_s": sum(xs)}
        for name, xs in sorted(durs.items())
    }
    rounds_seen = sorted({s["round"] for s in spans
                          if s.get("name") == "round"
                          and s.get("round") is not None})
    compile_s = sum(durs.get("compile", []))
    trace_lower_s = sum(durs.get("trace_lower", []))
    execute_med = _median(durs.get("execute", []))

    # -- comm ledgers -------------------------------------------------------
    comm = ledger_values("comm_bytes")
    comm_summary: Dict[str, Any] = {}
    if comm:
        last = comm[-1]
        comm_summary = {
            "rounds_accounted": len(comm),
            "bytes_down_per_round": _median(
                [c["down"] for c in comm if "down" in c]),
            "bytes_up_per_round": _median(
                [c["up"] for c in comm if "up" in c]),
            "cum_down": last.get("cum_down"),
            "cum_up": last.get("cum_up"),
            "cum_total": last.get("cum_total"),
        }

    # -- roofline (first-round lowered program) -----------------------------
    rooflines = ledger_values("roofline")
    roofline = rooflines[0] if rooflines else {}

    # -- client health ------------------------------------------------------
    def counter_total(name: str) -> int:
        return int(sum(c.get("value", 0) for c in counters
                       if c.get("name") == name))

    staleness: Dict[str, int] = {}
    for h in ledger_values("staleness_hist"):
        for k, v in h.items():
            staleness[k] = staleness.get(k, 0) + int(v)
    # participation histogram: last wins (cumulative over the run, unlike
    # the per-round staleness histograms which sum)
    part_hists = ledger_values("participation_hist")
    states = ledger_values("client_state")
    health = {
        "nan_excluded_devices": counter_total("nan_excluded_devices"),
        "padding_weight0_clients": counter_total("padding_weight0_clients"),
        "version_cache_hit": counter_total("version_cache_hit"),
        "version_cache_miss": counter_total("version_cache_miss"),
        "staleness_hist": dict(sorted(staleness.items(),
                                      key=lambda kv: int(kv[0]))),
        "participation_hist": part_hists[-1] if part_hists else {},
        "client_state_bytes": (states[-1].get("state_bytes")
                               if states else None),
    }

    # -- progress / rounds-to-target ----------------------------------------
    evals = [(e.get("round"), e.get("values", {}))
             for e in ledgers if e.get("name") == "eval"]
    trajectory = [(r, v.get(target_metric)) for r, v in evals
                  if v.get(target_metric) is not None]
    maximize = higher_is_better(target_metric)
    rounds_to_target = None
    if target is not None:
        for r, v in trajectory:
            if v is not None and (v >= target if maximize
                                  else v <= target):
                rounds_to_target = r
                break

    return {
        "run_config": run_config,
        "rounds": {
            "n_rounds": len(rounds_seen) or len(comm),
            "phase_wall": phase_wall,
            "compile_s": compile_s,
            "trace_lower_s": trace_lower_s,
            "execute_median_s": execute_med,
        },
        "comm": comm_summary,
        "roofline": roofline,
        "health": health,
        "progress": {
            "metric": target_metric,
            "target": target,
            "trajectory": trajectory,
            "rounds_to_target": rounds_to_target,
            "final": trajectory[-1][1] if trajectory else None,
        },
        "n_events": len(events),
    }


def _fmt_bytes(n: Optional[float]) -> str:
    if n is None:
        return "-"
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{n:.0f} B"
        n /= 1024
    return f"{n:.1f} GiB"


def _fmt_s(x: Optional[float]) -> str:
    return "-" if x is None else f"{x:.3f}s"


def render(summary: Dict[str, Any]) -> str:
    """Format a :func:`summarize` dict as the printed report."""
    lines: List[str] = []
    add = lines.append
    add("== telemetry run report ==")
    add(f"events: {summary['n_events']}")

    cfg = summary["run_config"]
    if cfg:
        add("")
        add("-- run --")
        for k in sorted(cfg):
            add(f"  {k}: {cfg[k]}")

    r = summary["rounds"]
    add("")
    add("-- rounds --")
    add(f"  rounds: {r['n_rounds']}")
    add(f"  compile (first round): {_fmt_s(r['compile_s'])} "
        f"(trace+lower {_fmt_s(r['trace_lower_s'])})")
    add(f"  execute median: {_fmt_s(r['execute_median_s'])}")
    for name, w in r["phase_wall"].items():
        add(f"  span {name}: n={w['n']} median={_fmt_s(w['median_s'])} "
            f"total={_fmt_s(w['total_s'])}")

    c = summary["comm"]
    if c:
        add("")
        add("-- comm --")
        add(f"  bytes/round down: {_fmt_bytes(c['bytes_down_per_round'])}  "
            f"up: {_fmt_bytes(c['bytes_up_per_round'])}")
        add(f"  cumulative: down {_fmt_bytes(c['cum_down'])}  "
            f"up {_fmt_bytes(c['cum_up'])}  "
            f"total {_fmt_bytes(c['cum_total'])}")

    roof = summary["roofline"]
    if roof:
        add("")
        add("-- roofline (lowered round) --")
        for k in sorted(roof):
            add(f"  {k}: {roof[k]}")

    h = summary["health"]
    add("")
    add("-- client health --")
    add(f"  NaN-excluded devices: {h['nan_excluded_devices']}")
    add(f"  weight-0 padding slots: {h['padding_weight0_clients']}")
    add(f"  version cache: {h['version_cache_hit']} hit / "
        f"{h['version_cache_miss']} miss")
    if h["staleness_hist"]:
        hist = "  ".join(f"s={k}:{v}" for k, v in h["staleness_hist"].items())
        add(f"  staleness histogram: {hist}")
    if h.get("participation_hist"):
        hist = "  ".join(f"n={k}:{v}"
                         for k, v in h["participation_hist"].items())
        add(f"  participation histogram: {hist}")
    if h.get("client_state_bytes") is not None:
        add(f"  client-state matrix: {_fmt_bytes(h['client_state_bytes'])}")

    p = summary["progress"]
    if p["trajectory"]:
        add("")
        add("-- progress --")
        add(f"  metric: {p['metric']}  final: {p['final']:.4f}")
        if p["target"] is not None:
            hit = p["rounds_to_target"]
            add(f"  target {p['target']}: "
                + (f"reached at round {hit}" if hit is not None
                   else "not reached"))
    return "\n".join(lines)


def report_path(path: str, target: Optional[float] = None,
                target_metric: str = "loss_complex") -> str:
    """Read a JSONL run log and return the rendered report."""
    return render(summarize(read_jsonl(path), target=target,
                            target_metric=target_metric))
