"""Observability: structured telemetry for the federated training stack.

Public surface:

* :mod:`repro.obs.telemetry` — the event registry (:class:`Telemetry`),
  sinks (:class:`MemorySink`, :class:`JsonlSink`, :class:`StdoutSink`,
  :class:`NullSink`), and the disabled :data:`NOOP` singleton.
* :mod:`repro.obs.report` — renders a JSONL run log into the
  human-readable summary ``tools/obs_report.py`` prints.
"""

from repro.obs.telemetry import (  # noqa: F401
    NOOP,
    JsonlSink,
    MemorySink,
    NullSink,
    Sink,
    StdoutSink,
    Telemetry,
    coalesce,
    jsonable,
    read_jsonl,
)
