"""Structured telemetry: one event stream for the whole training stack.

FedHeN's claims are *trajectories* — bytes per round, rounds to a target
accuracy, straggler/staleness behaviour across a heterogeneous cohort —
yet until this module the repo's metrics were ad-hoc dicts printed from
the round loop, invisible to the async and quantization machinery.  This
is the one instrumentation substrate everything reports through:

* **Events** are plain JSON-ready dicts (no classes on the hot path, no
  dependencies beyond the stdlib — this module never imports jax).  Four
  kinds:

  - ``span``    — one phase of a round, in a tree addressed by ``path``
                  (e.g. ``round/execute/train-chunk[2]``).  ``dur_s`` is
                  wall seconds for host-measured spans and ``None`` for
                  *logical* spans: the round is ONE fused jit, so the
                  phases inside it (broadcast → train-chunk[t] → fold →
                  finalize) are real structure with real attributes
                  (staleness, fold weight, wire dtype) but their wall
                  time is only measurable at the host boundary — it is
                  attributed to the enclosing ``execute`` span, never
                  invented per phase.
  - ``counter`` — one named scalar (client-health: NaN-excluded devices,
                  weight-0 padding, version-cache hits/misses).
  - ``ledger``  — one named dict of related values (per-round comm
                  bytes, the compiled round's roofline numbers, eval
                  metrics, run config).
  - ``log``     — one verbatim human line (the round loop's existing
                  print format routes through here bit-identically).

* **Sinks** receive every event: :class:`StdoutSink` (prints ``log``
  lines verbatim — the legacy print path), :class:`JsonlSink` (one JSON
  object per line — the run log ``tools/obs_report.py`` renders), and
  :class:`MemorySink` (in-process list, what the tests assert against).

* **Disabled is the default and costs (almost) nothing.**  The module
  singleton :data:`NOOP` — and any ``Telemetry(enabled=False)`` — takes
  an early-return path: ``span`` hands back one shared re-entrant no-op
  context manager and every emit method returns before building an event
  dict.  The overhead of both states is measured by
  ``benchmarks/obs_overhead.py`` and CI-gated (<2% round-clock when off,
  <5% when on, ``BENCH_obs.json``).

Every event carries ``seq`` (emission order), ``round`` (the trainer
stamps it via :meth:`Telemetry.set_round`), and ``t`` (wall clock).
Attribute values must be JSON-serializable scalars; :func:`jsonable`
coerces numpy/jax scalars at the sink boundary so the hot path never
imports them.
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, IO, Iterable, List, Optional, Sequence

EVENT_KINDS = ("span", "counter", "ledger", "log")


def jsonable(value: Any) -> Any:
    """Coerce a value to something ``json.dumps`` accepts: stdlib scalars
    pass through; numpy/jax zero-dim arrays and scalars go through their
    ``item()``; anything else falls back to ``str``."""
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, dict):
        return {str(k): jsonable(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [jsonable(v) for v in value]
    item = getattr(value, "item", None)
    if callable(item):
        try:
            return jsonable(item())
        except (TypeError, ValueError):
            pass
    return str(value)


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------

class Sink:
    """One consumer of the event stream.  ``emit`` receives every event
    dict (already JSON-ready); ``close`` flushes whatever the sink
    buffers.  Sinks must not mutate the event (it is shared)."""

    def emit(self, event: Dict[str, Any]) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class MemorySink(Sink):
    """Keeps every event in ``self.events`` — the test sink."""

    def __init__(self):
        self.events: List[Dict[str, Any]] = []

    def emit(self, event: Dict[str, Any]) -> None:
        self.events.append(event)

    def of_kind(self, kind: str) -> List[Dict[str, Any]]:
        return [e for e in self.events if e["kind"] == kind]

    def named(self, name: str) -> List[Dict[str, Any]]:
        return [e for e in self.events if e.get("name") == name]


class StdoutSink(Sink):
    """Prints ``log`` events' message VERBATIM (the legacy print-based
    round logging routes through here, so the line format stays
    bit-identical to the pre-telemetry driver).  Other kinds are dropped
    unless ``verbose=True``, which renders them as compact one-liners."""

    def __init__(self, verbose: bool = False):
        self.verbose = verbose

    def emit(self, event: Dict[str, Any]) -> None:
        if event["kind"] == "log":
            print(event["message"], flush=True)
        elif self.verbose:
            body = {k: v for k, v in event.items()
                    if k not in ("kind", "name", "seq", "t")}
            print(f"[obs] {event['kind']} {event.get('name', '')} {body}",
                  flush=True)


class JsonlSink(Sink):
    """Appends one JSON object per event to a file — the run log.

    The file handle is opened lazily on the first event and line-buffered
    so a crashed run still leaves a readable log.  ``tools/obs_report.py``
    renders the result.
    """

    def __init__(self, path: str):
        self.path = path
        self._fh: Optional[IO[str]] = None

    def emit(self, event: Dict[str, Any]) -> None:
        if self._fh is None:
            self._fh = open(self.path, "w", buffering=1)
        self._fh.write(json.dumps(event) + "\n")

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class NullSink(Sink):
    """Swallows everything.  A telemetry-ENABLED run with only this sink
    must be bit-identical to a telemetry-off run (test-enforced): sinks
    observe the round, they never steer it."""

    def emit(self, event: Dict[str, Any]) -> None:
        pass


# ---------------------------------------------------------------------------
# Spans
# ---------------------------------------------------------------------------

class _NullSpan:
    """Shared re-entrant no-op context manager — the disabled ``span``
    path.  One instance serves every call site (no allocation)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """A timed phase: enters the telemetry's span stack (its name becomes
    a path segment for everything emitted inside) and emits one ``span``
    event with measured ``dur_s`` on exit."""
    __slots__ = ("_tel", "name", "attrs", "_t0")

    def __init__(self, tel: "Telemetry", name: str,
                 attrs: Optional[Dict[str, Any]]):
        self._tel = tel
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self._tel._stack.append(self.name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self._t0
        tel = self._tel
        tel._stack.pop()
        tel._emit("span", self.name, path=tel._path(self.name),
                  dur_s=dur, attrs=self.attrs)
        return False


# ---------------------------------------------------------------------------
# The registry
# ---------------------------------------------------------------------------

class Telemetry:
    """The event registry one training run reports through.

    Args:
      sinks: consumers of the event stream (default: none — events are
        still assembled unless ``enabled=False``; pass :class:`NullSink`
        to measure the enabled path without I/O).
      enabled: ``False`` short-circuits every method before any event
        dict is built — the no-op path the :data:`NOOP` singleton and a
        plain (un-instrumented) trainer share.

    The span stack is **host-thread-local by construction** (one
    Telemetry per trainer, driven from the round loop); it is not safe to
    share one instance across threads.
    """

    def __init__(self, sinks: Sequence[Sink] = (), *, enabled: bool = True):
        self.sinks: List[Sink] = list(sinks)
        self.enabled = bool(enabled)
        self.current_round: Optional[int] = None
        self._stack: List[str] = []
        self._seq = 0

    # -- lifecycle -----------------------------------------------------------

    def add_sink(self, sink: Sink) -> "Telemetry":
        self.sinks.append(sink)
        return self

    def close(self) -> None:
        for s in self.sinks:
            s.close()

    def set_round(self, round_index: int) -> None:
        """Stamp subsequent events with this round index (the trainer
        calls it at round entry)."""
        if self.enabled:
            self.current_round = int(round_index)

    # -- emission ------------------------------------------------------------

    def _path(self, leaf: str) -> str:
        return "/".join(self._stack + [leaf])

    def _emit(self, kind: str, name: str, **fields) -> None:
        attrs = fields.pop("attrs", None)
        event: Dict[str, Any] = {
            "kind": kind, "name": name, "seq": self._seq,
            "round": self.current_round, "t": time.time(),
        }
        if attrs:
            event.update({k: jsonable(v) for k, v in attrs.items()})
        for k, v in fields.items():
            event[k] = jsonable(v)
        self._seq += 1
        for s in self.sinks:
            s.emit(event)

    def span(self, name: str, **attrs):
        """Timed context manager: wall time between enter and exit is the
        span's ``dur_s``; events emitted inside nest under its path."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs or None)

    def point_span(self, name: str, **attrs):
        """A *logical* span: structure + attributes, ``dur_s=None``.

        Used for the phases inside the fused round jit (broadcast /
        train-chunk[t] / fold / finalize): they are real stages of the
        executed program, but their wall time is only measurable at the
        host boundary, so none is invented — the enclosing ``execute``
        span owns the clock."""
        if not self.enabled:
            return
        self._emit("span", name, path=self._path(name), dur_s=None,
                   attrs=attrs or None)

    def counter(self, name: str, value, **attrs) -> None:
        """One named scalar observation (client health lives here)."""
        if not self.enabled:
            return
        self._emit("counter", name, value=value, attrs=attrs or None)

    def ledger(self, name: str, values: Dict[str, Any], **attrs) -> None:
        """One named dict of related values (comm bytes, roofline, eval
        metrics, run config)."""
        if not self.enabled:
            return
        self._emit("ledger", name, values=jsonable(values),
                   attrs=attrs or None)

    def log(self, message: str) -> None:
        """One verbatim human line.  :class:`StdoutSink` prints exactly
        ``message`` — the legacy round-loop print format survives
        bit-identically."""
        if not self.enabled:
            return
        self._emit("log", "log", message=str(message))


#: The module-wide disabled singleton: what every un-instrumented trainer
#: runs against.  Never add sinks to it.
NOOP = Telemetry(enabled=False)


def coalesce(telemetry: Optional[Telemetry]) -> Telemetry:
    """``None`` -> the :data:`NOOP` singleton (the constructor-default
    dance every instrumented component does)."""
    return NOOP if telemetry is None else telemetry


# ---------------------------------------------------------------------------
# Run-log reading (the reporter's input side lives with the schema)
# ---------------------------------------------------------------------------

def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Parse a :class:`JsonlSink` run log back into event dicts (blank
    and truncated trailing lines are skipped — crashed runs stay
    readable)."""
    events = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return events
