"""Roofline-term derivation from a compiled dry-run artifact.

Sources:
* ``compiled.cost_analysis()`` — per-device HLO FLOPs and bytes accessed.
* ``compiled.as_text()`` — post-SPMD per-device HLO; collective bytes are
  summed from the *result shapes* of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute ops (an upper bound on
  per-chip bytes moved; documented in EXPERIMENTS.md).

Terms (seconds, per step, per chip):
    compute    = HLO_FLOPs / PEAK_FLOPS_BF16
    memory     = HLO_bytes / HBM_BW
    collective = collective_bytes / ICI_LINK_BW
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from repro.roofline import hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %ar.1 = f32[256,128]{1,0} all-reduce(...)
#        %t = (bf16[8]{0}, bf16[8]{0}) all-gather(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-type result bytes (per device)."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        out[op] += _shape_bytes(shape_str)
        counts[op] += 1
    out["_counts"] = counts  # type: ignore
    return out


@dataclass
class RooflineRecord:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float          # HLO, per device, per step
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    peak_memory_per_chip: float = 0.0
    argument_bytes_per_chip: float = 0.0
    model_flops: float = 0.0       # analytical 6ND / 2ND (global)
    longctx_variant: bool = False
    param_bytes_per_chip: float = 0.0
    cache_bytes_per_chip: float = 0.0
    hbm_analytic_per_chip: float = 0.0   # traffic model (see analytic_hbm)

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        """Analytic HBM traffic (weights + activations + caches) / HBM bw.
        The HLO byte proxy (``bytes_per_chip``) is kept as a diagnostic but
        over-materializes on the CPU backend (weak fusion)."""
        return self.hbm_analytic_per_chip / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / hw.ICI_LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): how much compiled compute is
        'useful' (catches remat/redundancy/padding waste)."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_dict(self) -> Dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio)
        return d


def analytic_hbm(cfg, shape, param_bytes_chip: float,
                 cache_bytes_chip: float, chips: int) -> float:
    """Per-chip HBM traffic model for one step.

    train:  weights are read 3x (fwd, remat re-fwd, bwd) and written once
            with gradients read+written once -> ~6x param bytes; plus saved
            period activations written+read.
    prefill: weights 1x + cache write + layer activations streamed 2x.
    decode:  weights 1x + cache read + write (the classic decode bound).
    """
    act_bytes = 2  # bf16
    data_shards = max(chips // 16, 1)  # data(+pod) axes of the mesh
    if shape.kind == "train":
        tokens_chip = shape.global_batch * shape.seq_len / data_shards
        saved = cfg.n_periods * tokens_chip * cfg.d_model * act_bytes
        return 6.0 * param_bytes_chip + 2.0 * saved
    if shape.kind == "prefill":
        tokens_chip = shape.global_batch * shape.seq_len / data_shards
        stream = 2.0 * cfg.n_layers * tokens_chip * cfg.d_model * act_bytes
        return param_bytes_chip + cache_bytes_chip + stream
    # decode: one token; MoE reads only the experts the batch touches
    weight_read = param_bytes_chip
    if cfg.moe is not None and cfg.moe.n_experts > cfg.moe.top_k:
        e, k = cfg.moe.n_experts, cfg.moe.top_k
        inactive_frac = 1.0 - cfg.active_param_count() / cfg.param_count()
        expert_frac = min(inactive_frac * e / (e - k), 0.99)
        touched = min(1.0, shape.global_batch * k / e)
        weight_read = param_bytes_chip * (
            (1.0 - expert_frac) + expert_frac * touched)
    return weight_read + 2.0 * cache_bytes_chip


def model_flops(cfg, shape) -> float:
    """Analytical 'useful' FLOPs per step (global, all chips).

    train: 6 * N_active * tokens ; prefill: 2 * N_active * tokens ;
    decode: 2 * N_active * batch (one token per sequence).
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def make_record(*, arch: str, shape, mesh_name: str, chips: int,
                cost: Dict, mem, hlo_text: str, cfg,
                longctx_variant: bool = False,
                param_bytes_chip: float = 0.0,
                cache_bytes_chip: float = 0.0) -> RooflineRecord:
    """Loop-aware costs come from roofline.hlo_walk (XLA's cost_analysis
    counts while bodies once — kept only as a cross-reference field)."""
    from repro.roofline import hlo_walk
    walk = hlo_walk.analyze(hlo_text)
    hbm = analytic_hbm(cfg, shape, param_bytes_chip, cache_bytes_chip, chips)
    return RooflineRecord(
        param_bytes_per_chip=param_bytes_chip,
        cache_bytes_per_chip=cache_bytes_chip,
        hbm_analytic_per_chip=hbm,
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_chip=float(walk["flops"]),
        bytes_per_chip=float(walk["hbm_bytes"]),
        coll_bytes_per_chip=float(walk["total_collective_bytes"]),
        coll_breakdown={**walk["collective_bytes"],
                        "counts": walk["collective_counts"],
                        "xla_cost_flops": float(cost.get("flops", 0.0)),
                        "xla_cost_bytes":
                            float(cost.get("bytes accessed", 0.0))},
        peak_memory_per_chip=float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)),
        argument_bytes_per_chip=float(getattr(mem, "argument_size_in_bytes", 0)),
        model_flops=model_flops(cfg, shape),
        longctx_variant=longctx_variant)
