"""TPU v5e hardware constants (the TARGET; the container runs CPU)."""

PEAK_FLOPS_BF16 = 197e12        # per chip, bf16
HBM_BW = 819e9                  # bytes/s per chip
ICI_LINK_BW = 50e9              # bytes/s per link (~)
HBM_BYTES = 16 * 2 ** 30        # 16 GiB per chip
VMEM_BYTES = 128 * 2 ** 20      # ~128 MiB vector memory
MXU_DIM = 128                   # systolic array tile edge
