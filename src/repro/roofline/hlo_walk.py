"""Loop-aware HLO cost walker.

``compiled.cost_analysis()`` on the CPU backend counts while-loop bodies
ONCE (verified: a 10-iteration scan of matmuls reports 1x the flops), which
makes it useless for scan-based layer stacks.  This walker parses the
post-SPMD per-device HLO text and computes:

* ``flops``            — dot/convolution FLOPs (2*m*n*k convention), with
  while bodies multiplied by their trip count (parsed from the loop
  condition's comparison constant);
* ``collective_bytes`` — per collective type, result-shape bytes, loop-aware;
* ``hbm_bytes``        — an HBM-traffic proxy: operand + result bytes of
  materialization-boundary ops (fusions, dots, convs, copies, collectives),
  loop-aware.  Fusion-internal ops are not double counted.

Because the input is the *post-partitioning* module, per-device shapes
already reflect replication waste (e.g. attention replicated when heads
don't divide the model axis) — so per-chip numbers are honest.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
    "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_BOUNDARY_OPS = {"fusion", "dot", "convolution", "copy", "transpose",
                 "reshape", "broadcast", "reduce", "scatter", "gather",
                 "dynamic-slice", "dynamic-update-slice", "concatenate",
                 "slice", "pad", "select-and-scatter", "reduce-window",
                 "sort", "iota", "rng", "convert", "add", "multiply",
                 "subtract", "divide", "select", "compare", "tanh", "exponential",
                 } | set(_COLLECTIVES)


def _shape_numel_bytes(shape_str: str) -> Tuple[int, int]:
    """Total (numel, bytes) over all array components in a shape string."""
    numel = 0
    nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        numel += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return numel, nbytes


def _first_shape_dims(shape_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",") if d]


@dataclass
class Instruction:
    name: str
    op: str
    shape_str: str
    line: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instructions: Dict[str, Instruction] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)
    param_shapes: Dict[str, str] = field(default_factory=dict)


_COMP_HEADER_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w\.\-~]+)\s*\((.*?)\)\s*->\s*.*\{\s*$")
_INST_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-~]+)\s*=\s*(.*)$")
_OP_NAME_RE = re.compile(r"^\s*([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-~]+)")


def _split_shape_op(rest: str):
    """Split '<shape> <op>(<args...>' — shape may be a tuple containing
    parens and '/*index=N*/' comments, so match parens by depth."""
    rest = rest.lstrip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape, tail = rest[:i + 1], rest[i + 1:]
                    m = _OP_NAME_RE.match(tail)
                    if m:
                        return shape, m.group(1), tail[m.end():]
                    return None
        return None
    parts = rest.split(None, 1)
    if len(parts) != 2:
        return None
    shape, tail = parts
    m = _OP_NAME_RE.match(tail)
    if m:
        return shape, m.group(1), tail[m.end():]
    return None
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                        r"[{]?%?([\w\.\-~,%\s]+)[}]?")


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for raw in text.splitlines():
        header = _COMP_HEADER_RE.match(raw.strip()) if "{" in raw else None
        if header and "=" not in raw.split("(")[0]:
            current = Computation(header.group(1))
            comps[current.name] = current
            # parameter shapes from the header signature
            for pm in re.finditer(r"%?([\w\.\-~]+):\s*"
                                  r"(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)",
                                  header.group(2)):
                current.param_shapes[pm.group(1)] = pm.group(2)
            continue
        if current is None:
            continue
        if raw.strip() == "}":
            current = None
            continue
        m = _INST_HEAD_RE.match(raw)
        if m:
            name, rest = m.groups()
            split = _split_shape_op(rest)
            if split is None:
                continue
            shape_str, op, args = split
            args_part = args.split("),")[0]
            operands = _OPERAND_RE.findall(args_part)
            inst = Instruction(name=name, op=op, shape_str=shape_str,
                               line=raw, operands=operands)
            current.instructions[name] = inst
            current.order.append(name)
    return comps


def _operand_shape(comp: Computation, operand: str) -> Optional[str]:
    if operand in comp.instructions:
        return comp.instructions[operand].shape_str
    if operand in comp.param_shapes:
        return comp.param_shapes[operand]
    return None


def _dot_flops(comp: Computation, inst: Instruction) -> float:
    _, out_bytes = _shape_numel_bytes(inst.shape_str)
    out = _first_shape_dims(inst.shape_str)
    if out is None:
        return 0.0
    out_numel = math.prod(out[1]) if out[1] else 1
    k = 1
    mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.line)
    if mm and inst.operands:
        lhs_shape = _operand_shape(comp, inst.operands[0])
        if lhs_shape:
            parsed = _first_shape_dims(lhs_shape)
            if parsed:
                dims = parsed[1]
                for idx in mm.group(1).split(","):
                    if idx and int(idx) < len(dims):
                        k *= dims[int(idx)]
    return 2.0 * out_numel * k


def _conv_flops(comp: Computation, inst: Instruction) -> float:
    out = _first_shape_dims(inst.shape_str)
    if out is None or len(inst.operands) < 2:
        return 0.0
    out_numel = math.prod(out[1]) if out[1] else 1
    rhs_shape = _operand_shape(comp, inst.operands[1])
    if not rhs_shape:
        return 0.0
    parsed = _first_shape_dims(rhs_shape)
    if not parsed:
        return 0.0
    kernel = parsed[1]
    # per output element: 2 * prod(kernel dims except output-feature dim)
    dn = re.search(r"dim_labels=\S*", inst.line)
    per_out = 2 * math.prod(kernel)
    # divide by output feature count (one kernel dim indexes output features)
    if kernel:
        per_out //= max(kernel[-1], 1)   # HWIO default: last dim = O
    return float(out_numel * per_out)


_TRIP_CONST_RE = re.compile(r"constant\((\d+)\)")


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for iname in cond.order:
        inst = cond.instructions[iname]
        if inst.op == "constant":
            m = _TRIP_CONST_RE.search(inst.line)
            if m:
                best = max(best, int(m.group(1)))
        m = _TRIP_CONST_RE.search(inst.line)
        if m and inst.op in ("compare", "fusion"):
            best = max(best, int(m.group(1)))
    return best


@dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) \
                + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0.0) \
                + v * mult


def _called_comps(inst: Instruction) -> List[str]:
    out = []
    for key in ("calls", "to_apply", "body", "condition"):
        m = re.search(rf"{key}=%?([\w\.\-~]+)", inst.line)
        if m:
            out.append((key, m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", inst.line)
    if m:
        for b in m.group(1).split(","):
            out.append(("branch", b.strip().lstrip("%")))
    return out


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._cache: Dict[str, Costs] = {}
        entry = None
        for name in self.comps:
            if re.search(r"^(main|entry)", name) or entry is None:
                pass
        # entry = computation referenced by none (topmost) — find by name
        called = set()
        for c in self.comps.values():
            for iname in c.order:
                for _, cal in _called_comps(c.instructions[iname]):
                    called.add(cal)
        candidates = [n for n in self.comps if n not in called]
        # prefer one containing 'main'
        main = [n for n in candidates if "main" in n]
        self.entry = main[0] if main else (candidates[0] if candidates
                                           else next(iter(self.comps)))

    def comp_costs(self, name: str) -> Costs:
        if name in self._cache:
            return self._cache[name]
        comp = self.comps.get(name)
        total = Costs()
        self._cache[name] = total          # cycle guard (shouldn't happen)
        if comp is None:
            return total
        inside_fusion = name.startswith("fused_") or "fused" in name
        for iname in comp.order:
            inst = comp.instructions[iname]
            op = inst.op
            if op == "dot":
                total.flops += _dot_flops(comp, inst)
            elif op == "convolution":
                total.flops += _conv_flops(comp, inst)
            if op in _COLLECTIVES or op.replace("-start", "") in _COLLECTIVES:
                base = op.replace("-start", "")
                _, nbytes = _shape_numel_bytes(inst.shape_str)
                total.collective_bytes[base] = \
                    total.collective_bytes.get(base, 0.0) + nbytes
                total.collective_counts[base] = \
                    total.collective_counts.get(base, 0.0) + 1

            calls = _called_comps(inst)
            if op == "while":
                body = next((c for k, c in calls if k == "body"), None)
                cond = next((c for k, c in calls if k == "condition"), None)
                trips = _trip_count(self.comps, cond) if cond else 1
                if body:
                    total.add(self.comp_costs(body), trips)
                if cond:
                    total.add(self.comp_costs(cond), trips)
            elif op == "conditional":
                branches = [c for k, c in calls if k == "branch"]
                sub = [self.comp_costs(b) for b in branches]
                if sub:
                    # take the max-flops branch as the executed one
                    total.add(max(sub, key=lambda c: c.flops))
            else:
                for _, cal in calls:
                    total.add(self.comp_costs(cal))

            # HBM-traffic proxy: boundary ops only, skip inside fusions
            if not inside_fusion and op in _BOUNDARY_OPS:
                _, out_b = _shape_numel_bytes(inst.shape_str)
                total.hbm_bytes += out_b
                for operand in inst.operands:
                    oshape = _operand_shape(comp, operand)
                    if oshape:
                        _, ob = _shape_numel_bytes(oshape)
                        total.hbm_bytes += ob
        self._cache[name] = total
        return total

    def entry_costs(self) -> Costs:
        return self.comp_costs(self.entry)


def analyze(text: str) -> Dict:
    model = HloCostModel(text)
    c = model.entry_costs()
    return {
        "flops": c.flops,
        "hbm_bytes": c.hbm_bytes,
        "collective_bytes": dict(c.collective_bytes),
        "collective_counts": dict(c.collective_counts),
        "total_collective_bytes": sum(c.collective_bytes.values()),
    }


def xla_cost_analysis(compiled) -> Dict:
    """XLA's own cost analysis of a compiled executable, normalized.

    ``compiled.cost_analysis()`` returns a per-device *list* of dicts on
    some jax versions and a plain dict on others; this shim always
    returns the first device's dict (empty if the backend refuses the
    query), so consumers — the telemetry roofline ledger, tests on both
    CI jax matrix legs — never branch on the jax version.  Remember the
    number it reports is loop-UNAWARE (while bodies counted once); use
    :func:`analyze` for trip-count-corrected costs.
    """
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
