"""Optimizers.  The paper trains with plain SGD (eta = 0.1) and global-norm
gradient clipping at 10 (Appendix A).  AdamW is provided for the beyond-paper
centralized/e2e drivers.  All are stateless-or-explicit-state pure functions
so they jit/scan cleanly and keep the 1T-param SGD path zero-state."""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Tree = Any


def global_norm(tree: Tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Tree, max_norm: float) -> Tuple[Tree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def sgd_update(params: Tree, grads: Tree, lr: float,
               clip_norm: Optional[float] = None) -> Tree:
    """w <- w - lr * clip(g).  Arithmetic in fp32, stored in param dtype."""
    if clip_norm:
        grads, _ = clip_by_global_norm(grads, clip_norm)
    return jax.tree.map(
        lambda w, g: (w.astype(jnp.float32)
                      - lr * g.astype(jnp.float32)).astype(w.dtype),
        params, grads)


# ---------------------------------------------------------------------------
# AdamW (beyond-paper, for the centralized reference runs)
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    step: jax.Array
    mu: Tree
    nu: Tree


def adam_init(params: Tree) -> AdamState:
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    return AdamState(jnp.zeros((), jnp.int32), zeros,
                     jax.tree.map(jnp.copy, zeros))


def adam_update(params: Tree, grads: Tree, state: AdamState, lr: float, *,
                b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                weight_decay: float = 0.0,
                clip_norm: Optional[float] = None) -> Tuple[Tree, AdamState]:
    if clip_norm:
        grads, _ = clip_by_global_norm(grads, clip_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)

    def upd(w, m, v):
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * w.astype(jnp.float32)
        return (w.astype(jnp.float32) - lr * delta).astype(w.dtype)

    return jax.tree.map(upd, params, mu, nu), AdamState(step, mu, nu)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = (step - warmup) / jnp.maximum(total - warmup, 1)
        frac = jnp.clip(frac, 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr
